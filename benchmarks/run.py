"""Benchmark harness entrypoint: one section per paper table/figure plus
the roofline report.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,us_per_call,derived`` CSV lines per section:
  * table1_*       -- paper Table I (Q0-Q6 latency + cost, 3 conditions)
  * shuffle_*      -- SQS vs S3 shuffle (paper SectionV/VI comparison)
  * kernel rows    -- Pallas-kernel reference benches + TPU predictions
  * roofline_*     -- per-(arch x shape) dominant term from the dry-run
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import kernels_bench, shuffle_backends, table1_queries
    print("name,us_per_call,derived")

    results, agreement = table1_queries.run()
    for r in results:
        print(f"table1_{r['query']}_{r['backend']},"
              f"{r['latency_s'] * 1e6:.0f},cost_usd={r['cost_usd']:.6f}")
    print(f"table1_agreement,0,{agreement}")

    rows, agree = shuffle_backends.run()
    for r in rows:
        print(f"shuffle_{r['backend']},{r['wall_s'] * 1e6:.0f},"
              f"modeled_service_s={r['modeled_service_s']}"
              f";cost={r['shuffle_cost_usd']}")
    print(f"shuffle_agreement,0,{agree}")

    ab, identical, speedup = shuffle_backends.run_pipeline_ab()
    for r in ab:
        print(f"pipeline_{r['mode']},{r['wall_s'] * 1e6:.0f},"
              f"sqs_requests={r['sqs_requests']}"
              f";lambda_requests={r['lambda_requests']}"
              f";cost={r['total_usd']}")
    print(f"pipeline_speedup,0,{speedup}x_identical={identical}")

    kernels_bench.main()  # prints its own rows

    try:
        from benchmarks import roofline
        rows = roofline.load_rows()
        for r in rows:
            if "skipped" in r:
                continue
            dom_us = max(r["compute_s"], r["memory_s"],
                         r["collective_s"]) * 1e6
            print(f"roofline_{r['arch']}_{r['shape']},{dom_us:.0f},"
                  f"dominant={r['dominant']}"
                  f";frac={r['roofline_fraction']:.3f}")
    except Exception as e:  # artifacts absent until the dry-run has run
        print(f"roofline_unavailable,0,{type(e).__name__}", file=sys.stderr)


if __name__ == "__main__":
    main()
