"""Shuffle benchmarks.

1. SQS vs S3 transport (paper §V/§VI: 'the design choice of using S3 vs.
   SQS for data shuffling should be examined in detail'). Same
   shuffle-heavy query, two transports. We report measured wall latency,
   billed requests, and the MODELED service latency (request count x
   typical 2018 per-op latency: SQS batch ~10 ms, S3 PUT ~30 ms /
   GET ~20 ms, LIST ~50 ms) — the analytic form of the paper's 'I/O
   patterns are not a good fit for S3' claim: object-store shuffles pay
   per-object latency and 12.5x the per-request price of a queue batch.

2. Barrier vs PIPELINED stage execution (EOS shuffle protocol, see
   docs/eos_shuffle.md). Same query, same transport, invocation start
   latency simulated (``start_latency_scale=1``): the barrier scheduler
   pays the consumer stage's cold-start wave and queue drain AFTER the
   producer stage finishes; the pipelined scheduler overlaps both with
   producer compute. Results must be identical — the speedup is measured,
   not claimed.

3. Fault-injection A/B (visibility-timeout recovery, paper §III/§VI):
   the same query fault-free vs with one reducer dying mid-drain
   (``fail_after_records``) plus a second reducer straggling (eligible
   for consumer-side speculation), under at-least-once duplication.
   Before visibility-timeout receives, the dying reducer aborted the
   whole job; now both modes must complete with IDENTICAL results, the
   overhead being a visibility-deadline wait plus the retry.
"""

from __future__ import annotations

import os
import time

from repro.core import FlintConfig, FlintContext
from repro.data.synthetic import taxi_csv

SQS_OP_LATENCY = 0.010
S3_PUT_LATENCY = 0.030
S3_GET_LATENCY = 0.020

N_ROWS = int(os.environ.get("TAXI_ROWS", "40000"))


def shuffle_query(ctx):
    # high-cardinality groupBy: every (month, hour, payment) cell
    return (ctx.textFile("taxi.csv", 8)
            .map(lambda x: x.split(","))
            .map(lambda x: ((x[0][5:7], x[0][11:13], x[5]), 1))
            .reduceByKey(lambda a, b: a + b, 16)
            .collect())


def run(rows=None):
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    answers = []
    for backend in ("sqs", "s3"):
        ctx = FlintContext("flint", FlintConfig(concurrency=16,
                                                flush_records=2000,
                                                shuffle_backend=backend))
        ctx.upload("taxi.csv", data)
        t0 = time.monotonic()
        ans = shuffle_query(ctx)
        wall = time.monotonic() - t0
        rep = ctx.cost_report()
        if backend == "sqs":
            modeled = rep["sqs_requests"] * SQS_OP_LATENCY
        else:
            modeled = (rep["s3_puts"] * S3_PUT_LATENCY
                       + rep["s3_gets"] * S3_GET_LATENCY)
        out.append({
            "backend": backend, "wall_s": round(wall, 4),
            "modeled_service_s": round(modeled, 3),
            "shuffle_cost_usd": round(rep["sqs_usd"] + rep["s3_usd"], 6),
            "sqs_requests": rep["sqs_requests"],
            "s3_ops": rep["s3_gets"] + rep["s3_puts"],
        })
        answers.append(sorted(ans))
    agreement = answers[0] == answers[1]
    return out, agreement


def run_pipeline_ab(rows=None, trials=2):
    """Barrier vs pipelined stage execution, same query + transport.
    Best-of-``trials`` wall time per mode (latency benchmark: the minimum
    is the least noise-contaminated sample). Returns (per-mode rows,
    results-identical, speedup)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    answers = []
    for pipelined in (False, True):
        wall = float("inf")
        for _ in range(trials):
            ctx = FlintContext("flint",
                               FlintConfig(concurrency=16,
                                           flush_records=2000,
                                           start_latency_scale=1.0,
                                           pipeline_stages=pipelined))
            ctx.upload("taxi.csv", data)
            t0 = time.monotonic()
            ans = shuffle_query(ctx)
            wall = min(wall, time.monotonic() - t0)
        rep = ctx.cost_report()
        out.append({
            "mode": "pipelined" if pipelined else "barrier",
            "wall_s": round(wall, 4),
            "sqs_requests": rep["sqs_requests"],
            "lambda_requests": rep["lambda_requests"],
            "total_usd": round(rep["total_usd"], 6),
        })
        answers.append(sorted(ans))
    speedup = out[0]["wall_s"] / max(out[1]["wall_s"], 1e-9)
    return out, answers[0] == answers[1], round(speedup, 2)


def run_fault_ab(rows=None):
    """Consumer fault injection: reduce-stage task 0 dies after 5 records,
    task 1 straggles 0.6 s (speculation candidate), SQS duplicates 5 % of
    deliveries. Returns (per-run rows, all-runs-identical)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    faults = {(1, 0): {"fail_after_records": 5},
              (1, 1): {"straggle_s": 0.6}}
    out = []
    identical = True
    for pipelined in (False, True):
        answers = []
        for fault_plan in ({}, faults):
            ctx = FlintContext(
                "flint",
                FlintConfig(concurrency=16, flush_records=2000,
                            pipeline_stages=pipelined,
                            duplicate_prob=0.05,
                            visibility_timeout_s=1.0,
                            drain_timeout_s=10.0,
                            speculation_factor=2.0,
                            speculation_min_done=2),
                fault_plan=fault_plan, elastic_retries=0)
            ctx.upload("taxi.csv", data)
            t0 = time.monotonic()
            ans = shuffle_query(ctx)
            wall = time.monotonic() - t0
            answers.append(sorted(ans))
            stats = ctx.last_scheduler.stage_stats
            out.append({
                "mode": "pipelined" if pipelined else "barrier",
                "faults": "injected" if fault_plan else "none",
                "wall_s": round(wall, 4),
                "attempts": sum(s["attempts"] for s in stats),
                "speculated": sum(s["speculated"] for s in stats),
                "redeliveries": ctx.last_scheduler.sqs.redeliveries,
            })
        identical = identical and answers[0] == answers[1]
    return out, identical


def main():
    rows, agreement = run()
    print("backend,wall_s,modeled_service_s,shuffle_cost_usd,sqs_requests,s3_ops")
    for r in rows:
        print(f"{r['backend']},{r['wall_s']},{r['modeled_service_s']},"
              f"{r['shuffle_cost_usd']},{r['sqs_requests']},{r['s3_ops']}")
    print(f"# backends agree: {agreement}")
    ab, identical, speedup = run_pipeline_ab()
    print("mode,wall_s,sqs_requests,lambda_requests,total_usd")
    for r in ab:
        print(f"{r['mode']},{r['wall_s']},{r['sqs_requests']},"
              f"{r['lambda_requests']},{r['total_usd']}")
    print(f"# pipelined speedup: {speedup}x, results identical: {identical}")
    fault_rows, fault_identical = run_fault_ab()
    print("mode,faults,wall_s,attempts,speculated,redeliveries")
    for r in fault_rows:
        print(f"{r['mode']},{r['faults']},{r['wall_s']},{r['attempts']},"
              f"{r['speculated']},{r['redeliveries']}")
    print(f"# fault-injected runs identical to fault-free: {fault_identical}")
    return rows, agreement


if __name__ == "__main__":
    main()
