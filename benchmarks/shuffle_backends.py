"""SQS vs S3 shuffle (paper §V/§VI: 'the design choice of using S3 vs. SQS
for data shuffling should be examined in detail').

Same shuffle-heavy query, two transports. We report measured wall latency,
billed requests, and the MODELED service latency (request count x typical
2018 per-op latency: SQS batch ~10 ms, S3 PUT ~30 ms / GET ~20 ms,
LIST ~50 ms) — the analytic form of the paper's 'I/O patterns are not a
good fit for S3' claim: object-store shuffles pay per-object latency and
12.5x the per-request price of a queue batch.
"""

from __future__ import annotations

import os
import time

from repro.core import FlintConfig, FlintContext
from repro.data.synthetic import taxi_csv

SQS_OP_LATENCY = 0.010
S3_PUT_LATENCY = 0.030
S3_GET_LATENCY = 0.020

N_ROWS = int(os.environ.get("TAXI_ROWS", "40000"))


def shuffle_query(ctx):
    # high-cardinality groupBy: every (month, hour, payment) cell
    return (ctx.textFile("taxi.csv", 8)
            .map(lambda x: x.split(","))
            .map(lambda x: ((x[0][5:7], x[0][11:13], x[5]), 1))
            .reduceByKey(lambda a, b: a + b, 16)
            .collect())


def run(rows=None):
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    answers = []
    for backend in ("sqs", "s3"):
        ctx = FlintContext("flint", FlintConfig(concurrency=16,
                                                flush_records=2000,
                                                shuffle_backend=backend))
        ctx.upload("taxi.csv", data)
        t0 = time.monotonic()
        ans = shuffle_query(ctx)
        wall = time.monotonic() - t0
        rep = ctx.cost_report()
        if backend == "sqs":
            modeled = rep["sqs_requests"] * SQS_OP_LATENCY
        else:
            modeled = (rep["s3_puts"] * S3_PUT_LATENCY
                       + rep["s3_gets"] * S3_GET_LATENCY)
        out.append({
            "backend": backend, "wall_s": round(wall, 4),
            "modeled_service_s": round(modeled, 3),
            "shuffle_cost_usd": round(rep["sqs_usd"] + rep["s3_usd"], 6),
            "sqs_requests": rep["sqs_requests"],
            "s3_ops": rep["s3_gets"] + rep["s3_puts"],
        })
        answers.append(sorted(ans))
    agreement = answers[0] == answers[1]
    return out, agreement


def main():
    rows, agreement = run()
    print("backend,wall_s,modeled_service_s,shuffle_cost_usd,sqs_requests,s3_ops")
    for r in rows:
        print(f"{r['backend']},{r['wall_s']},{r['modeled_service_s']},"
              f"{r['shuffle_cost_usd']},{r['sqs_requests']},{r['s3_ops']}")
    print(f"# backends agree: {agreement}")
    return rows, agreement


if __name__ == "__main__":
    main()
