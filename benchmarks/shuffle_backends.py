"""Shuffle benchmarks.

1. TRANSPORT THREE-WAY (paper §V/§VI: 'the design choice of using S3 vs.
   SQS for data shuffling should be examined in detail'): the same taxi
   groupBy and join workloads over the SQS transport, the Lambada-style
   S3 exchange transport, and the provisioned-cluster baseline. Results
   must be identical across all three; per run we report measured wall
   latency, the MODELED service latency (request count x typical 2018
   per-op latency: SQS batch ~10 ms, S3 PUT ~30 ms / GET ~20 ms /
   LIST ~50 ms), and a Table-I-style per-service cost breakdown from
   ``CostLedger.service_subtotals``. Every serverless run is followed by
   a zero-leak assertion: no ``_spill/``, ``_payload/``, ``_exchange/``
   or ``_result/`` keys survive query completion.

2. COLUMNAR VS PICKLE FRAMING: the same groupBy with
   ``columnar_batches`` on/off — typed key/value columns must shrink
   shuffled bytes on the homogeneous-key workload.

3. Barrier vs PIPELINED stage execution (EOS shuffle protocol, see
   docs/eos_shuffle.md), invocation start latency simulated.

4. Fault-injection A/B (visibility-timeout recovery, paper §III/§VI):
   a reducer dying mid-drain plus a straggling reducer under 5 %
   duplicate delivery; both modes must match the fault-free run.

5. FAN-OUT A/B (docs/dag_fanout.md): a self-join and a diamond (one
   aggregation feeding two wide consumers) with plan-time CSE on/off on
   both transports — CSE must shrink the task count (the shared producer
   stage runs exactly once) with identical results — plus an RDD.cache()
   A/B where the second action replans from the materialization.

6. SQL OPTIMIZER A/B (docs/dataframe.md): two taxi analytics queries on
   the structured DataFrame surface (filter+project+groupBy, and
   join+agg), run optimized vs ``optimize=False`` on both transports.
   Hard gates: identical results across every (backend, optimized) cell,
   the optimized plan shuffles STRICTLY fewer bytes than the naive
   lowering on both queries, and zero leaked keys/queues.

7. VECTORIZE A/B (docs/vectorized_execution.md): both SQL taxi queries
   run with the vectorized columnar engine vs ``FlintConfig.vectorize=
   False`` (per-row closures), optimized plans, best-of-N wall time.
   Hard gates: bit-identical results, the vectorized path STRICTLY
   faster on wall-clock AND rows-per-second for both queries, zero
   leaks — the benchmark tells a speed story, not just a bytes story.

8. CHAOS A/B (docs/fault_tolerance.md): the groupBy on BOTH serverless
   transports under a composite fault schedule — 5 % transient service
   errors on every S3/SQS call, one invocation timeout that lands a
   partial flush, and one lost durable exchange object. Hard gates:
   results identical to the fault-free reference on both transports,
   zero leaked keys/queues, and chaos-run cost within 2x of fault-free
   (failed 5xx attempts bill nothing; recovery re-bills only work that
   actually ran).

9. MULTI-TENANT SERVICE A/B (docs/multi_tenant.md): 4 tenants x 2 taxi
   queries through one FlintService on both transports vs serial
   single-tenant runs. Hard gates: identical results, duplicate
   concurrent submissions share one producer stage (strictly fewer
   invocations than 2x serial), the byte-capped shared cache evicts and
   ends under its cap, a seeded chaos leg (FLINT_CHAOS_SEED) reproduces
   fault-free answers with per-tenant retry budgets isolated, and zero
   leaked keys after every session closes.

10. ADAPTIVE EXECUTION A/B (docs/adaptive_execution.md): a skewed
    taxi join whose build side aggregates to a handful of keys, run
    with runtime replanning on vs ``FlintConfig.adaptive=False``, plus
    a groupBy+orderBy query. Hard gates: bit-identical results, the
    adaptive join converts to a broadcast hash join with STRICTLY
    fewer shuffled bytes and fewer Lambda invocations, the orderBy
    executes as a distributed range-partitioned sort (no driver ops,
    >1 sort task), and zero leaks. Emits ``BENCH_9.json``.

11. STREAMING A/B (docs/streaming.md): a windowed per-payment-type tip
    aggregation streamed micro-batch-by-micro-batch from a tailed
    object prefix — with a driver kill/resume from checkpoint in the
    middle and one deliberately bursty window — vs the equivalent batch
    query over the full data. Hard gates: the finalized streamed
    windows EXACTLY match the batch query, the per-window cost model
    picks BOTH transports (SQS on quiet windows, S3 on the burst), and
    zero leaked keys/queues/checkpoints/staged batches. Emits
    ``BENCH_10.json``.

``--quick`` runs a reduced-size pass of (1), (2), (5), (6), (7), (8),
(9), (10) and (11) with hard assertions — the CI smoke gate for
transport regressions.
"""

from __future__ import annotations

import json
import os
import sys
import time

from repro.core import FaultPlan, FlintConfig, FlintContext
from repro.data.synthetic import taxi_csv
from repro.sql import Schema, col, count_, lit, sum_
from repro.streaming import S3PrefixTailer, read_stream

SQS_OP_LATENCY = 0.010
S3_PUT_LATENCY = 0.030
S3_GET_LATENCY = 0.020
S3_LIST_LATENCY = 0.050

N_ROWS = int(os.environ.get("TAXI_ROWS", "40000"))

TRANSIENT_PREFIXES = ("_spill/", "_payload/", "_exchange/", "_result/",
                      "_broadcast/", "_stream/")


def groupby_query(ctx):
    # high-cardinality groupBy: every (month, hour, payment) cell
    return (ctx.textFile("taxi.csv", 8)
            .map(lambda x: x.split(","))
            .map(lambda x: ((x[0][5:7], x[0][11:13], x[5]), 1))
            .reduceByKey(lambda a, b: a + b, 16)
            .collect())


def join_query(ctx):
    # per-hour trip counts joined with per-hour tips (integer cents: float
    # sums are arrival-order-sensitive and would break cross-transport
    # result identity in the last bits)
    def trips():
        return ctx.textFile("taxi.csv", 8).map(lambda x: x.split(","))

    counts = (trips().map(lambda x: (x[0][11:13], 1))
              .reduceByKey(lambda a, b: a + b, 8))
    tips = (trips().map(lambda x: (x[0][11:13],
                                   int(round(float(x[6]) * 100))))
            .reduceByKey(lambda a, b: a + b, 8))
    return counts.join(tips, 8).collect()


def selfjoin_query(ctx):
    # per-hour trip counts joined with THEMSELVES: without CSE the whole
    # source scan + aggregation lineage is planned and executed twice
    agg = (ctx.textFile("taxi.csv", 8).map(lambda x: x.split(","))
           .map(lambda x: (x[0][11:13], 1))
           .reduceByKey(lambda a, b: a + b, 8))
    return agg.join(agg, 8).collect()


def diamond_query(ctx, cache=False):
    # one source aggregation feeding TWO wide consumers (integer cents:
    # float sums are arrival-order-sensitive)
    agg = (ctx.textFile("taxi.csv", 8).map(lambda x: x.split(","))
           .map(lambda x: (x[0][11:13], int(round(float(x[6]) * 100))))
           .reduceByKey(lambda a, b: a + b, 8))
    if cache:
        agg = agg.cache()
    c1 = (agg.map(lambda kv: (int(kv[0]) % 4, kv[1]))
          .reduceByKey(lambda a, b: a + b, 4))
    c2 = (agg.map(lambda kv: ("all", kv[1]))
          .reduceByKey(lambda a, b: a + b, 2))
    return c1.union(c2).collect()


WORKLOADS = {"groupby": groupby_query, "join": join_query}

FANOUT_WORKLOADS = {"selfjoin": selfjoin_query, "diamond": diamond_query}

# ------------------------------------------------ SQL (DataFrame) surface

TAXI_SCHEMA = Schema([
    ("pickup", "str"), ("dropoff", "str"), ("dropoff_lon", "float"),
    ("dropoff_lat", "float"), ("trip_miles", "float"),
    ("payment_type", "str"), ("tip", "float"), ("total", "float"),
    ("precip", "float"), ("color", "str"),
])


def sql_filter_groupby_query(ctx, optimize=True):
    """Per-hour credit-card tip totals: filter + computed columns +
    groupBy/agg. Optimized: predicate pushdown, projection pruning into
    the scan (3 of 10 columns parsed), map-side combine."""
    df = ctx.read_csv("taxi.csv", TAXI_SCHEMA, 8)
    q = (df.where(col("payment_type") == lit("credit"))
           .withColumn("hour", col("pickup").substr(12, 2))
           .withColumn("tip_cents", (col("tip") * lit(100.0)).cast("int"))
           .groupBy("hour")
           .agg(sum_(col("tip_cents")).alias("tips"),
                count_().alias("n")))
    return q.collect(optimize=optimize)


def sql_join_agg_query(ctx, optimize=True):
    """Per-hour trip counts joined with per-hour credit tips: two
    aggregations + a join (three shuffles). Integer cents keep float
    sums arrival-order-independent."""
    df = ctx.read_csv("taxi.csv", TAXI_SCHEMA, 8)
    hour = col("pickup").substr(12, 2)
    trips = (df.withColumn("hour", hour)
               .groupBy("hour").agg(count_().alias("trips")))
    tips = (df.where(col("payment_type") == lit("credit"))
              .withColumn("hour", hour)
              .withColumn("tip_cents",
                          (col("tip") * lit(100.0)).cast("int"))
              .groupBy("hour").agg(sum_(col("tip_cents")).alias("tips")))
    return trips.join(tips, on="hour").collect(optimize=optimize)


SQL_WORKLOADS = {"sql_filter_groupby": sql_filter_groupby_query,
                 "sql_join_agg": sql_join_agg_query}


def assert_no_leaks(ctx):
    leaked = [k for prefix in TRANSIENT_PREFIXES
              for k in ctx.store.list(prefix)]
    assert not leaked, f"transient keys leaked past query completion: " \
                       f"{leaked[:5]}{'...' if len(leaked) > 5 else ''}"
    assert ctx.last_scheduler.sqs._queues == {}, "queues leaked"


def modeled_service_latency(rep: dict, backend: str) -> float:
    if backend == "sqs":
        return rep["sqs_requests"] * SQS_OP_LATENCY
    return (rep["s3_puts"] * S3_PUT_LATENCY
            + rep["s3_gets"] * S3_GET_LATENCY
            + rep["s3_lists"] * S3_LIST_LATENCY)


def run_transport_ab(rows=None, workloads=("groupby", "join")):
    """SQS vs S3-exchange vs cluster on each workload. Returns (rows,
    all-transports-agree)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    agreement = True
    for workload in workloads:
        query = WORKLOADS[workload]
        answers = []
        for backend in ("sqs", "s3", "cluster"):
            serverless = backend != "cluster"
            ctx = FlintContext(
                "flint" if serverless else "cluster",
                FlintConfig(concurrency=16, flush_records=2000,
                            shuffle_backend=backend if serverless
                            else "sqs"))
            ctx.upload("taxi.csv", data)
            uploaded_bytes = ctx.ledger.bytes_to_s3  # exclude the input
            t0 = time.monotonic()
            ans = query(ctx)
            wall = time.monotonic() - t0
            rep = ctx.cost_report()
            row = {
                "workload": workload, "backend": backend,
                "wall_s": round(wall, 4),
                "total_usd": round(rep["total_usd"], 6),
                "subtotals": ctx.ledger.service_subtotals(),
            }
            if serverless:
                row["modeled_service_s"] = round(
                    modeled_service_latency(rep, backend), 3)
                row["shuffle_requests"] = (
                    rep["sqs_requests"] if backend == "sqs"
                    else rep["s3_gets"] + rep["s3_puts"] + rep["s3_lists"])
                row["shuffled_bytes"] = (
                    rep["bytes_to_sqs"] if backend == "sqs"
                    else rep["bytes_to_s3"] - uploaded_bytes)
                assert_no_leaks(ctx)
                row["gc"] = dict(ctx.last_scheduler.gc_report)
            answers.append(sorted(ans))
            out.append(row)
        agreement = agreement and answers[0] == answers[1] == answers[2]
    return out, agreement


def run_columnar_ab(rows=None):
    """Columnar vs per-record-pickle framing on the homogeneous-key
    groupBy. Returns (rows, identical-results, bytes ratio)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    answers = []
    for columnar in (False, True):
        ctx = FlintContext("flint",
                           FlintConfig(concurrency=16, flush_records=2000,
                                       shuffle_backend="sqs",
                                       columnar_batches=columnar))
        ctx.upload("taxi.csv", data)
        t0 = time.monotonic()
        ans = groupby_query(ctx)
        wall = time.monotonic() - t0
        rep = ctx.cost_report()
        out.append({
            "framing": "columnar" if columnar else "pickle",
            "wall_s": round(wall, 4),
            "bytes_to_sqs": rep["bytes_to_sqs"],
            "sqs_requests": rep["sqs_requests"],
            "shuffle_cost_usd": round(rep["sqs_usd"], 6),
        })
        answers.append(sorted(ans))
        assert_no_leaks(ctx)
    ratio = out[1]["bytes_to_sqs"] / max(out[0]["bytes_to_sqs"], 1)
    return out, answers[0] == answers[1], round(ratio, 3)


def run_pipeline_ab(rows=None, trials=2):
    """Barrier vs pipelined stage execution, same query + transport.
    Best-of-``trials`` wall time per mode (latency benchmark: the minimum
    is the least noise-contaminated sample). Returns (per-mode rows,
    results-identical, speedup)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    answers = []
    for pipelined in (False, True):
        wall = float("inf")
        for _ in range(trials):
            ctx = FlintContext("flint",
                               FlintConfig(concurrency=16,
                                           flush_records=2000,
                                           shuffle_backend="sqs",
                                           start_latency_scale=1.0,
                                           pipeline_stages=pipelined))
            ctx.upload("taxi.csv", data)
            t0 = time.monotonic()
            ans = groupby_query(ctx)
            wall = min(wall, time.monotonic() - t0)
        rep = ctx.cost_report()
        out.append({
            "mode": "pipelined" if pipelined else "barrier",
            "wall_s": round(wall, 4),
            "sqs_requests": rep["sqs_requests"],
            "lambda_requests": rep["lambda_requests"],
            "total_usd": round(rep["total_usd"], 6),
        })
        answers.append(sorted(ans))
    speedup = out[0]["wall_s"] / max(out[1]["wall_s"], 1e-9)
    return out, answers[0] == answers[1], round(speedup, 2)


def run_fault_ab(rows=None):
    """Consumer fault injection: reduce-stage task 0 dies after 5 records,
    task 1 straggles 0.6 s (speculation candidate), SQS duplicates 5 % of
    deliveries. Returns (per-run rows, all-runs-identical)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    faults = {(1, 0): {"fail_after_records": 5},
              (1, 1): {"straggle_s": 0.6}}
    out = []
    identical = True
    for pipelined in (False, True):
        answers = []
        for fault_plan in ({}, faults):
            ctx = FlintContext(
                "flint",
                FlintConfig(concurrency=16, flush_records=2000,
                            shuffle_backend="sqs",
                            pipeline_stages=pipelined,
                            duplicate_prob=0.05,
                            visibility_timeout_s=1.0,
                            drain_timeout_s=10.0,
                            speculation_factor=2.0,
                            speculation_min_done=2),
                fault_plan=fault_plan, elastic_retries=0)
            ctx.upload("taxi.csv", data)
            t0 = time.monotonic()
            ans = groupby_query(ctx)
            wall = time.monotonic() - t0
            answers.append(sorted(ans))
            stats = ctx.last_scheduler.stage_stats
            out.append({
                "mode": "pipelined" if pipelined else "barrier",
                "faults": "injected" if fault_plan else "none",
                "wall_s": round(wall, 4),
                "attempts": sum(s["attempts"] for s in stats),
                "speculated": sum(s["speculated"] for s in stats),
                "redeliveries": ctx.last_scheduler.sqs.redeliveries,
            })
        identical = identical and answers[0] == answers[1]
    return out, identical


def run_fanout_ab(rows=None):
    """Self-join + diamond under plan-time CSE on/off, on both serverless
    transports (docs/dag_fanout.md). Hard gates: identical results across
    every (transport, cse) cell, a REDUCED task count with CSE (the shared
    producer stage executes exactly once), and zero leaked keys/queues.
    Returns (rows, all-cells-agree)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    agreement = True
    for workload, query in FANOUT_WORKLOADS.items():
        answers = []
        tasks_by_cell = {}
        for backend in ("sqs", "s3"):
            for cse in (False, True):
                ctx = FlintContext(
                    "flint",
                    FlintConfig(concurrency=16, flush_records=2000,
                                shuffle_backend=backend, plan_cse=cse))
                ctx.upload("taxi.csv", data)
                t0 = time.monotonic()
                ans = query(ctx)
                wall = time.monotonic() - t0
                stats = ctx.last_scheduler.stage_stats
                tasks = sum(s["tasks"] for s in stats)
                tasks_by_cell[(backend, cse)] = tasks
                rep = ctx.cost_report()
                assert_no_leaks(ctx)
                out.append({
                    "workload": workload, "backend": backend,
                    "cse": cse, "wall_s": round(wall, 4),
                    "tasks": tasks, "stages": len(stats),
                    "lambda_requests": rep["lambda_requests"],
                    "total_usd": round(rep["total_usd"], 6),
                    "subtotals": ctx.ledger.service_subtotals(),
                    "gc": dict(ctx.last_scheduler.gc_report),
                })
                answers.append(sorted(ans, key=repr))
        agreement = agreement and all(a == answers[0] for a in answers)
        for backend in ("sqs", "s3"):
            assert tasks_by_cell[(backend, True)] \
                < tasks_by_cell[(backend, False)], \
                f"{workload}/{backend}: CSE did not reduce task count " \
                f"({tasks_by_cell[(backend, True)]} vs " \
                f"{tasks_by_cell[(backend, False)]})"
    return out, agreement


def run_cache_ab(rows=None):
    """RDD.cache() on the diamond's shared aggregation: the second action
    must replan from the materialization (fewer invocations), return
    identical results, and leave zero cache keys after clear_cache()."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    ctx = FlintContext("flint", FlintConfig(concurrency=16,
                                            flush_records=2000))
    ctx.upload("taxi.csv", data)
    t0 = time.monotonic()
    first = sorted(diamond_query(ctx, cache=True), key=repr)
    first_wall = time.monotonic() - t0
    first_invokes = ctx.ledger.lambda_requests
    t0 = time.monotonic()
    second = sorted(diamond_query(ctx, cache=True), key=repr)
    second_wall = time.monotonic() - t0
    second_invokes = ctx.ledger.lambda_requests - first_invokes
    assert first == second, "cache hit changed query results"
    assert second_invokes < first_invokes, \
        f"cache did not cut invocations ({second_invokes} vs {first_invokes})"
    assert_no_leaks(ctx)
    ctx.clear_cache()
    assert not ctx.store.list("_cache/"), "cache keys leaked past clear"
    return [
        {"action": "first", "wall_s": round(first_wall, 4),
         "lambda_requests": first_invokes},
        {"action": "second", "wall_s": round(second_wall, 4),
         "lambda_requests": second_invokes},
    ]


def run_sql_ab(rows=None):
    """DataFrame queries, optimized vs naive lowering, on both serverless
    transports. Hard gates: identical results across every cell, a STRICT
    shuffled-bytes reduction from the optimizer on both queries and both
    backends, and zero leaks. Returns (rows, all-cells-agree)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    agreement = True
    for workload, query in SQL_WORKLOADS.items():
        answers = []
        shuffled_by_cell = {}
        for backend in ("sqs", "s3"):
            for optimized in (False, True):
                ctx = FlintContext(
                    "flint",
                    FlintConfig(concurrency=16, flush_records=2000,
                                shuffle_backend=backend))
                ctx.upload("taxi.csv", data)
                uploaded = ctx.ledger.bytes_to_s3
                t0 = time.monotonic()
                ans = query(ctx, optimize=optimized)
                wall = time.monotonic() - t0
                rep = ctx.cost_report()
                shuffled = (rep["bytes_to_sqs"] if backend == "sqs"
                            else rep["bytes_to_s3"] - uploaded)
                shuffled_by_cell[(backend, optimized)] = shuffled
                assert_no_leaks(ctx)
                out.append({
                    "workload": workload, "backend": backend,
                    "optimized": optimized, "wall_s": round(wall, 4),
                    "shuffled_bytes": shuffled,
                    "lambda_requests": rep["lambda_requests"],
                    "total_usd": round(rep["total_usd"], 6),
                })
                answers.append(sorted(ans))
        agreement = agreement and all(a == answers[0] for a in answers)
        for backend in ("sqs", "s3"):
            opt = shuffled_by_cell[(backend, True)]
            raw = shuffled_by_cell[(backend, False)]
            assert opt < raw, \
                f"{workload}/{backend}: optimizer did not shrink " \
                f"shuffled bytes ({opt} vs {raw})"
    return out, agreement


def run_vectorize_ab(rows=None, trials=3):
    """Vectorized columnar engine vs per-row closures on both SQL taxi
    queries (optimized plans, SQS transport). Best-of-``trials`` wall
    per mode — the minimum is the least noise-contaminated sample. Hard
    gates: bit-identical results, vectorized STRICTLY faster on
    wall-clock and rows-per-second for both queries, zero leaks.
    Returns (rows, all-pairs-identical)."""
    n = rows or N_ROWS
    data = taxi_csv(n, seed=13)
    out = []
    identical = True
    for workload, query in SQL_WORKLOADS.items():
        answers = {}
        wall_by_mode = {}
        for vectorize in (False, True):
            wall = float("inf")
            for _ in range(trials):
                ctx = FlintContext(
                    "flint",
                    FlintConfig(concurrency=16, flush_records=2000,
                                shuffle_backend="sqs",
                                vectorize=vectorize))
                ctx.upload("taxi.csv", data)
                t0 = time.monotonic()
                ans = query(ctx, optimize=True)
                wall = min(wall, time.monotonic() - t0)
                assert_no_leaks(ctx)
            answers[vectorize] = sorted(ans)
            wall_by_mode[vectorize] = wall
            out.append({
                "workload": workload,
                "mode": "vectorized" if vectorize else "row",
                "wall_s": round(wall, 4),
                "rows_per_s": int(n / max(wall, 1e-9)),
            })
        identical = identical and answers[True] == answers[False]
        vec, row = wall_by_mode[True], wall_by_mode[False]
        assert vec < row, \
            f"{workload}: vectorized not faster ({vec:.4f}s vs {row:.4f}s)"
        assert n / vec > n / row, \
            f"{workload}: vectorized rows/s did not win"
    return out, identical


def run_chaos_ab(rows=None):
    """Fault-free reference vs composite chaos schedule (5 % transient
    errors + one invocation timeout + one lost exchange object) on both
    serverless transports. Hard gates: identical results, zero leaks,
    chaos cost <= 2x fault-free. Returns (per-run rows, identical)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    chaos = FaultPlan(seed=1337,
                      s3_error_prob=0.05, sqs_error_prob=0.05,
                      tasks={(0, 0): {"timeout_after_records": 300}},
                      lose_keys=("_exchange/",))
    out = []
    identical = True
    for backend in ("sqs", "s3"):
        answers = []
        costs = {}
        for plan in (None, chaos):
            label = "chaos" if plan is not None else "none"
            ctx = FlintContext(
                "flint",
                FlintConfig(concurrency=16, flush_records=2000,
                            shuffle_backend=backend,
                            visibility_timeout_s=1.0,
                            drain_timeout_s=2.0,
                            max_stage_retries=5),
                fault_plan=plan, elastic_retries=0)
            ctx.upload("taxi.csv", data)
            t0 = time.monotonic()
            ans = groupby_query(ctx)
            wall = time.monotonic() - t0
            rep = ctx.cost_report()
            costs[label] = rep["total_usd"]
            assert_no_leaks(ctx)
            sched = ctx.last_scheduler
            out.append({
                "backend": backend, "faults": label,
                "wall_s": round(wall, 4),
                "total_usd": round(rep["total_usd"], 6),
                "service_faults": rep["service_faults"],
                "injector": dict(sched.faults.stats),
                "recovery": dict(sched.recovery_stats),
            })
            answers.append(sorted(ans))
        identical = identical and answers[0] == answers[1]
        assert costs["chaos"] <= 2 * costs["none"], \
            f"{backend}: chaos run cost {costs['chaos']} exceeds 2x " \
            f"fault-free {costs['none']}"
    return out, identical


def run_service_ab(rows=None):
    """Multi-tenant service A/B (docs/multi_tenant.md). Hard gates:

    * 4 tenants x 2 taxi queries over one shared slot pool return
      results identical to serial single-tenant runs, on BOTH
      transports, with zero transient keys left after close;
    * duplicate concurrent submissions of the same query (s3) share one
      producer stage — strictly fewer lambda invocations than 2x the
      serial single-run count;
    * a byte-capped shared cache sees evictions and ends under its cap;
    * a seeded chaos leg (FLINT_CHAOS_SEED) reproduces the fault-free
      answers with per-tenant retry budgets spent only by the tenants
      that retried.

    Returns (summary rows, all_gates_passed)."""
    import threading

    from repro.svc import FlintService

    n = rows or N_ROWS
    data = taxi_csv(n, seed=17)
    out = []
    ok = True

    def svc_cfg(backend, **kw):
        kw = {"concurrency": 8, "visibility_timeout_s": 1.0,
              "drain_timeout_s": 4.0, "flush_records": 2000, **kw}
        return FlintConfig(shuffle_backend=backend, **kw)

    def serial_answers(backend):
        ctx = FlintContext("flint", svc_cfg(backend))
        ctx.upload("taxi.csv", data)
        return ({"groupby": sorted(groupby_query(ctx)),
                 "join": sorted(join_query(ctx))}, ctx.cost_report())

    # ---- leg 1: 4 tenants x 2 queries, both transports, serial-equal
    for backend in ("sqs", "s3"):
        expected, _ = serial_answers(backend)
        svc = FlintService(svc_cfg(backend), slot_capacity=16)
        for t, w in (("t0", 2), ("t1", 1), ("t2", 1), ("t3", 1)):
            svc.register_tenant(t, weight=w)
        svc.upload("taxi.csv", data)
        results, errors = {}, []

        def run_tenant(name):
            try:
                with svc.session(name) as s:
                    results[name] = {"groupby": sorted(groupby_query(s)),
                                     "join": sorted(join_query(s))}
            except Exception as e:
                errors.append((name, repr(e)))

        t0 = time.monotonic()
        threads = [threading.Thread(target=run_tenant, args=(t,))
                   for t in ("t0", "t1", "t2", "t3")]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0
        rep = svc.report()
        svc.close()
        leaks = sum(svc.leak_report().values())
        equal = (not errors
                 and all(results[t] == expected for t in results)
                 and len(results) == 4)
        ok = ok and equal and leaks == 0
        out.append({"leg": "tenants4x2", "backend": backend,
                    "wall_s": round(wall, 4), "serial_equal": equal,
                    "leaked_keys": leaks,
                    "pool_peak": rep["pool"]["peak_held"],
                    "share_hits": rep["share"]["hits"],
                    "account_usd": rep["account"]["total_usd"]})
        assert equal, f"service {backend}: tenant results != serial " \
                      f"({errors or 'result mismatch'})"
        assert leaks == 0, f"service {backend}: {leaks} leaked keys"

    # ---- leg 2: duplicate submissions share one producer stage (s3)
    _, serial_rep = serial_answers("s3")
    svc = FlintService(svc_cfg("s3"), slot_capacity=16)
    svc.upload("taxi.csv", data)

    def slow_parts(it):
        time.sleep(0.2)  # keep the producer stage alive for the joiner
        return it

    def dup_query(sess):
        return sorted(sess.textFile("taxi.csv", 8)
                      .mapPartitions(slow_parts)
                      .map(lambda x: x.split(","))
                      .map(lambda x: ((x[0][11:13], x[5]), 1))
                      .reduceByKey(lambda a, b: a + b, 8)
                      .collect())

    dup_out = {}

    def run_first():
        with svc.session("first") as s:
            dup_out["first"] = dup_query(s)

    ta = threading.Thread(target=run_first)
    ta.start()
    deadline = time.monotonic() + 10.0
    while (svc.share.stats["published"] == 0
           and time.monotonic() < deadline):
        time.sleep(0.005)
    with svc.session("second") as s:
        dup_out["second"] = dup_query(s)
    ta.join()
    rep = svc.report()
    dup_requests = rep["account"]["lambda_requests"]
    svc.close()
    # 8 producer + 8 consumer tasks serial: two shared runs must invoke
    # strictly fewer than two private ones
    dup_serial = 2 * (8 + 8)
    dedup_ok = (dup_out["first"] == dup_out["second"]
                and rep["share"]["hits"] >= 1
                and dup_requests < dup_serial
                and sum(svc.leak_report().values()) == 0)
    ok = ok and dedup_ok
    out.append({"leg": "dup-query", "backend": "s3",
                "lambda_requests": dup_requests,
                "serial_2x": dup_serial,
                "share_hits": rep["share"]["hits"],
                "serial_equal": dup_out["first"] == dup_out["second"]})
    assert dedup_ok, \
        f"duplicate submissions did not share: {dup_requests} invocations" \
        f" vs 2x serial {dup_serial}, hits={rep['share']['hits']}"

    # ---- leg 3: byte-capped shared cache evicts and stays under cap
    svc = FlintService(svc_cfg("s3"), slot_capacity=8, cache_bytes=4096)
    svc.upload("taxi.csv", data)
    with svc.session("cachey") as s:
        hours = (s.textFile("taxi.csv", 4)
                 .map(lambda x: (x.split(",")[0][11:13], 1)).cache())
        r1 = sorted(hours.reduceByKey(lambda a, b: a + b, 4).collect())
        months = (s.textFile("taxi.csv", 4)
                  .map(lambda x: (x.split(",")[0][5:7], 1)).cache())
        sorted(months.reduceByKey(lambda a, b: a + b, 4).collect())
        r2 = sorted(hours.reduceByKey(lambda a, b: a + b, 4).collect())
    cache_ok = (r1 == r2 and svc.cache.stats["evictions"] >= 1
                and svc.cache.total_bytes() <= 4096)
    ok = ok and cache_ok
    out.append({"leg": "cache-cap", "backend": "s3",
                "evictions": svc.cache.stats["evictions"],
                "cache_bytes": svc.cache.total_bytes(), "cap": 4096,
                "serial_equal": r1 == r2})
    svc.close()
    assert cache_ok, \
        f"cache cap not enforced: {svc.cache.stats} " \
        f"bytes={svc.cache.total_bytes()}"

    # ---- leg 4: seeded account-wide chaos, per-tenant retry budgets
    seed = int(os.environ.get("FLINT_CHAOS_SEED", "1337"))
    expected, _ = serial_answers("s3")
    plan = FaultPlan(seed=seed, s3_error_prob=0.02, sqs_error_prob=0.02,
                     invoke_throttle_prob=0.02, lose_object_prob=0.005,
                     account_concurrency=12)
    svc = FlintService(svc_cfg("s3", max_stage_retries=5,
                               retry_base_s=0.001, retry_cap_s=0.01),
                       fault_plan=plan, slot_capacity=12)
    svc.register_tenant("ca", retry_budget=2000)
    svc.register_tenant("cb", retry_budget=2000)
    svc.register_tenant("idle", retry_budget=2000)
    svc.upload("taxi.csv", data)
    chaos_results, chaos_errors = {}, []

    def run_chaos_tenant(name):
        try:
            with svc.session(name) as s:
                chaos_results[name] = {"groupby": sorted(groupby_query(s)),
                                       "join": sorted(join_query(s))}
        except Exception as e:
            chaos_errors.append((name, repr(e)))

    threads = [threading.Thread(target=run_chaos_tenant, args=(t,))
               for t in ("ca", "cb")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spent = {t: svc._tenants[t].retry_budget.spent
             for t in ("ca", "cb", "idle")}
    svc.close()
    chaos_ok = (not chaos_errors
                and all(chaos_results[t] == expected for t in ("ca", "cb"))
                and spent["idle"] == 0
                and sum(svc.leak_report().values()) == 0)
    ok = ok and chaos_ok
    out.append({"leg": "chaos", "backend": "s3", "seed": seed,
                "serial_equal": not chaos_errors and all(
                    chaos_results.get(t) == expected for t in ("ca", "cb")),
                "retry_spent": spent, "gauge_peak": svc.gauge.peak,
                "leaked_keys": sum(svc.leak_report().values())})
    assert chaos_ok, \
        f"chaos service leg failed: errors={chaos_errors} spent={spent}"
    return out, ok


def _print_transport_rows(rows, agreement):
    print("workload,backend,wall_s,modeled_service_s,total_usd,"
          "shuffle_requests,shuffled_bytes")
    for r in rows:
        print(f"{r['workload']},{r['backend']},{r['wall_s']},"
              f"{r.get('modeled_service_s', '-')},{r['total_usd']},"
              f"{r.get('shuffle_requests', '-')},"
              f"{r.get('shuffled_bytes', '-')}")
    print("# Table-I-style cost breakdown (USD per service operation):")
    print("workload,backend," + ",".join(rows[0]["subtotals"]))
    for r in rows:
        print(f"{r['workload']},{r['backend']}," +
              ",".join(str(v) for v in r["subtotals"].values()))
    print(f"# transports agree: {agreement}")


def adaptive_join_query(ctx):
    """Skewed build side: the per-hour tip aggregate (24 keys, a few
    hundred bytes) joined against every raw trip row (the probe side,
    ~the whole file). A static plan shuffles BOTH sides into join
    partitions; adaptive measures the aggregate's output as it
    completes, converts to a broadcast hash join, and the probe rows
    never cross the wire at all — the join runs inside the probe side's
    map stage."""
    def trips():
        return ctx.textFile("taxi.csv", 8).map(lambda x: x.split(","))

    tips = (trips().map(lambda x: (x[0][11:13],
                                   int(round(float(x[6]) * 100))))
            .reduceByKey(lambda a, b: a + b, 8))
    probe = trips().map(lambda x: (x[0][11:13], ",".join(x)))
    return probe.join(tips, 8).collect()


def adaptive_sort_query(ctx):
    """groupBy + total-order orderBy (unique (tips, hour) tie-break so
    the full row order is deterministic across strategies)."""
    df = ctx.read_csv("taxi.csv", TAXI_SCHEMA, 8)
    q = (df.withColumn("hour", col("pickup").substr(12, 2))
           .withColumn("tip_cents", (col("tip") * lit(100.0)).cast("int"))
           .groupBy("hour")
           .agg(sum_(col("tip_cents")).alias("tips"),
                count_().alias("n"))
           .orderBy("tips", "hour", ascending=[False, True]))
    return q.collect()


def run_adaptive_ab(rows=None):
    """Adaptive execution A/B (docs/adaptive_execution.md). Hard gates:
    identical results per workload, the adaptive join leg converts to a
    broadcast join with strictly fewer shuffled bytes AND fewer Lambda
    invocations, the orderBy leg runs as a distributed range sort, and
    zero leaks everywhere. Returns (rows, all-gates-ok)."""
    data = taxi_csv(rows or N_ROWS, seed=13)
    out = []
    cells: dict = {}
    for workload, query in (("broadcast_join", adaptive_join_query),
                            ("orderby", adaptive_sort_query)):
        for adaptive in (True, False):
            ctx = FlintContext(
                "flint",
                FlintConfig(concurrency=16, flush_records=2000,
                            adaptive=adaptive))
            ctx.upload("taxi.csv", data)
            uploaded = ctx.ledger.bytes_to_s3
            t0 = time.monotonic()
            ans = query(ctx)
            wall = time.monotonic() - t0
            rep = ctx.cost_report()
            sched = ctx.last_scheduler
            shuffled = (rep["bytes_to_sqs"]
                        + rep["bytes_to_s3"] - uploaded)
            assert_no_leaks(ctx)
            cell = {
                "workload": workload, "adaptive": adaptive,
                "wall_s": round(wall, 4), "shuffled_bytes": shuffled,
                "lambda_requests": rep["lambda_requests"],
                "total_usd": round(rep["total_usd"], 6),
                "adaptive_stats": dict(sched.adaptive_stats),
                "sort_tasks": sched.stage_stats[-1]["tasks"],
            }
            out.append(cell)
            # the join's row ORDER is partitioning-dependent (canon by
            # sort); the orderBy leg is compared EXACTLY — the total
            # order is the result
            if workload == "broadcast_join":
                ans = sorted(ans)
            cells[(workload, adaptive)] = (ans, cell)

    for workload in ("broadcast_join", "orderby"):
        on_ans, on = cells[(workload, True)]
        off_ans, off = cells[(workload, False)]
        assert on_ans == off_ans, \
            f"{workload}: adaptive changed query results"
    on = cells[("broadcast_join", True)][1]
    off = cells[("broadcast_join", False)][1]
    assert on["adaptive_stats"]["broadcast_joins"] >= 1, \
        "join did not convert to a broadcast join"
    assert on["shuffled_bytes"] < off["shuffled_bytes"], \
        f"broadcast join did not shrink shuffled bytes " \
        f"({on['shuffled_bytes']} vs {off['shuffled_bytes']})"
    assert on["lambda_requests"] < off["lambda_requests"], \
        f"broadcast join did not cut invocations " \
        f"({on['lambda_requests']} vs {off['lambda_requests']})"
    sort_on = cells[("orderby", True)][1]
    assert sort_on["sort_tasks"] > 1, \
        "adaptive orderBy did not run as a distributed sort"
    return out, True


#: the burst window's row count — sized so its observed volume crosses
#: the SQS->S3 crossover of core.costs.pick_shuffle_transport at the
#: streaming query's 2 shuffle partitions (~4 MB effective)
STREAM_BURST_ROWS = 150_000


def _stream_query(ctx, src, name):
    return (read_stream(ctx, src)
            .withColumn("ts", col("pickup").substr(12, 2).cast("int"))
            .withColumn("tip_cents",
                        (col("tip") * lit(100.0)).cast("int"))
            .window("ts", 4)
            .groupBy("payment_type")
            .agg(sum_(col("tip_cents")).alias("tips"),
                 count_().alias("n"), numPartitions=2)
            # hours arrive in random order within every tailed object, so
            # windows may only finalize at drain: lateness spans the day
            .start(name, batch_size=1, allowed_lateness=24))


def run_streaming_ab(rows=None):
    """Streaming vs batch A/B (docs/streaming.md). The streamed taxi
    windowed groupBy — killed after two micro-batches and resumed from
    its ``_stream/`` checkpoint — must produce finalized windows
    IDENTICAL to the equivalent batch query over the full prefix, the
    per-window cost model must pick SQS on the quiet windows and S3 on
    the burst, and nothing may leak. Returns (rows, all-gates-ok)."""
    n = rows or N_ROWS
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=8, flush_records=2000))
    # 5 quiet objects + one burst object, tailed in upload order
    chunks = [taxi_csv(max(200, n // 8), seed=100 + i) for i in range(3)]
    chunks.append(taxi_csv(STREAM_BURST_ROWS, seed=777))
    chunks += [taxi_csv(max(200, n // 8), seed=200 + i) for i in range(2)]
    for i, data in enumerate(chunks):
        ctx.store.put(f"taxi_stream/{i:04d}.csv", data)
    ctx.upload("taxi.csv", b"".join(chunks))

    src = S3PrefixTailer(ctx.store, "taxi_stream/", TAXI_SCHEMA)
    src.seal()
    t0 = time.monotonic()
    q1 = _stream_query(ctx, src, "bench-stream")
    q1.step()
    q1.step()
    q1.stop()  # driver killed mid-stream ...
    q2 = _stream_query(ctx, src, "bench-stream")  # ... and resumed
    resumed_at = q2.batch
    streamed = q2.run()
    stream_wall = time.monotonic() - t0
    stats = q2.stats()
    q1.cleanup()
    q2.cleanup()

    t0 = time.monotonic()
    batch = (ctx.read_csv("taxi.csv", TAXI_SCHEMA, 8)
             .withColumn("ts", col("pickup").substr(12, 2).cast("int"))
             .withColumn("tip_cents",
                         (col("tip") * lit(100.0)).cast("int"))
             .withWindow("ts", 4)
             .groupBy("window_start", "payment_type")
             .agg(sum_(col("tip_cents")).alias("tips"),
                  count_().alias("n"))
             .collect())
    batch_wall = time.monotonic() - t0
    batch_rows = sorted((ws, ws + 4, k, t, cnt)
                        for ws, k, t, cnt in batch)

    assert streamed == batch_rows, \
        "streamed finalized windows != batch query result"
    assert resumed_at == 2, \
        f"driver did not resume from the checkpoint (batch {resumed_at})"
    picked = set(stats["transports"])
    assert picked == {"sqs", "s3"}, \
        f"cost model did not exercise both transports: {stats['transports']}"
    staged = ctx.store.list("_collections/")
    assert not staged, f"staged micro-batch data leaked: {staged[:5]}"
    ctx.store.delete_prefix("taxi_stream/")
    assert_no_leaks(ctx)
    out = [{"leg": "stream", "wall_s": round(stream_wall, 4),
            "batches": stats["batches"],
            "transports": stats["transports"],
            "late_dropped": stats["late_dropped"],
            "windows": len(streamed), "resumed_at_batch": resumed_at},
           {"leg": "batch", "wall_s": round(batch_wall, 4),
            "windows": len(batch_rows)}]
    return out, True


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    rows = 4000 if quick else None

    ab, agreement = run_transport_ab(rows)
    _print_transport_rows(ab, agreement)
    col, col_identical, ratio = run_columnar_ab(rows)
    print("framing,wall_s,bytes_to_sqs,sqs_requests,shuffle_cost_usd")
    for r in col:
        print(f"{r['framing']},{r['wall_s']},{r['bytes_to_sqs']},"
              f"{r['sqs_requests']},{r['shuffle_cost_usd']}")
    print(f"# columnar/pickle shuffled-bytes ratio: {ratio}, "
          f"results identical: {col_identical}")

    fan, fan_agreement = run_fanout_ab(rows)
    print("workload,backend,cse,wall_s,tasks,stages,lambda_requests,"
          "total_usd")
    for r in fan:
        print(f"{r['workload']},{r['backend']},{r['cse']},{r['wall_s']},"
              f"{r['tasks']},{r['stages']},{r['lambda_requests']},"
              f"{r['total_usd']}")
    print(f"# fan-out cells agree: {fan_agreement}")
    cache_rows = run_cache_ab(rows)
    print("cache_action,wall_s,lambda_requests")
    for r in cache_rows:
        print(f"{r['action']},{r['wall_s']},{r['lambda_requests']}")

    sql_rows, sql_agreement = run_sql_ab(rows)
    print("workload,backend,optimized,wall_s,shuffled_bytes,"
          "lambda_requests,total_usd")
    for r in sql_rows:
        print(f"{r['workload']},{r['backend']},{r['optimized']},"
              f"{r['wall_s']},{r['shuffled_bytes']},"
              f"{r['lambda_requests']},{r['total_usd']}")
    print(f"# sql optimized/naive cells agree: {sql_agreement}")

    vec_rows, vec_identical = run_vectorize_ab(rows)
    print("workload,mode,wall_s,rows_per_s")
    for r in vec_rows:
        print(f"{r['workload']},{r['mode']},{r['wall_s']},"
              f"{r['rows_per_s']}")
    print(f"# vectorized/row results identical: {vec_identical}")

    chaos_rows, chaos_identical = run_chaos_ab(rows)
    print("backend,faults,wall_s,total_usd,service_faults,recovery")
    for r in chaos_rows:
        print(f"{r['backend']},{r['faults']},{r['wall_s']},"
              f"{r['total_usd']},{r['service_faults']},{r['recovery']}")
    print(f"# chaos runs identical to fault-free: {chaos_identical}")

    service_rows, service_ok = run_service_ab(rows)
    for r in service_rows:
        print("service," + ",".join(f"{k}={v}" for k, v in r.items()))
    print(f"# multi-tenant service gates passed: {service_ok}")

    adaptive_rows, adaptive_ok = run_adaptive_ab(rows)
    print("workload,adaptive,wall_s,shuffled_bytes,lambda_requests,"
          "total_usd,broadcast_joins")
    for r in adaptive_rows:
        print(f"{r['workload']},{r['adaptive']},{r['wall_s']},"
              f"{r['shuffled_bytes']},{r['lambda_requests']},"
              f"{r['total_usd']},"
              f"{r['adaptive_stats']['broadcast_joins']}")
    print(f"# adaptive gates passed: {adaptive_ok}")
    bench_path = os.path.join(os.path.dirname(__file__), os.pardir,
                              "BENCH_9.json")
    with open(os.path.abspath(bench_path), "w") as f:
        json.dump({"adaptive_ab": adaptive_rows}, f, indent=2)
        f.write("\n")

    stream_rows, stream_ok = run_streaming_ab(rows)
    print("leg,wall_s,windows,batches,transports")
    for r in stream_rows:
        print(f"{r['leg']},{r['wall_s']},{r['windows']},"
              f"{r.get('batches', '')},"
              f"{'|'.join(r.get('transports', []))}")
    print(f"# streaming gates passed: {stream_ok}")
    bench_path = os.path.join(os.path.dirname(__file__), os.pardir,
                              "BENCH_10.json")
    with open(os.path.abspath(bench_path), "w") as f:
        json.dump({"streaming_ab": stream_rows}, f, indent=2)
        f.write("\n")

    # hard gates — make transport regressions fail loudly (CI --quick)
    assert agreement, "transports disagree on query results"
    assert col_identical, "columnar framing changed query results"
    assert ratio < 1.0, \
        f"columnar batches did not shrink shuffled bytes (ratio {ratio})"
    assert fan_agreement, \
        "fan-out results differ across transports / CSE on-off"
    assert sql_agreement, \
        "sql results differ across transports / optimize on-off"
    assert vec_identical, \
        "vectorized execution changed SQL query results"
    assert chaos_identical, \
        "chaos runs differ from the fault-free reference"
    assert service_ok, "multi-tenant service gates failed"
    assert adaptive_ok, "adaptive execution gates failed"
    assert stream_ok, "streaming gates failed"
    if quick:
        print("# quick smoke passed")
        return ab, agreement

    pab, identical, speedup = run_pipeline_ab()
    print("mode,wall_s,sqs_requests,lambda_requests,total_usd")
    for r in pab:
        print(f"{r['mode']},{r['wall_s']},{r['sqs_requests']},"
              f"{r['lambda_requests']},{r['total_usd']}")
    print(f"# pipelined speedup: {speedup}x, results identical: {identical}")
    fault_rows, fault_identical = run_fault_ab()
    print("mode,faults,wall_s,attempts,speculated,redeliveries")
    for r in fault_rows:
        print(f"{r['mode']},{r['faults']},{r['wall_s']},{r['attempts']},"
              f"{r['speculated']},{r['redeliveries']}")
    print(f"# fault-injected runs identical to fault-free: {fault_identical}")
    return ab, agreement


if __name__ == "__main__":
    main()
