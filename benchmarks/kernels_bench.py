"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock here measures the REFERENCE implementations (the jnp oracles,
which XLA compiles natively) — a correctness-bench, plus arithmetic
intensity derived per shape so the TPU roofline slot of each kernel is
visible without hardware.

``--quick`` is the CI smoke leg: tiny shapes, every Pallas kernel run in
interpret mode and asserted against its oracle, plus the exactness
envelopes of ``ops.grouped_reduce`` — the grouped-aggregation backend of
the vectorized SQL engine (docs/vectorized_execution.md), which makes
this path load-bearing for query results, not just for model code.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def flash_rows():
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, h, kk, s, d) in [(1, 8, 2, 1024, 128), (1, 8, 8, 2048, 64)]:
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(key, (b, s, kk, d), jnp.float32)
        v = jax.random.normal(key, (b, s, kk, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        dt = _time(fn, q, k, v)
        flops = 4.0 * b * h * s * s * d  # qk + pv
        bytes_ = (q.size + k.size + v.size + q.size) * 4
        rows.append({
            "name": f"flash_ref_b{b}h{h}s{s}d{d}",
            "us_per_call": dt * 1e6,
            "derived": f"AI={flops/bytes_:.0f}flop/B "
                       f"tpu_pred={max(flops/PEAK_FLOPS, bytes_/HBM_BW)*1e6:.1f}us",
        })
    return rows


def bucket_rows():
    rows = []
    key = jax.random.PRNGKey(1)
    for (n, p, d) in [(65536, 160, 512), (16384, 16, 1024)]:
        vals = jax.random.normal(key, (n, d), jnp.float32)
        ids = jax.random.randint(key, (n,), 0, p)
        fn = jax.jit(lambda v, i: ref.bucket_reduce_ref(v, i, p))
        dt = _time(fn, vals, ids)
        flops = 2.0 * n * p * d
        rows.append({
            "name": f"bucket_reduce_ref_n{n}p{p}d{d}",
            "us_per_call": dt * 1e6,
            "derived": f"tpu_pred={flops/PEAK_FLOPS*1e6:.1f}us",
        })
    return rows


def gmm_rows():
    rows = []
    key = jax.random.PRNGKey(2)
    for (e, t, d, f) in [(8, 1024, 512, 2048), (160, 128, 512, 1536)]:
        x = jax.random.normal(key, (e, t, d), jnp.float32)
        w = jax.random.normal(key, (e, d, f), jnp.float32)
        fn = jax.jit(ref.grouped_matmul_ref)
        dt = _time(fn, x, w)
        flops = 2.0 * e * t * d * f
        rows.append({
            "name": f"gmm_ref_e{e}t{t}d{d}f{f}",
            "us_per_call": dt * 1e6,
            "derived": f"tpu_pred={flops/PEAK_FLOPS*1e6:.1f}us",
        })
    return rows


def quick_rows():
    """CI smoke: interpret-mode kernels vs their oracles on tiny shapes,
    and the grouped_reduce exactness envelopes — hard assertions, a
    correctness gate rather than a timing run."""
    import numpy as np

    from repro.kernels import ops

    rows = []
    key = jax.random.PRNGKey(3)

    def timed(name, fn):
        t0 = time.monotonic()
        fn()
        rows.append({"name": name,
                     "us_per_call": (time.monotonic() - t0) * 1e6,
                     "derived": "smoke"})

    def check_flash():
        q = jax.random.normal(key, (1, 64, 2, 16), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 16),
                              jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 2, 16),
                              jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        assert jnp.allclose(got, want, atol=1e-4), "flash kernel != oracle"

    def check_bucket():
        vals = jax.random.normal(key, (256, 8), jnp.float32)
        ids = jax.random.randint(key, (256,), 0, 16)
        got = ops.bucket_reduce(vals, ids.astype(jnp.int32), 16,
                                interpret=True)
        want = ref.bucket_reduce_ref(vals, ids, 16)
        assert jnp.allclose(got, want, atol=1e-4), \
            "bucket_reduce kernel != oracle"

    def check_gmm():
        x = jax.random.normal(key, (2, 16, 16), jnp.float32)
        w = jax.random.normal(key, (2, 16, 16), jnp.float32)
        got = ops.grouped_matmul(x, w, interpret=True)
        want = ref.grouped_matmul_ref(x, w)
        assert jnp.allclose(got, want, atol=1e-4), "gmm kernel != oracle"

    def check_grouped_reduce():
        rng = np.random.default_rng(7)
        ids = rng.integers(0, 16, size=500)

        def fold(vals):
            acc = np.zeros(16, dtype=object)
            for i, v in zip(ids, vals):
                acc[i] += int(v)
            return acc

        # envelope 1: sum(|v|) < 2**24 — the one-hot-matmul kernel
        small = rng.integers(-50, 50, size=500)
        got = ops.grouped_reduce(small, ids, 16, interpret=True)
        assert got.dtype == np.int64 and (got == fold(small)).all(), \
            "grouped_reduce kernel envelope != bigint fold"
        # envelope 2: sum(|v|) <= 2**62 — the x64 segment sum
        big = rng.integers(-2**40, 2**40, size=500)
        got = ops.grouped_reduce(big, ids, 16, interpret=True)
        assert (got == fold(big)).all(), \
            "grouped_reduce x64 envelope != bigint fold"
        # past the envelope: refuse (caller keeps its exact path)
        over = np.array([2**62, 2**62], dtype=np.int64)
        assert ops.grouped_reduce(over, np.array([0, 0]), 1,
                                  interpret=True) is None
        # empty input: zeros, no kernel launch
        empty = ops.grouped_reduce(np.array([], dtype=np.int64),
                                   np.array([], dtype=np.int64), 4,
                                   interpret=True)
        assert (empty == np.zeros(4, dtype=np.int64)).all()

    timed("flash_attention_smoke", check_flash)
    timed("bucket_reduce_smoke", check_bucket)
    timed("grouped_matmul_smoke", check_gmm)
    timed("grouped_reduce_smoke", check_grouped_reduce)
    return rows


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--quick" in argv:
        rows = quick_rows()
    else:
        rows = flash_rows() + bucket_rows() + gmm_rows()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if "--quick" in argv:
        print("# kernel smoke passed")
    return rows


if __name__ == "__main__":
    main()
