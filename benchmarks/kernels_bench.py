"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock here measures the REFERENCE implementations (the jnp oracles,
which XLA compiles natively) — a correctness-bench, plus arithmetic
intensity derived per shape so the TPU roofline slot of each kernel is
visible without hardware.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def flash_rows():
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, h, kk, s, d) in [(1, 8, 2, 1024, 128), (1, 8, 8, 2048, 64)]:
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(key, (b, s, kk, d), jnp.float32)
        v = jax.random.normal(key, (b, s, kk, d), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        dt = _time(fn, q, k, v)
        flops = 4.0 * b * h * s * s * d  # qk + pv
        bytes_ = (q.size + k.size + v.size + q.size) * 4
        rows.append({
            "name": f"flash_ref_b{b}h{h}s{s}d{d}",
            "us_per_call": dt * 1e6,
            "derived": f"AI={flops/bytes_:.0f}flop/B "
                       f"tpu_pred={max(flops/PEAK_FLOPS, bytes_/HBM_BW)*1e6:.1f}us",
        })
    return rows


def bucket_rows():
    rows = []
    key = jax.random.PRNGKey(1)
    for (n, p, d) in [(65536, 160, 512), (16384, 16, 1024)]:
        vals = jax.random.normal(key, (n, d), jnp.float32)
        ids = jax.random.randint(key, (n,), 0, p)
        fn = jax.jit(lambda v, i: ref.bucket_reduce_ref(v, i, p))
        dt = _time(fn, vals, ids)
        flops = 2.0 * n * p * d
        rows.append({
            "name": f"bucket_reduce_ref_n{n}p{p}d{d}",
            "us_per_call": dt * 1e6,
            "derived": f"tpu_pred={flops/PEAK_FLOPS*1e6:.1f}us",
        })
    return rows


def gmm_rows():
    rows = []
    key = jax.random.PRNGKey(2)
    for (e, t, d, f) in [(8, 1024, 512, 2048), (160, 128, 512, 1536)]:
        x = jax.random.normal(key, (e, t, d), jnp.float32)
        w = jax.random.normal(key, (e, d, f), jnp.float32)
        fn = jax.jit(ref.grouped_matmul_ref)
        dt = _time(fn, x, w)
        flops = 2.0 * e * t * d * f
        rows.append({
            "name": f"gmm_ref_e{e}t{t}d{d}f{f}",
            "us_per_call": dt * 1e6,
            "derived": f"tpu_pred={flops/PEAK_FLOPS*1e6:.1f}us",
        })
    return rows


def main():
    rows = flash_rows() + bucket_rows() + gmm_rows()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return rows


if __name__ == "__main__":
    main()
