"""Roofline analysis per (arch x shape) from the compiled dry-run artifacts.

Terms (per chip, per step; TPU v5e targets):
  compute    = HLO_FLOPs / 197e12          (bf16 peak / chip)
  memory     = HLO_bytes / 819e9           (HBM bandwidth / chip)
  collective = collective_bytes / 50e9     (ICI link bandwidth)

FLOPs/bytes/collective-bytes come from the loop-aware HLO cost model
(repro.launch.hlo_cost) over the compiled module of the SINGLE-POD mesh —
already per-device post-GSPMD quantities, so no further division by chips.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) / 2*N_active*B
(decode), with N_active excluding embedding tables and counting routed
experts at top_k/n_experts utilization. The ratio MODEL_FLOPS/HLO_FLOPs
shows how much compiled compute is "useful" (remat recompute, GSPMD
padding and dispatch overhead push it below 1; for train, remat of one
full forward makes ~0.75 the practical ceiling).
"""

from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.layers import embedding_schema, unembed_schema
from repro.common import param as pm

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
OUT = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def non_embedding_params(cfg: ModelConfig) -> int:
    total = lm.n_params(cfg)
    emb = pm.param_count(embedding_schema(cfg))
    if not cfg.tie_embeddings:
        emb += pm.param_count(unembed_schema(cfg))
    return total - emb


def active_params(cfg: ModelConfig) -> int:
    n = non_embedding_params(cfg)
    if not cfg.n_experts:
        return n
    # routed experts execute at top_k / n_experts utilization
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed = cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff * n_moe_layers
    active_routed = cfg.top_k * 3 * cfg.d_model * cfg.moe_d_ff * n_moe_layers
    return n - routed + active_routed


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global 'useful' FLOPs per step (6ND convention)."""
    shape = SHAPES[shape_name]
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    hlo = rec.get("hlo_analysis") or {}
    flops = hlo.get("flops", 0.0)
    hbm = hlo.get("hbm_bytes", 0.0)
    coll = hlo.get("collective_total", 0.0)
    cfg = get_config(rec["arch"])
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, rec["shape"]) / CHIPS
    ratio = (mf / flops) if flops else 0.0
    # roofline fraction: useful-compute time over the bound term
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    fixes = {
        "compute": "reduce remat recompute / pad waste (raise useful-FLOP ratio)",
        "memory": "fuse/shrink materialized activations; shard saved residuals",
        "collective": "reshard to cut all-gather/all-to-all volume; overlap with compute",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": flops,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "hbm_gib": rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30,
        "note": fixes[dominant],
    }


def load_rows(mesh: str = "pod16x16") -> list[dict]:
    rows = []
    base = ART / mesh
    for arch in ARCHS:
        for shape in SHAPES:
            p = base / f"{arch}__{shape}.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if rec.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape, "dominant": "n/a",
                             "skipped": rec.get("reason", "")})
                continue
            row = analyze_cell(rec)
            if row:
                rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL/HLO flops | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | n/a "
                       f"(skipped) | — | — | {r['skipped'][:40]} |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {r['note']} |\n")
    return "".join(out)


def main():
    rows = load_rows()
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "roofline.json").write_text(json.dumps(rows, indent=2))
    md = to_markdown(rows)
    (OUT / "roofline.md").write_text(md)
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},,,,skipped,,")
        else:
            print(f"{r['arch']},{r['shape']},{r['compute_s']:.5f},"
                  f"{r['memory_s']:.5f},{r['collective_s']:.5f},"
                  f"{r['dominant']},{r['useful_ratio']:.3f},"
                  f"{r['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    main()
