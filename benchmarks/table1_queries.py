"""Paper Table I reproduction: Q0-Q6 over the (synthetic) NYC-taxi data,
three conditions — Flint (serverless, SQS shuffle), PySpark-on-cluster
(record pipe overhead), Spark-on-cluster — reporting latency and estimated
USD per query from the 2018 price model.

Schema (repro.data.synthetic.taxi_csv):
  0 pickup_dt, 1 dropoff_dt, 2 dropoff_lon, 3 dropoff_lat, 4 trip_miles,
  5 payment_type, 6 tip, 7 total, 8 precip_mm, 9 taxi_color
"""

from __future__ import annotations

import os
import time

from repro.core import FlintConfig, FlintContext
from repro.data.synthetic import CITIGROUP, GOLDMAN, taxi_csv

N_ROWS = int(os.environ.get("TAXI_ROWS", "40000"))
N_PARTS = 8
TRIALS = int(os.environ.get("TAXI_TRIALS", "1"))


def _inside(box):
    def f(row):
        try:
            lon, lat = float(row[2]), float(row[3])
        except ValueError:
            return False
        return box[0] <= lon <= box[2] and box[1] <= lat <= box[3]
    return f


def _hour(ts: str) -> int:
    return int(ts[11:13])


def _month(ts: str) -> int:
    return int(ts[5:7])


def q0(ctx):  # line count: raw read throughput
    return ctx.textFile("taxi.csv", N_PARTS).count()


def q1(ctx):  # Goldman drop-offs by hour
    return (ctx.textFile("taxi.csv", N_PARTS)
            .map(lambda x: x.split(","))
            .filter(_inside(GOLDMAN))
            .map(lambda x: (_hour(x[1]), 1))
            .reduceByKey(lambda a, b: a + b, 8)
            .collect())


def q2(ctx):  # Citigroup drop-offs by hour
    return (ctx.textFile("taxi.csv", N_PARTS)
            .map(lambda x: x.split(","))
            .filter(_inside(CITIGROUP))
            .map(lambda x: (_hour(x[1]), 1))
            .reduceByKey(lambda a, b: a + b, 8)
            .collect())


def q3(ctx):  # generous tippers at Goldman
    g = _inside(GOLDMAN)
    return (ctx.textFile("taxi.csv", N_PARTS)
            .map(lambda x: x.split(","))
            .filter(lambda x: g(x) and float(x[6]) > 10.0)
            .map(lambda x: (x[0], float(x[6])))
            .collect())


def q4(ctx):  # credit-card share by month
    rows = (ctx.textFile("taxi.csv", N_PARTS)
            .map(lambda x: x.split(","))
            .map(lambda x: ((_month(x[0]), x[5] == "credit"), 1))
            .reduceByKey(lambda a, b: a + b, 12)
            .collect())
    share = {}
    for (m, credit), n in rows:
        tot = share.setdefault(m, [0, 0])
        tot[0] += n
        if credit:
            tot[1] += n
    return sorted((m, v[1] / v[0]) for m, v in share.items())


def q5(ctx):  # yellow vs green by month
    return sorted(ctx.textFile("taxi.csv", N_PARTS)
                  .map(lambda x: x.split(","))
                  .map(lambda x: ((_month(x[0]), x[9]), 1))
                  .reduceByKey(lambda a, b: a + b, 12)
                  .collect())


def q6(ctx):  # rides per precipitation bucket
    return sorted(ctx.textFile("taxi.csv", N_PARTS)
                  .map(lambda x: x.split(","))
                  .map(lambda x: (int(float(x[8])), 1))
                  .reduceByKey(lambda a, b: a + b, 16)
                  .collect())


QUERIES = [q0, q1, q2, q3, q4, q5, q6]


def run(rows=None, trials=TRIALS):
    data = taxi_csv(rows or N_ROWS, seed=11)
    results = []
    answers = {}
    for backend in ("flint", "pyspark", "cluster"):
        for qi, q in enumerate(QUERIES):
            best = None
            for _ in range(trials):
                ctx = FlintContext(backend, FlintConfig(concurrency=16))
                ctx.upload("taxi.csv", data)
                t0 = time.monotonic()
                ans = q(ctx)
                dt = time.monotonic() - t0
                rep = ctx.cost_report()
                cost = rep["total_usd"]
                if backend in ("cluster", "pyspark"):
                    cost = rep.get("cluster_usd", cost)
                if best is None or dt < best[0]:
                    best = (dt, cost)
            key = (qi, repr_answer(ans))
            answers.setdefault(qi, set()).add(key[1])
            results.append({"query": f"Q{qi}", "backend": backend,
                            "latency_s": round(best[0], 4),
                            "cost_usd": best[1]})
    # all three backends must agree on every query's answer
    agreement = all(len(v) == 1 for v in answers.values())
    return results, agreement


def repr_answer(ans):
    if isinstance(ans, list):
        return repr(sorted(ans))
    return repr(ans)


def main():
    results, agreement = run()
    print("query,backend,latency_s,cost_usd")
    for r in results:
        print(f"{r['query']},{r['backend']},{r['latency_s']},{r['cost_usd']:.6f}")
    print(f"# answers agree across backends: {agreement}")
    return results, agreement


if __name__ == "__main__":
    main()
