"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --preset smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", help=f"one of {ARCHS}")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (args.batch, cfg.frontend_len, cfg.d_model), cfg.cdtype)
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, 64, cfg.d_model), cfg.cdtype)

    t0 = time.time()
    toks = lm.generate(params, batch, cfg, n_steps=args.new_tokens)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.0f} tok/s)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
