"""ShapeDtypeStruct stand-ins for every (architecture x input-shape) cell.

``input_specs`` returns abstract inputs with attached NamedShardings —
weak-type-correct, shardable, zero allocation — plus the matching step
builder, so the dry-run is:

    step, args, donate = dryrun_cell(arch, shape, mesh)
    jax.jit(step, donate_argnums=donate).lower(*args).compile()

Cache sharding policy (decode cells):
  * batched decode:   batch -> ('pod','data'); kv-heads / state-heads ->
    'model' (head-aligned TP); time axis unsharded.
  * long_500k (B=1):  nothing to shard on batch — the KV/latent TIME axis
    shards on 'data' (sequence parallelism for the cache); heads stay on
    'model'. SSM states are seq-length-free and just TP-shard.
  * MLA latent has no head dim: the time axis shards on 'model' (batched)
    or ('data','model') (B=1).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.models import lm
from repro.runtime import steps as steps_mod
from repro.runtime.sharding import param_shardings, resolve_spec, rules_for

# encoder memory length for enc-dec decode cells (frames after the stub
# frontend): fixed, independent of the decoder cache length.
ENC_DEC_MEMORY_LEN = 4096


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _dp(mesh) -> P:
    names = tuple(mesh.axis_names)
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Abstract train/prefill batch with input shardings."""
    b, s = shape.global_batch, shape.seq_len
    dp = _dp(mesh)
    out: dict[str, Any] = {}
    if cfg.is_enc_dec:
        out["enc_embeds"] = _sds((b, s, cfg.d_model), cfg.cdtype, mesh,
                                 P(dp, None, None))
        out["tokens"] = _sds((b, s), jnp.int32, mesh, P(dp, None))
    elif cfg.frontend == "vision":
        f = cfg.frontend_len
        out["frontend"] = _sds((b, f, cfg.d_model), cfg.cdtype, mesh,
                               P(dp, None, None))
        out["tokens"] = _sds((b, s - f), jnp.int32, mesh, P(dp, None))
    else:
        out["tokens"] = _sds((b, s), jnp.int32, mesh, P(dp, None))
    return out


def _cache_spec(path, leaf, cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    """Sharding for one cache leaf, keyed off its name/rank."""
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    leafname = names[-1] if names else ""
    mesh_names = tuple(mesh.axis_names)
    dp = _dp(mesh)
    has_model = "model" in mesh_names
    mdl = "model" if has_model else None
    shape = leaf.shape
    cross = "cross" in names

    def head_axis(dim):  # shard a head-like dim only if it tiles the axis
        return mdl if (has_model and dim % mesh.shape["model"] == 0) else None

    spec: list = [None] * len(shape)
    # unstacked rank per leaf kind; stacked (scan) caches carry one extra
    # leading layers axis -> off = ndim - base, never sharded.
    if leafname in ("k", "v"):
        base = 4
    elif leafname == "c":
        base = 3 if cfg.attn_type == "mla" else (4 if "mlstm" in names else 2)
    elif leafname == "k_rope":
        base = 3
    elif leafname == "state":
        base = 4
    elif leafname in ("conv", "conv_x", "conv_bc"):
        base = 3
    elif leafname == "n":
        base = 3 if "mlstm" in names else 2
    else:  # m, h
        base = 2
    off = len(shape) - base

    if leafname in ("k", "v"):  # (L?, B, S, K, D)
        bdim, sdim, kdim = off, off + 1, off + 2
        if batch > 1:
            spec[bdim] = dp
            spec[kdim] = head_axis(shape[kdim])
            if spec[kdim] is None and not cross:
                # kv heads don't tile the model axis (qwen3 kv=8 on 16):
                # shard the cache TIME axis instead (SP for the cache)
                spec[sdim] = (mdl if shape[sdim] % mesh.shape.get("model", 1)
                              == 0 else None) if has_model else None
        else:
            if not cross:
                spec[sdim] = "data" if "data" in mesh_names else None
            spec[kdim] = head_axis(shape[kdim])
    elif leafname in ("c", "k_rope") and cfg.attn_type == "mla":
        bdim, sdim = off, off + 1
        if batch > 1:
            spec[bdim] = dp
            spec[sdim] = mdl
        else:
            spec[sdim] = (("data", "model") if has_model and
                          "data" in mesh_names else mdl)
    elif leafname == "state":  # mamba (L?, B, H, P, N)
        if batch > 1:
            spec[off] = dp
        spec[off + 1] = head_axis(shape[off + 1])
    elif leafname in ("conv", "conv_x", "conv_bc"):  # (L?, B, W-1, C)
        if batch > 1:
            spec[off] = dp
        spec[off + 2] = head_axis(shape[off + 2])
    elif leafname in ("c", "n", "m", "h"):  # xlstm states
        if batch > 1:
            spec[off] = dp
    return P(*spec)


def cache_specs(cfg: ModelConfig, batch: int, kv_len: int, mesh: Mesh,
                enc_len: int = 0):
    """Abstract caches (eval_shape over init_caches) with shardings."""
    abstract = jax.eval_shape(
        lambda: lm.init_caches(cfg, batch, kv_len, enc_len=enc_len))

    def attach(path, leaf):
        spec = _cache_spec(path, leaf, cfg, mesh, batch)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(attach, abstract)


def state_specs(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh):
    """Abstract TrainState with param/optimizer shardings attached."""
    from repro.models.lm import lm_schema
    schema = lm_schema(cfg)
    pshard = param_shardings(cfg, schema, mesh)
    abstract = steps_mod.abstract_train_state(cfg, tc)

    def attach(leaf, shard):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=shard)

    params = jax.tree.map(attach, abstract.params, pshard)
    m = jax.tree.map(attach, abstract.opt.m, pshard)
    v = jax.tree.map(attach, abstract.opt.v, pshard)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    ef = None
    if abstract.ef is not None:
        ef = jax.tree.map(attach, abstract.ef, pshard)
    return steps_mod.TrainState(params,
                                steps_mod.AdamWState(step, m, v), ef)


def param_specs_only(cfg: ModelConfig, mesh: Mesh):
    from repro.models.lm import lm_schema
    schema = lm_schema(cfg)
    pshard = param_shardings(cfg, schema, mesh)
    abstract = lm.abstract(cfg)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        abstract, pshard)


def dryrun_cell(arch: str, shape_name: str, mesh: Mesh, *,
                tc: TrainConfig | None = None, cfg: ModelConfig | None = None):
    """Returns (step_fn, example_args, donate_argnums) for one dry-run cell."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    tc = tc or TrainConfig()

    if shape.kind == "train":
        step = steps_mod.build_train_step(cfg, tc)
        state = state_specs(cfg, tc, mesh)
        batch = batch_specs(cfg, shape, mesh)
        # new state inherits the input state's shardings (donated buffers)
        state_sh = jax.tree.map(lambda sds: sds.sharding, state)
        return (step, (state, batch), (0,),
                {"out_shardings": (state_sh, None)})

    if shape.kind == "prefill":
        step = steps_mod.build_prefill_step(cfg)
        params = param_specs_only(cfg, mesh)
        batch = batch_specs(cfg, shape, mesh)
        b, s = shape.global_batch, shape.seq_len
        enc_len = s if cfg.is_enc_dec else 0
        # explicit out_shardings for the returned caches: without them XLA
        # replicates the cache across 'model' when heads are unshardable
        cache_sh = jax.tree.map(lambda sds: sds.sharding,
                                cache_specs(cfg, b, s, mesh, enc_len=enc_len))
        logits_sh = NamedSharding(mesh, P(_dp(mesh), None))
        return (step, (params, batch), (),
                {"out_shardings": (logits_sh, cache_sh)})

    # decode: one new token against a kv_len cache
    b = shape.global_batch
    kv_len = shape.seq_len
    enc_len = ENC_DEC_MEMORY_LEN if cfg.is_enc_dec else 0
    step = steps_mod.build_decode_step(cfg, kv_len=kv_len)
    params = param_specs_only(cfg, mesh)
    dp = _dp(mesh)
    token = _sds((b, 1), jnp.int32, mesh, P(dp if b > 1 else None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    caches = cache_specs(cfg, b, kv_len, mesh, enc_len=enc_len)
    cache_sh = jax.tree.map(lambda sds: sds.sharding, caches)
    logits_sh = NamedSharding(mesh, P(dp if b > 1 else None, None))
    return (step, (params, token, pos, caches), (3,),
            {"out_shardings": (logits_sh, cache_sh)})
