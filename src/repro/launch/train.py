"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --preset smoke \
        --steps 50 --workdir /tmp/run1

On a real TPU slice the same entrypoint runs the full config with the
production mesh (--mesh pod); on this CPU container use the reduced
presets. Lease seconds > 0 exercises chained executor semantics.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.data.synthetic import lm_batch
from repro.runtime import driver
from repro.runtime.sharding import rules_for, use_rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", help=f"one of {ARCHS}")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "full"],
                    help="smoke: reduced config for CPU; full: the real "
                         "config (TPU slice)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--lease-seconds", type=float, default=0.0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--workdir", default="/tmp/flintjax_run")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    tc = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                     warmup_steps=max(5, args.steps // 20),
                     checkpoint_every=max(5, args.steps // 10),
                     lease_seconds=args.lease_seconds,
                     grad_compression=args.grad_compression,
                     microbatches=args.microbatches)
    with use_rules(rules_for(cfg)):
        reports = driver.train_with_restarts(
            cfg, tc, workdir=args.workdir,
            batch_fn=lambda i: lm_batch(tc.seed, i, args.batch, args.seq,
                                        cfg.vocab_size),
            verbose=True, max_restarts=1000)
    r = reports[-1]
    print(f"status={r.status} end_step={r.end_step} leases={len(reports)}")
    if r.metrics:
        print(f"final loss={r.metrics[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
