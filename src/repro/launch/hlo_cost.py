"""Loop-aware cost model over compiled (post-GSPMD) HLO text.

XLA's HloCostAnalysis visits a while-loop body ONCE — for scan-over-layers
models that undercounts FLOPs/bytes/collective traffic by the layer count.
This module parses the compiled module, recovers loop trip counts from the
loop-condition constants, propagates execution multipliers through
while/call/fusion/conditional edges, and accumulates:

  * flops              — dot ops: 2 * |out| * contraction size, x multiplier
                         (dots inside fusion computations included)
  * hbm_bytes          — HBM traffic proxy: per materializing op,
                         sum(operand bytes) + output bytes; fusion internals
                         are accounted once at the fusion call site (matching
                         real fused-kernel traffic); dynamic-(update-)slice
                         counts the slice, not the buffer
  * collective_bytes   — per-op tensor bytes x multiplier, by kind

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * trip count = max integer constant in the loop condition computation;
  * conditional branches count as executed (upper bound);
  * parameter/tuple plumbing, reshapes and bitcasts are free.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|pred|s64|s32|s16"
                       r"|s8|s4|u64|u32|u16|u8|u4|c64|c128)\[([0-9,]*)\]")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
# tuple types may contain /*index=N*/ comments; match to the first ')'
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$")
_REF_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations=\{([^}]*)\}"
                        r"|true_computation=%?([\w\.\-]+)"
                        r"|false_computation=%?([\w\.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "reshape", "iota", "partition-id", "replica-id",
             "domain", "opt-barrier",
             # TPU: transposes fold into dot layouts; loop-carry copies are
             # elided by buffer aliasing; while/conditional are control flow
             # (their carried buffers alias in place)
             "transpose", "copy", "while", "conditional"}

# XLA:CPU leaves many elementwise ops unfused that XLA:TPU fuses into their
# producers/consumers; counting their traffic would overstate TPU HBM bytes
# several-fold. Under the TPU-fusion assumption these are traffic-free
# (their flops are negligible next to the dots); structural ops (dot,
# fusion, reduce, copy, transpose, concat, slice, scatter/gather,
# collectives, dynamic-(update-)slice) still pay full traffic.
_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                "select", "compare", "convert", "negate", "abs", "sign",
                "exponential", "exp", "log", "log-plus-one", "sqrt", "rsqrt",
                "power", "tanh", "logistic", "sine", "cosine", "floor",
                "ceil", "round-nearest-afz", "round-nearest-even", "clamp",
                "and", "or", "not", "xor", "shift-left",
                "shift-right-logical", "shift-right-arithmetic", "remainder",
                "atan2", "expm1", "log1p", "cbrt", "is-finite", "popcnt",
                "broadcast", "exponential-minus-one"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _first_shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


class _Op:
    __slots__ = ("name", "kind", "result_type", "args", "line")

    def __init__(self, name, kind, result_type, args, line):
        self.name = name
        self.kind = kind
        self.result_type = result_type
        self.args = args
        self.line = line


class _Comp:
    __slots__ = ("ops", "symtab")

    def __init__(self):
        self.ops: list[_Op] = []
        self.symtab: dict[str, str] = {}  # value name -> type string


def _parse(text: str):
    comps: dict[str, _Comp] = {}
    entry = None
    current = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            if line.lstrip().endswith("{"):
                mc = _COMP_RE.match(line)
                if mc:
                    current = mc.group(2)
                    comps[current] = _Comp()
                    if mc.group(1):
                        entry = current
            continue
        if current is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = _Op(mo.group(1), mo.group(3), mo.group(2), mo.group(4), line)
            comps[current].ops.append(op)
            comps[current].symtab[op.name] = op.result_type
    return comps, entry


def _operand_bytes(op: _Op, symtab: dict[str, str]) -> float:
    return sum(_shape_bytes(symtab[n]) for n in _REF_RE.findall(op.args)
               if n in symtab)


def _lhs_dims(op: _Op, symtab: dict[str, str]):
    for n in _REF_RE.findall(op.args):
        if n in symtab:
            return _first_shape_dims(symtab[n])
    return _first_shape_dims(op.args)  # typed-operand format fallback


def analyze(text: str) -> dict:
    comps, entry = _parse(text)
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c]))

    mult: dict[str, float] = defaultdict(float)
    fusion_comps: set[str] = set()
    mult[entry] = 1.0
    for _ in range(40):  # fixpoint over the (acyclic) call graph
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m <= 0:
                continue
            for op in comp.ops:
                callees: list[tuple[str, float]] = []
                if op.kind == "while":
                    mw = _WHILE_RE.search(op.line)
                    if mw:
                        cond, body = mw.group(1), mw.group(2)
                        cond_ops = comps.get(cond)
                        consts = [int(x) for o in
                                  (cond_ops.ops if cond_ops else ())
                                  for x in _CONST_RE.findall(o.line)]
                        trips = max(consts) if consts else 1
                        callees = [(body, m * max(trips, 1)),
                                   (cond, m * max(trips, 1))]
                elif op.kind in ("call", "fusion"):
                    mc = _CALL_RE.search(op.line)
                    if mc:
                        if op.kind == "fusion":
                            fusion_comps.add(mc.group(1))
                        callees = [(mc.group(1), m)]
                elif op.kind == "conditional":
                    mb = _BRANCH_RE.search(op.line)
                    if mb:
                        names = (re.findall(r"%?([\w\.\-]+)", mb.group(1))
                                 if mb.group(1) else [])
                        names += [g for g in mb.groups()[1:] if g]
                        callees = [(n, m) for n in names if n in comps]
                for callee, newm in callees:
                    if callee in comps and mult.get(callee, 0.0) < newm:
                        mult[callee] = newm
                        changed = True
        if not changed:
            break

    flops = 0.0
    hbm = 0.0
    hbm_by_kind: dict[str, float] = defaultdict(float)
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, float] = defaultdict(float)

    def add_hbm(kind, amount):
        nonlocal hbm
        hbm += amount
        hbm_by_kind[kind] += amount
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fusion_comps
        symtab = comp.symtab
        for op in comp.ops:
            kind = op.kind
            if kind in _FREE_OPS:
                continue
            if kind == "dot":
                out_dims = _first_shape_dims(op.result_type) or []
                out_elems = math.prod(out_dims) if out_dims else 1
                lhs = _lhs_dims(op, symtab) or []
                mcon = _CONTRACT_RE.search(op.line)
                csize = 1
                if mcon and mcon.group(1):
                    for dd in mcon.group(1).split(","):
                        if int(dd) < len(lhs):
                            csize *= lhs[int(dd)]
                flops += m * 2.0 * out_elems * csize
                if not in_fusion:
                    add_hbm("dot", m * (_shape_bytes(op.result_type)
                                        + _operand_bytes(op, symtab)))
                continue
            if in_fusion:
                continue  # traffic accounted at the fusion call site
            if kind == "fusion":
                mc = _CALL_RE.search(op.line)
                callee = comps.get(mc.group(1)) if mc else None
                ob = _operand_bytes(op, symtab)
                rb = _shape_bytes(op.result_type)
                has_dus = callee and any(o.kind == "dynamic-update-slice"
                                         for o in callee.ops)
                has_ds = callee and any(o.kind == "dynamic-slice"
                                        for o in callee.ops)
                if has_dus or has_ds:
                    # fused indexing into a loop-invariant / carried buffer
                    # (scan xs slicing or ys stacking): traffic is the
                    # slice, not the whole buffer
                    refs = [_shape_bytes(symtab[n])
                            for n in _REF_RE.findall(op.args) if n in symtab]
                    buf = max(refs) if refs else 0.0
                    if has_dus:
                        add_hbm("fusion-slice", m * max(0.0, ob + rb - 2 * buf))
                    else:
                        add_hbm("fusion-slice", m * (max(0.0, ob - buf) + rb))
                else:
                    add_hbm("fusion", m * (ob + rb))
                continue
            base = next((c for c in _COLLECTIVES if kind.startswith(c)), None)
            if base:
                b = _shape_bytes(op.result_type)
                coll_bytes[base] += m * b
                coll_count[base] += m
                add_hbm("collective", m * (b + _operand_bytes(op, symtab)))
                continue
            if kind == "dynamic-update-slice":
                refs = [n for n in _REF_RE.findall(op.args) if n in symtab]
                upd = _shape_bytes(symtab[refs[1]]) if len(refs) >= 2 else 0.0
                add_hbm("dus", m * 2 * upd)
                continue
            if kind == "dynamic-slice":
                add_hbm("ds", m * 2 * _shape_bytes(op.result_type))
                continue
            if kind in _ELEMENTWISE:
                continue  # fused on TPU (see note above)
            add_hbm(kind, m * (_shape_bytes(op.result_type)
                               + _operand_bytes(op, symtab)))

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "hbm_by_kind": {k: v for k, v in sorted(hbm_by_kind.items(),
                                                key=lambda kv: -kv[1])},
        "collective_bytes": dict(coll_bytes),
        "collective_count": dict(coll_count),
        "collective_total": sum(coll_bytes.values()),
        "n_computations": len(comps),
    }
