import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Perf-iteration harness: re-lower one (arch x shape) cell with config /
sharding-rule overrides and report the roofline terms, for
hypothesis -> change -> measure loops (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v2-236b \
        --shape train_4k --set remat=dots --rule act_seq=model --label v2
"""

import argparse
import ast
import json
import pathlib
import time

import jax

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import dryrun_cell
from repro.runtime.sharding import rules_for, use_rules

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "perf"


def measure(arch: str, shape: str, *, overrides=None, rule_overrides=None,
            tc: TrainConfig | None = None, label: str = "baseline",
            multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    rules = rules_for(cfg)
    if rule_overrides:
        rules.update(rule_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.sharding.set_mesh(mesh), use_rules(rules):
        step, args, donate, jkw = dryrun_cell(arch, shape, mesh,
                                              tc=tc, cfg=cfg)
        compiled = jax.jit(step, donate_argnums=donate,
                           **jkw).lower(*args).compile()
        res = hlo_cost.analyze(compiled.as_text())
        mem = compiled.memory_analysis()
    rec = {
        "label": label, "arch": arch, "shape": shape,
        "compute_s": res["flops"] / PEAK_FLOPS,
        "memory_s": res["hbm_bytes"] / HBM_BW,
        "collective_s": res["collective_total"] / ICI_BW,
        "hbm_peak_gib": mem.temp_size_in_bytes / 2**30,
        "collective_by_kind_gib": {k: round(v / 2**30, 2) for k, v in
                                   res["collective_bytes"].items()},
        "compile_s": round(time.time() - t0, 1),
        "overrides": {**(overrides or {}),
                      **{f"rule:{k}": v for k, v in
                         (rule_overrides or {}).items()}},
    }
    rec["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: rec[k])
    return rec


def _parse_kv(items):
    out = {}
    for it in items or []:
        k, _, v = it.partition("=")
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", dest="sets",
                    help="ModelConfig override, e.g. remat=dots")
    ap.add_argument("--rule", action="append", dest="rules",
                    help="sharding-rule override, e.g. act_seq=model "
                         "(use None to clear)")
    ap.add_argument("--label", default="exp")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    tc = TrainConfig(microbatches=args.microbatches)
    rule_over = {k: (None if v in ("None", "none") else v)
                 for k, v in _parse_kv(args.rules).items()}
    rec = measure(args.arch, args.shape, overrides=_parse_kv(args.sets),
                  rule_overrides=rule_over, tc=tc, label=args.label)
    ART.mkdir(parents=True, exist_ok=True)
    out = ART / f"{args.arch}__{args.shape}__{args.label}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
