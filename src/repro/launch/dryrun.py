import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

MUST be the first import in the process (device count locks on jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>.json — the
roofline analysis (benchmarks/roofline.py) reads them.
"""

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import dryrun_cell
from repro.runtime.sharding import rules_for, use_rules

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?\s*")


def skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: long_500k requires sub-quadratic decode"
    del shape
    return None


_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "f8": 1, "s8": 1,
                "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8\w*|pred|s64|s32|s16|s8|s4|u64"
                       r"|u32|u16|u8|u4)\[([0-9,]*)\]")


def _tensor_bytes(text: str) -> float:
    """Sum byte sizes of all tensor literals in an HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        dt = "f8" if dt.startswith("f8") else dt
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO module.

    Output (not operand) sizes: for all-gather the output is the gathered
    tensor (bytes that actually crossed links, x(n-1)/n), for all-to-all
    and collective-permute output==input, for all-reduce/reduce-scatter the
    moved bytes are ~the operand size — we take whichever side the op
    reports on its result type, a consistent ~1x proxy for link traffic.
    """
    per_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|[\w\[\],{} ]+?))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all"
            r"|collective-permute)(?:-start)?\(", s)
        if not m:
            continue
        ty, kind = m.group(1), m.group(2)
        per_kind[kind] = per_kind.get(kind, 0.0) + _tensor_bytes(ty)
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             out_dir: pathlib.Path) -> dict:
    cfg = get_config(arch)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mesh_shape": dict(mesh.shape), "status": "ok"}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    try:
        with jax.sharding.set_mesh(mesh), use_rules(rules_for(cfg)):
            step, args, donate, jkw = dryrun_cell(arch, shape_name, mesh)
            lowered = jax.jit(step, donate_argnums=donate, **jkw).lower(*args)
            compiled = lowered.compile()
            # collectives only exist POST-GSPMD: parse the compiled module
            ctext = compiled.as_text()
            rec["collectives"] = collective_bytes(ctext)
            # loop-aware cost model (XLA's cost_analysis counts while
            # bodies once; scan-over-layers needs trip-count multipliers)
            rec["hlo_analysis"] = hlo_cost.analyze(ctext)
            del ctext
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
        rec["memory_analysis"] = {
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
        rec["cost_analysis"] = {
            k: float(v) for k, v in (cost or {}).items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals") or k.startswith("bytes"))}
        rec["lower_compile_seconds"] = round(time.time() - t0, 2)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}.json").write_text(
        json.dumps(rec, indent=2, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [(False, "pod16x16"), (True, "multipod2x16x16")]
    else:
        meshes = [(args.multi_pod,
                   "multipod2x16x16" if args.multi_pod else "pod16x16")]

    archs = ARCHS if (args.all or not args.arch) else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = 0
    for multi_pod, mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        out_dir = ART / mesh_name
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, mesh_name, out_dir)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mb = rec["memory_analysis"].get("temp_size_in_bytes", 0)
                    extra = (f" compile={rec['lower_compile_seconds']}s"
                             f" temp={mb/2**30:.2f}GiB"
                             f" coll={rec['collectives']['total_bytes']/2**30:.2f}GiB")
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:160]
                elif status == "skipped":
                    extra = " " + rec["reason"]
                print(f"[{mesh_name}] {arch:22s} {shape:12s} {status:7s}{extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
