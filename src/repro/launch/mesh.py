"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init;
smoke tests and benchmarks see the 1 real CPU device.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    'pod'   — data parallelism across pods (DCN-connected)
    'data'  — data parallelism + FSDP weight sharding (ICI)
    'model' — tensor / expert parallelism (ICI)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
