"""Multi-tenant query service over the Flint engine
(docs/multi_tenant.md): driver-as-a-service with admission control,
weighted fair-share slot scheduling, cross-job CSE of shuffle streams,
a byte-capped shared cache, and per-tenant cost/retry quotas.

    from repro.svc import FlintService
    svc = FlintService(config, slot_capacity=16)
    svc.register_tenant("acme", weight=2, max_usd=0.02)
    with svc.session("acme") as s:
        rows = s.read_csv("taxi.csv", schema, 8).collect()
"""

from repro.svc.admission import AdmissionController, AdmissionRejected
from repro.svc.fairshare import FairSharePool, JobSlots
from repro.svc.session import FlintService, Session, TenantQuota
from repro.svc.share import ShareRegistry, SharedCache

__all__ = ["FlintService", "Session", "TenantQuota",
           "AdmissionController", "AdmissionRejected",
           "FairSharePool", "JobSlots",
           "ShareRegistry", "SharedCache"]
