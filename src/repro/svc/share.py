"""Cross-query sharing for the multi-tenant service
(docs/multi_tenant.md): one producer stage and one cache
materialization can feed MANY concurrent jobs, across tenants.

``ShareRegistry`` lifts the planner's per-plan CSE memo (core.dag) to
service scope. When job B plans a shuffle whose close-site key —
lineage fingerprint, mode, partition count, combiner, transport, batch
schema — matches one job A already published, B plans NO producer
stage: it reads A's stream as a FOREIGN input through a fresh consumer
group, exactly the multi-consumer fan-out the transports already speak
(docs/dag_fanout.md). Only S3-routed shuffles share: the exchange's
reads are non-destructive and its per-partition EOS manifests serve
any number of groups, while SQS queues are destroyed by consumption —
a late-joining job would race the owner's acks for messages.

Lifecycle is reference-counted per JOB: a shared shuffle dies only
once its owner's run closed (retired) AND every participating job
drained or closed. The registry deletes the data itself
(``delete_prefix`` — exempt from fault injection, so cleanup cannot
flake under a service-wide chaos plan); the owning scheduler is told
via ``manages()`` to keep its hands off.

``SharedCache`` is the service-wide ``RDD.cache()`` index: the same
mapping protocol contexts already use, plus an LRU byte cap. Entries
are sized when their materialization commits; overflowing the cap
evicts least-recently-planned READY entries — never entries PINNED by
a running job (a plan that resolved a CacheInput must find its batches
until the job ends) and never still-materializing ones.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import MutableMapping

#: close-key element that names the shuffle's transport hint (see
#: core.dag._close_key); "" defers to the job's configured fallback
_KEY_TRANSPORT = 4


class _Entry:
    __slots__ = ("sid", "key", "owner", "n_prod", "write", "transport",
                 "nparts", "participants", "done", "retired", "destroyed")

    def __init__(self, sid, key, owner, n_prod, write):
        self.sid = sid
        self.key = key
        self.owner = owner
        self.n_prod = n_prod
        self.write = write
        self.transport = None   # set at notify_open (owner's instance)
        self.nparts = write.nparts
        self.participants = {owner}
        self.done: set = set()
        self.retired = False
        self.destroyed = False


class ShareRegistry:
    """Service-wide shuffle-share state. Jobs talk to it through
    ``view(job_id, fallback)`` handles — one per job — that stamp the
    job identity on every call."""

    def __init__(self, store):
        self.store = store
        self._lock = threading.RLock()
        self._by_key: dict[tuple, int] = {}   # close key -> sid
        self._entries: dict[int, _Entry] = {}
        self.stats = {"published": 0, "hits": 0, "joined_groups": 0,
                      "destroyed": 0}

    def view(self, job_id: int, fallback: str) -> "ShareView":
        return ShareView(self, job_id, fallback)

    # ----------------------------------------------------- plan-time hooks
    def _resolved(self, key: tuple, fallback: str) -> str:
        return key[_KEY_TRANSPORT] or fallback

    def publish(self, job_id: int, key: tuple, sid: int, n_prod: int,
                write, fallback: str):
        if self._resolved(key, fallback) != "s3":
            return  # destructive transports cannot fan out across jobs
        with self._lock:
            if key in self._by_key:
                # two jobs planned the same shuffle concurrently before
                # either published: first wins, the later one runs its
                # own producer privately (double work, never wrong)
                return
            self._by_key[key] = sid
            self._entries[sid] = _Entry(sid, key, job_id, n_prod, write)
            self.stats["published"] += 1

    def lookup(self, job_id: int, key: tuple, fallback: str):
        if self._resolved(key, fallback) != "s3":
            return None
        with self._lock:
            sid = self._by_key.get(key)
            if sid is None:
                return None
            entry = self._entries[sid]
            if entry.retired or entry.owner == job_id:
                return None
            entry.participants.add(job_id)
            # a re-planning job (elastic retry) joins afresh
            entry.done.discard(job_id)
            self.stats["hits"] += 1
            return sid, entry.n_prod

    def join_group(self, job_id: int, sid: int) -> int:
        """Allocate one more consumer group on a shared shuffle — one
        per read site of the joining plan. Bumps the OWNER's write (its
        ``open`` creates channels for every group known by then) and,
        once the owner's transport is known, raises its all-groups-
        released data-reclaim threshold too (``add_group``)."""
        with self._lock:
            entry = self._entries[sid]
            g = entry.write.consumer_groups
            entry.write.consumer_groups += 1
            if entry.transport is not None:
                entry.transport.add_group(sid, entry.write.consumer_groups)
            self.stats["joined_groups"] += 1
            return g

    # ------------------------------------------------------ run-time hooks
    def notify_open(self, sid: int, transport, write):
        with self._lock:
            entry = self._entries.get(sid)
            if entry is None:
                return
            entry.transport = transport
            entry.nparts = write.nparts
            # groups joined between the owner's open() reading the count
            # and this call are folded in here, under the same lock that
            # join_group takes
            transport.add_group(sid, write.consumer_groups)

    def manages(self, sid: int) -> bool:
        with self._lock:
            return sid in self._entries

    def job_drained(self, job_id: int, sid: int):
        """Every one of ``job_id``'s consuming stages drained this
        shared shuffle."""
        with self._lock:
            entry = self._entries.get(sid)
            if entry is None:
                return
            entry.done.add(job_id)
            self._maybe_destroy(entry)

    def run_closed(self, job_id: int, produced_sids: set):
        """A job's scheduler shut down (success or failure): retire the
        entries it owned — no NEW job may plan against a stream whose
        producer run is over — and count it done everywhere it
        participated."""
        with self._lock:
            for entry in list(self._entries.values()):
                if entry.owner == job_id:
                    entry.retired = True
                    self._by_key.pop(entry.key, None)
                if job_id in entry.participants:
                    entry.done.add(job_id)
                self._maybe_destroy(entry)

    def sweep(self) -> int:
        """Service-close backstop: destroy anything still alive (there
        are no jobs left to drain it). Returns keys deleted."""
        n = 0
        with self._lock:
            for entry in self._entries.values():
                if not entry.destroyed:
                    entry.destroyed = True
                    n += self.store.delete_prefix(f"_exchange/{entry.sid}/")
        return n

    def _maybe_destroy(self, entry: _Entry):
        """Caller holds the lock."""
        if (entry.retired and not entry.destroyed
                and entry.participants <= entry.done):
            entry.destroyed = True
            self.stats["destroyed"] += 1
            # delete_prefix bypasses fault injection by design — the
            # sweep cannot flake under the service-wide chaos injector
            self.store.delete_prefix(f"_exchange/{entry.sid}/")


class ShareView:
    """One job's handle on the registry: what the planner (lookup /
    join_group / publish) and the scheduler (notify_open / manages /
    job_drained / run_closed) receive. ``used_foreign`` records whether
    this job's plan leaned on another job's stream — the service's solo
    fallback re-plans without sharing when such a job fails."""

    def __init__(self, registry: ShareRegistry, job_id: int, fallback: str):
        self.registry = registry
        self.job_id = job_id
        self.fallback = fallback
        self.used_foreign = False

    # planner side
    def lookup(self, key: tuple):
        return self.registry.lookup(self.job_id, key, self.fallback)

    def join_group(self, sid: int) -> int:
        self.used_foreign = True
        return self.registry.join_group(self.job_id, sid)

    def publish(self, key: tuple, sid: int, n_prod: int, write):
        self.registry.publish(self.job_id, key, sid, n_prod, write,
                              self.fallback)

    # scheduler side
    def notify_open(self, sid: int, transport, write):
        self.registry.notify_open(sid, transport, write)

    def manages(self, sid: int) -> bool:
        return self.registry.manages(sid)

    def job_drained(self, sid: int, job_id: int):
        self.registry.job_drained(job_id, sid)

    def run_closed(self, job_id: int, produced_sids: set):
        self.registry.run_closed(job_id, produced_sids)


class SharedCache(MutableMapping):
    """Service-wide ``RDD.cache()`` registry with an LRU byte cap.

    Drop-in for the context's plain-dict ``_cache_index`` (same mapping
    protocol — the planner and GC never know the difference), plus:

      * ``committed(token)`` — called by the context once a
        materialization is durable; sizes it and evicts LRU unpinned
        READY entries while the total exceeds ``byte_cap``;
      * ``pin``/``unpin`` — jobs pin every token their plan touches for
        the duration of the run, so eviction never deletes batches a
        live plan resolved;
      * ``drop(token)`` / ``drop_all()`` — explicit ``uncache()`` /
        ``clear_cache()``, refusing pinned entries the same way.

    Cache identity is the content-addressed lineage token, so two
    tenants caching the same derivation share one materialization —
    cross-tenant hits are the point of the shared service.
    """

    def __init__(self, store, byte_cap: int):
        self.store = store
        self.byte_cap = byte_cap
        self._lock = threading.RLock()
        self._entries: "OrderedDict[str, dict]" = OrderedDict()
        self._sizes: dict[str, int] = {}
        self._pins: dict[str, int] = {}
        self.stats = {"evictions": 0, "evicted_bytes": 0, "dropped": 0}

    # ----------------------------------------------------- dict protocol
    def __getitem__(self, token):
        with self._lock:
            entry = self._entries[token]
            if entry.get("ready"):
                self._entries.move_to_end(token)  # LRU touch
            return entry

    def __setitem__(self, token, entry):
        with self._lock:
            self._entries[token] = entry

    def __delitem__(self, token):
        with self._lock:
            del self._entries[token]
            self._sizes.pop(token, None)

    def __iter__(self):
        with self._lock:
            return iter(list(self._entries))

    def __len__(self):
        with self._lock:
            return len(self._entries)

    def items(self):
        with self._lock:
            return list(self._entries.items())

    # ------------------------------------------------------ service hooks
    def pin(self, token: str):
        with self._lock:
            self._pins[token] = self._pins.get(token, 0) + 1

    def unpin(self, token: str):
        with self._lock:
            n = self._pins.get(token, 0) - 1
            if n <= 0:
                self._pins.pop(token, None)
                # a pin may have carried the total over the cap (the
                # running job's own fresh materialization often does) —
                # releasing the last pin is the moment to re-check
                self._evict_over_cap()
            else:
                self._pins[token] = n

    def pinned(self, token: str) -> bool:
        with self._lock:
            return self._pins.get(token, 0) > 0

    def total_bytes(self) -> int:
        with self._lock:
            return sum(self._sizes.values())

    def committed(self, token: str):
        """A materialization finished: size it and enforce the cap."""
        with self._lock:
            entry = self._entries.get(token)
            if entry is None:
                return
            self._sizes[token] = self.store.prefix_bytes(
                f"_cache/{token}/{entry['nparts']}/")
            self._entries.move_to_end(token)
            self._evict_over_cap()

    def drop(self, token: str) -> int:
        """Explicit uncache; refuses (returns 0) while a running job has
        the entry pinned — its plan already resolved these batches."""
        with self._lock:
            if self._pins.get(token, 0) > 0:
                return 0
            if self._entries.pop(token, None) is None:
                return 0
            self._sizes.pop(token, None)
            self.stats["dropped"] += 1
            return self.store.delete_prefix(f"_cache/{token}/")

    def drop_all(self) -> int:
        with self._lock:
            return sum(self.drop(t) for t in list(self._entries))

    def _evict_over_cap(self):
        """Caller holds the lock. Oldest-planned-first over READY,
        UNPINNED entries; pinned or in-flight entries may carry the
        total over the cap transiently — the next commit re-checks."""
        for token in list(self._entries):
            if sum(self._sizes.values()) <= self.byte_cap:
                break
            entry = self._entries[token]
            if not entry.get("ready") or self._pins.get(token, 0) > 0:
                continue
            size = self._sizes.pop(token, 0)
            del self._entries[token]
            self.store.delete_prefix(f"_cache/{token}/")
            self.stats["evictions"] += 1
            self.stats["evicted_bytes"] += size
