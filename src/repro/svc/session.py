"""FlintService: the serverless driver AS A SERVICE
(docs/multi_tenant.md).

The solo ``FlintContext`` is one driver owning one store, one ledger and
one scheduler at a time. ``FlintService`` runs MANY of them: each tenant
opens ``Session`` objects whose context speaks the unchanged
RDD/DataFrame surface, while underneath every job draws from ONE shared
substrate —

  * one object store (inputs uploaded once serve every tenant) under one
    account-wide chaos injector when a fault plan is set;
  * one invocation-slot pool split by weighted fair share
    (svc.fairshare) and one account concurrency gauge, so
    ``FaultPlan.account_concurrency`` caps the account, not each job;
  * one admission gate (svc.admission) bounding concurrent + queued
    jobs and pre-rejecting over-quota tenants;
  * one cross-job CSE registry and one byte-capped cache (svc.share):
    two tenants submitting the same query plan ONE producer stage and
    share one ``cache()`` materialization;
  * one root ``CostLedger`` with per-tenant child ledgers — every
    charge lands on both, so tenant bills sum to the account bill.

Billing attribution: Lambda and SQS sims are created per scheduler with
the TENANT's ledger, so compute and queue traffic meter per tenant. The
shared store bills its OWNER — the service root ledger — i.e. S3 is
"bucket owner pays"; per-tenant dollar quotas therefore meter
lambda + sqs, which is where serverless analytics money goes (paper
Table I).

Failure containment: a job whose plan leaned on ANOTHER job's shuffle
stream (``used_foreign``) can be failed by that foreign producer's
death. The service answers with a SOLO FALLBACK — one replan with
sharing disabled — before surfacing the error; tenant-quota failures
are never retried this way (the budget is spent either way).
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.core import FlintContext
from repro.core.costs import CostLedger
from repro.core.dag import CacheInput
from repro.core.dag import build_plan
from repro.core.executors import FlintConfig
from repro.core.faults import ConcurrencyGauge, FaultInjector, FaultPlan
from repro.core.queues import ObjectStoreSim
from repro.core.retry import RetryBudget, TransientServiceError
from repro.core.scheduler import (GC_PREFIXES, STREAM_PREFIX,
                                  FlintScheduler, StageFailure)
from repro.svc.admission import AdmissionController
from repro.svc.fairshare import FairSharePool
from repro.svc.share import ShareRegistry, SharedCache

#: default shared-cache byte cap — roomy for tests, small enough that a
#: benchmark caching a few taxi derivations actually sees evictions
DEFAULT_CACHE_BYTES = 64 * 2**20


@dataclasses.dataclass
class TenantQuota:
    """Per-tenant limits. ``weight`` skews the fair-share slot split;
    ``max_usd`` caps metered lambda+sqs spend (checked at admission and
    again between task launches mid-job); ``retry_budget`` bounds
    service-call retries across ALL the tenant's jobs together (the solo
    engine's per-job budget, lifted to tenant scope)."""
    weight: int = 1
    max_usd: float | None = None
    retry_budget: int | None = None


class _Tenant:
    def __init__(self, name: str, quota: TenantQuota, ledger: CostLedger):
        self.name = name
        self.quota = quota
        self.ledger = ledger
        self.retry_budget = (RetryBudget(quota.retry_budget)
                             if quota.retry_budget is not None else None)
        self.jobs = 0

    def quota_error(self) -> str | None:
        """Admission-time pre-check: the reason this tenant may not start
        another job, or None."""
        q = self.quota
        if q.max_usd is not None and self.ledger.total_usd >= q.max_usd:
            return (f"tenant {self.name!r} over budget: "
                    f"${self.ledger.total_usd:.6f} spent of "
                    f"${q.max_usd:.6f}")
        if (self.retry_budget is not None
                and self.retry_budget.remaining <= 0):
            return (f"tenant {self.name!r} retry budget exhausted "
                    f"({self.retry_budget.total} service-call retries)")
        return None

    def cost_guard(self):
        """Mid-job enforcement, called by the scheduler between task
        launches: a tenant that crosses its dollar cap WHILE running is
        stopped, not just refused next time. Non-retryable — elastic
        replans would bill the same budget again."""
        q = self.quota
        if q.max_usd is not None and self.ledger.total_usd >= q.max_usd:
            raise StageFailure(
                f"tenant {self.name!r} exceeded ${q.max_usd:.6f} quota "
                f"mid-job (spent ${self.ledger.total_usd:.6f})",
                error_type="TenantQuotaExceeded", retryable=False,
                detail={"tenant": self.name, "max_usd": q.max_usd,
                        "spent_usd": self.ledger.total_usd})


class _JobBinding:
    """What one scheduler receives from the service: its slice of every
    shared resource (FlintScheduler reads exactly these attributes)."""

    __slots__ = ("job_id", "scope", "slots", "share", "gauge",
                 "retry_budget", "cost_guard")

    def __init__(self, job_id, scope, slots, share, gauge, retry_budget,
                 cost_guard):
        self.job_id = job_id
        self.scope = scope
        self.slots = slots
        self.share = share
        self.gauge = gauge
        self.retry_budget = retry_budget
        self.cost_guard = cost_guard


class _Job:
    """One run_action: a service-unique id, a share-registry view, the
    cache tokens its plans pinned, and the solo-fallback latch."""

    def __init__(self, job_id: int, view):
        self.job_id = job_id
        self.view = view
        self.solo = False
        self.pinned: list[str] = []


class _ServiceContext(FlintContext):
    """A tenant session's engine: the stock FlintContext pointed at the
    service's shared store/cache/ledger, with the three service hooks
    filled in — scheduler binding, share-aware planning, and admission
    around every action."""

    def __init__(self, service: "FlintService", tenant: _Tenant):
        super().__init__("flint", service.config,
                         fault_plan=service.fault_plan,
                         store=service.store, ledger=tenant.ledger,
                         cache_index=service.cache,
                         verbose=service.verbose)
        self.service = service
        self.tenant = tenant
        self._job: _Job | None = None
        # one action at a time per session — concurrency comes from many
        # sessions, and an unsynchronized second action would race the
        # per-job state below
        self._action_lock = threading.Lock()
        # a streaming query holds ONE admission slot for its whole
        # lifetime (stream_begin/stream_end); per-batch actions then skip
        # re-admission so an admitted stream cannot deadlock the gate or
        # be re-queued against itself between micro-batches
        self._stream_admitted = False

    # ------------------------------------------------------ service hooks
    def _make_scheduler(self):
        svc = self.service
        job = self._job
        binding = _JobBinding(
            job_id=job.job_id,
            scope=f"j{job.job_id}/",
            # a fresh lease per scheduler: elastic replans detach the old
            # one at shutdown and re-enter the pool cleanly
            slots=svc.pool.lease(self.tenant.name),
            share=None if job.solo else job.view,
            gauge=svc.gauge,
            retry_budget=self.tenant.retry_budget,
            cost_guard=self.tenant.cost_guard)
        return FlintScheduler(self.config, self.tenant.ledger, self.store,
                              fault_plan=self.fault_plan,
                              verbose=self.verbose,
                              cache_index=self._cache_index,
                              binding=binding)

    def _build_plan(self, rdd, action, save_prefix, mult, limit):
        job = self._job
        plan = build_plan(rdd, action, save_prefix,
                          partition_multiplier=mult,
                          cse=self.config.plan_cse,
                          cache_index=self._cache_index,
                          default_transport=self.config.shuffle_backend,
                          limit=limit,
                          share=None if job.solo else job.view)
        # pin every cache token this plan touches (reads AND pending
        # materializations) so the byte-cap eviction and other tenants'
        # uncache() cannot delete batches a resolved plan will fetch
        for token in self._plan_tokens(plan):
            self._cache_index.pin(token)
            job.pinned.append(token)
        return plan

    def _plan_tokens(self, plan) -> set:
        tokens = set(self._plan_cache_tokens(plan))
        for stage in plan:
            for task in stage.tasks:
                if isinstance(task.input, CacheInput):
                    tokens.add(task.input.token)
        return tokens

    # ------------------------------------------------- streaming admission
    def stream_begin(self):
        """Admit a long-running streaming query ONCE: the admission slot
        is held until ``stream_end`` so the query counts against
        max_running for its whole life, while each micro-batch still
        leases fair-share invocation slots and re-checks the tenant
        quota (``stream_quota_check``) between batches."""
        if self._stream_admitted:
            raise RuntimeError("session already runs a streaming query")
        self.service.admission.admit(self.tenant.name,
                                     quota_check=self.tenant.quota_error)
        self._stream_admitted = True

    def stream_end(self):
        if self._stream_admitted:
            self._stream_admitted = False
            self.service.admission.release()

    def stream_quota_check(self):
        """Between-batch tenant quota enforcement: raises the same
        structured TenantQuotaExceeded StageFailure as the mid-job
        guard."""
        self.tenant.cost_guard()

    def run_action(self, rdd, action, save_prefix=None, limit=None):
        svc = self.service
        tenant = self.tenant
        with self._action_lock:
            admitted = not self._stream_admitted
            if admitted:
                svc.admission.admit(tenant.name,
                                    quota_check=tenant.quota_error)
            try:
                job = svc._new_job(tenant)
                self._job = job
                try:
                    return super().run_action(rdd, action, save_prefix,
                                              limit)
                except StageFailure as e:
                    if (job.view.used_foreign and not job.solo
                            and e.error_type != "TenantQuotaExceeded"):
                        # SOLO FALLBACK: this plan consumed another job's
                        # stream and that dependency (not this job's own
                        # work) may be what died — replan once with
                        # sharing off, correctness over sharing
                        job.solo = True
                        svc.stats["solo_fallbacks"] += 1
                        if self.verbose:
                            print(f"[svc] job {job.job_id} foreign-input "
                                  f"failure -> solo replan")
                        return super().run_action(rdd, action,
                                                  save_prefix, limit)
                    raise
                finally:
                    for token in job.pinned:
                        self._cache_index.unpin(token)
                    job.pinned.clear()
                    self._job = None
            finally:
                if admitted:
                    svc.admission.release()


class Session:
    """One tenant's handle on the service — the object application code
    holds. ``.ctx`` is a full FlintContext (textFile / read_csv /
    parallelize / cache / collect ... all unchanged); the common entry
    points are re-exported here for convenience."""

    def __init__(self, service: "FlintService", tenant: _Tenant):
        self.service = service
        self.tenant = tenant
        self.ctx = _ServiceContext(service, tenant)
        self.closed = False

    # convenience delegation — the surface tests and benchmarks touch
    def textFile(self, key, numPartitions: int = 8):
        return self.ctx.textFile(key, numPartitions)

    def read_csv(self, key, schema, numPartitions: int = 8):
        return self.ctx.read_csv(key, schema, numPartitions)

    def parallelize(self, data, numPartitions: int = 8):
        return self.ctx.parallelize(data, numPartitions)

    def upload(self, key, data: bytes):
        self.service.upload(key, data)

    def read_stream(self, source):
        """Open a streaming frame over an unbounded source; the query it
        starts admits as ONE long-running job (stream_begin) with
        per-tenant quota re-checked between micro-batches
        (docs/streaming.md)."""
        from repro.streaming import read_stream
        return read_stream(self.ctx, source)

    def cost_report(self) -> dict:
        """THIS tenant's bill (the child ledger): shared with the
        tenant's other sessions, disjoint from other tenants'."""
        return self.tenant.ledger.report()

    def close(self):
        self.closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class FlintService:
    """The multi-tenant driver service. Typical shape:

        svc = FlintService(config, slot_capacity=16)
        svc.register_tenant("acme", weight=2, max_usd=0.02)
        svc.upload("taxi.csv", data)
        with svc.session("acme") as s:
            rows = s.read_csv("taxi.csv", schema, 8).collect()
        print(svc.report()["tenants"]["acme"]["total_usd"])
        svc.close()   # sweeps transient state; leak_report() then
                      # shows zero keys under every transient prefix
    """

    def __init__(self, config: FlintConfig | None = None, *,
                 fault_plan: FaultPlan | dict | None = None,
                 slot_capacity: int | None = None,
                 max_running: int = 8, max_queued: int = 16,
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 verbose: bool = False):
        self.config = config or FlintConfig()
        self.config.validate()
        self.verbose = verbose
        self.ledger = CostLedger()  # the account-wide (root) ledger
        self.store = ObjectStoreSim(self.ledger)
        self.fault_plan = FaultPlan.coerce(fault_plan)
        # ONE service-wide injector chaoses the shared store for the
        # service's whole lifetime (each scheduler still injects its own
        # private SQS + Lambda faults); detached at close so the final
        # sweep and post-mortem leak checks run fault-free
        self.injector = None
        if self.fault_plan.has_service_faults:
            self.injector = FaultInjector(self.fault_plan, self.ledger)
            self.store.faults = self.injector
        self.gauge = ConcurrencyGauge()
        self.pool = FairSharePool(slot_capacity
                                  or self.config.concurrency)
        self.admission = AdmissionController(max_running=max_running,
                                             max_queued=max_queued)
        self.share = ShareRegistry(self.store)
        self.cache = SharedCache(self.store, cache_bytes)
        self._tenants: dict[str, _Tenant] = {}
        self._lock = threading.Lock()
        self._job_counter = 0
        self.stats = {"jobs": 0, "solo_fallbacks": 0}
        self.closed = False

    # ----------------------------------------------------------- tenants
    def register_tenant(self, name: str, *, weight: int = 1,
                        max_usd: float | None = None,
                        retry_budget: int | None = None) -> None:
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            quota = TenantQuota(weight=weight, max_usd=max_usd,
                                retry_budget=retry_budget)
            self._tenants[name] = _Tenant(name, quota,
                                          self.ledger.child())
        self.pool.set_weight(name, weight)

    def session(self, tenant: str) -> Session:
        """Open a session for ``tenant`` (auto-registered with default
        quotas on first sight)."""
        if self.closed:
            raise RuntimeError("FlintService is closed")
        with self._lock:
            t = self._tenants.get(tenant)
        if t is None:
            try:
                self.register_tenant(tenant)
            except ValueError:
                pass  # lost a registration race — use the winner's
            with self._lock:
                t = self._tenants[tenant]
        return Session(self, t)

    def _new_job(self, tenant: _Tenant) -> _Job:
        with self._lock:
            self._job_counter += 1
            jid = self._job_counter
            self.stats["jobs"] += 1
            tenant.jobs += 1
        return _Job(jid, self.share.view(jid, self.config.shuffle_backend))

    # -------------------------------------------------------------- data
    def upload(self, key: str, data: bytes):
        """Put shared input data, riding out the service-wide chaos
        injector the way a real driver's SDK retries a 503."""
        for i in range(8):
            try:
                return self.store.put(key, data)
            except TransientServiceError:
                time.sleep(min(0.25, 0.002 * (2 ** i)))
        return self.store.put(key, data)  # last try surfaces the error

    # ------------------------------------------------------ observability
    def report(self) -> dict:
        with self._lock:
            tenants = dict(self._tenants)
        return {
            "account": self.ledger.report(),
            "tenants": {n: t.ledger.report() for n, t in tenants.items()},
            "jobs": dict(self.stats),
            "admission": dict(self.admission.stats),
            "pool": {"capacity": self.pool.capacity,
                     "grants": self.pool.grants,
                     "denials": self.pool.denials,
                     "peak_held": self.pool.peak_held},
            "gauge_peak": self.gauge.peak,
            "share": dict(self.share.stats),
            "cache": {"entries": len(self.cache),
                      "bytes": self.cache.total_bytes(),
                      "cap": self.cache.byte_cap,
                      **self.cache.stats},
        }

    def leak_report(self) -> dict:
        """Keys still present under every transient prefix — all zero
        after ``close()``. Reads the sim's key set directly: leak
        accounting must not itself bill requests or draw chaos faults."""
        prefixes = GC_PREFIXES + ("_exchange/", STREAM_PREFIX)
        keys = list(self.store._objects)
        return {p: sum(k.startswith(p) for k in keys) for p in prefixes}

    # ------------------------------------------------------------ closing
    def close(self) -> dict:
        """Shut the service: detach chaos, destroy surviving shared
        shuffles, sweep every transient prefix (content-addressed
        ``_spill/`` keys are shared across jobs, so only now is it safe).
        Cache materializations PERSIST (a service restart can reuse
        them); call ``clear_cache()`` first for a full wipe. Returns the
        sweep counts."""
        self.closed = True
        self.store.faults = None
        report = {"_exchange/": self.share.sweep()}
        for prefix in GC_PREFIXES + ("_exchange/", STREAM_PREFIX):
            n = self.store.delete_prefix(prefix)
            if n:
                report[prefix] = report.get(prefix, 0) + n
        return report

    def clear_cache(self) -> int:
        return self.cache.drop_all()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
