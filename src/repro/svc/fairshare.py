"""Weighted fair-share allocation of executor-invocation slots across
tenants (docs/multi_tenant.md).

The solo engine bounds launches with ``FlintConfig.concurrency`` alone —
its thread pool IS the capacity. The service runs MANY jobs over one
account, so the account's invocation capacity becomes a first-class
shared resource: a ``FairSharePool`` of slots, leased per job through
``JobSlots`` handles that plug into the scheduler's ``_NullSlots``
protocol (try_acquire / acquire / release / set_demand / contended /
wait / detach).

Allocation is weighted MAX-MIN: a tenant may take a slot only while no
OTHER tenant with unmet demand sits at a strictly lower held/weight
ratio (integer cross-multiplication — no float drift). The rule is
work-conserving: with a single demanding tenant every slot is
grantable; denial only happens in favor of a concrete lower-share
tenant, which the scheduler's short contended-mode wakeups let claim
the slot within one poll interval.

Liveness notes (why this cannot deadlock):

  * the pipelined scheduler CARRIES slots across retries and chained
    continuations, so in-flight producer work never re-enters the
    scramble behind other tenants' blocked consumers;
  * lineage-recovery replays bypass slots entirely — a replay must not
    starve behind the very consumers waiting for its output;
  * ``set_demand`` advertises only EFFECTIVE demand (launchable now),
    so a tenant whose local pool is saturated does not pin the global
    pool idle;
  * ``detach`` (scheduler shutdown, including failure paths) returns
    everything a job still holds.
"""

from __future__ import annotations

import threading


class JobSlots:
    """One job's lease on a FairSharePool — the scheduler-facing handle.
    Slot accounting is per-lease, fairness accounting per-tenant (all of
    a tenant's concurrent jobs draw from the tenant's one share)."""

    def __init__(self, pool: "FairSharePool", tenant: str):
        self.pool = pool
        self.tenant = tenant
        self.held = 0      # slots this lease holds
        self.demand = 0    # launchable-now tasks wanting a slot
        self.waiting = 0   # threads blocked in acquire() (barrier mode)
        self.detached = False

    # ------------------------------------------- scheduler-facing protocol
    def try_acquire(self) -> bool:
        pool = self.pool
        with pool._cond:
            if self.detached or not pool._grantable(self.tenant):
                pool.denials += 1
                return False
            self._take()
            return True

    def acquire(self):
        """Blocking acquire (barrier mode, called inside worker threads —
        safe there because barrier-stage inputs are complete). Returns on
        detach too, so a shut-down job never wedges its pool threads."""
        pool = self.pool
        with pool._cond:
            self.waiting += 1
            try:
                while not self.detached and not pool._grantable(self.tenant):
                    pool._cond.wait(0.1)
            finally:
                self.waiting -= 1
            if not self.detached:
                self._take()

    def release(self):
        pool = self.pool
        with pool._cond:
            if self.held > 0:
                self.held -= 1
                pool._held[self.tenant] -= 1
                pool._cond.notify_all()

    def set_demand(self, n: int):
        pool = self.pool
        with pool._cond:
            if n != self.demand:
                self.demand = n
                # falling demand can make OTHER tenants grantable
                pool._cond.notify_all()

    def contended(self) -> bool:
        """True while any other lease wants slots — the scheduler
        shortens its event-loop wait so releases redistribute fast."""
        pool = self.pool
        with pool._cond:
            return any(ls is not self and (ls.demand or ls.waiting)
                       for ls in pool._leases)

    def wait(self, timeout: float):
        """Block (bounded) until a slot could be grantable — the
        slot-starved idle path of the pipelined event loop."""
        pool = self.pool
        with pool._cond:
            if not self.detached and not pool._grantable(self.tenant):
                pool._cond.wait(timeout)

    def detach(self):
        """Job over (success or failure): return every slot still held,
        drop demand, unblock any waiter. Idempotent."""
        pool = self.pool
        with pool._cond:
            if self.detached:
                return
            self.detached = True
            if self.held:
                pool._held[self.tenant] -= self.held
                self.held = 0
            self.demand = 0
            pool._leases.discard(self)
            pool._cond.notify_all()

    # ------------------------------------------------------------ internal
    def _take(self):
        """Caller holds the pool lock and verified grantability."""
        pool = self.pool
        self.held += 1
        pool._held[self.tenant] = pool._held.get(self.tenant, 0) + 1
        pool.grants += 1
        total = sum(pool._held.values())
        if total > pool.peak_held:
            pool.peak_held = total


class FairSharePool:
    """The service-wide slot pool. ``capacity`` models the account's
    concurrent-invocation budget the service chooses to spend; tenant
    ``weight`` skews the max-min split (weight 2 deserves twice the
    slots of weight 1 under contention)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("FairSharePool capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._weights: dict[str, int] = {}
        self._held: dict[str, int] = {}
        self._leases: set[JobSlots] = set()
        self.grants = 0
        self.denials = 0
        self.peak_held = 0

    def set_weight(self, tenant: str, weight: int):
        if weight < 1:
            raise ValueError("tenant weight must be >= 1")
        with self._cond:
            self._weights[tenant] = weight
            self._cond.notify_all()

    def lease(self, tenant: str) -> JobSlots:
        ls = JobSlots(self, tenant)
        with self._cond:
            self._leases.add(ls)
        return ls

    def held(self, tenant: str | None = None) -> int:
        with self._cond:
            if tenant is not None:
                return self._held.get(tenant, 0)
            return sum(self._held.values())

    # ------------------------------------------------------------ internal
    def _grantable(self, tenant: str) -> bool:
        """Caller holds the lock. Weighted max-min: grant unless some
        OTHER tenant with unmet demand holds a strictly smaller
        normalized share — that tenant claims the slot first."""
        if sum(self._held.values()) >= self.capacity:
            return False
        ht = self._held.get(tenant, 0)
        wt = self._weights.get(tenant, 1)
        for ls in self._leases:
            o = ls.tenant
            if o == tenant or not (ls.demand or ls.waiting):
                continue
            if ht * self._weights.get(o, 1) > self._held.get(o, 0) * wt:
                return False
        return True
