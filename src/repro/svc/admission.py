"""Admission control for the multi-tenant query service
(docs/multi_tenant.md).

Job submission passes through one gate before any planning happens:

  * tenants already over a quota (dollar budget spent, retry budget
    exhausted) are REJECTED outright — running them would only burn
    the shared pool to hit the same wall mid-job;
  * up to ``max_running`` jobs execute concurrently (the fair-share
    pool then splits invocation slots among them);
  * the next ``max_queued`` submissions WAIT at the gate;
  * anything beyond that is rejected with a structured
    ``AdmissionRejected`` the client can branch on (back off and
    resubmit vs. give up), never an opaque timeout.

Rejection is an exception rather than a status code so a session's
``collect()`` call site fails loudly — a serverless driver has no
partially-started state to clean up at this point, by construction.
"""

from __future__ import annotations

import threading


class AdmissionRejected(RuntimeError):
    """A job was refused at the service gate. ``reason`` is "capacity"
    (running + queued limits are both full) or "quota" (the tenant's
    own budget is spent); ``detail`` carries the numbers."""

    def __init__(self, msg: str, *, reason: str, tenant: str,
                 detail: dict | None = None):
        super().__init__(msg)
        self.reason = reason
        self.tenant = tenant
        self.detail = detail or {}


class AdmissionController:
    def __init__(self, max_running: int = 8, max_queued: int = 16):
        if max_running < 1:
            raise ValueError("max_running must be >= 1")
        if max_queued < 0:
            raise ValueError("max_queued must be >= 0")
        self.max_running = max_running
        self.max_queued = max_queued
        self._cond = threading.Condition()
        self.running = 0
        self.queued = 0
        self.stats = {"admitted": 0, "queued": 0, "rejected_capacity": 0,
                      "rejected_quota": 0, "peak_running": 0,
                      "peak_queued": 0}

    def admit(self, tenant: str, quota_check=None):
        """Block until the job may start (or raise AdmissionRejected).
        ``quota_check`` is a callable returning an error string when the
        tenant is over budget — checked at submission AND again after
        any queueing wait (budgets drain while a job waits)."""
        with self._cond:
            self._quota_gate(tenant, quota_check)
            if self.running >= self.max_running:
                if self.queued >= self.max_queued:
                    self.stats["rejected_capacity"] += 1
                    raise AdmissionRejected(
                        f"service at capacity: {self.running} running, "
                        f"{self.queued} queued (max_queued="
                        f"{self.max_queued}) — resubmit later",
                        reason="capacity", tenant=tenant,
                        detail={"running": self.running,
                                "queued": self.queued})
                self.queued += 1
                self.stats["queued"] += 1
                self.stats["peak_queued"] = max(self.stats["peak_queued"],
                                                self.queued)
                try:
                    while self.running >= self.max_running:
                        self._cond.wait(0.05)
                finally:
                    self.queued -= 1
                self._quota_gate(tenant, quota_check)
            self.running += 1
            self.stats["admitted"] += 1
            self.stats["peak_running"] = max(self.stats["peak_running"],
                                             self.running)

    def release(self):
        with self._cond:
            self.running -= 1
            self._cond.notify_all()

    def _quota_gate(self, tenant: str, quota_check):
        msg = quota_check() if quota_check is not None else None
        if msg:
            self.stats["rejected_quota"] += 1
            raise AdmissionRejected(msg, reason="quota", tenant=tenant)
