"""Mamba2 (state-space duality) blocks — chunked scan for train/prefill,
recurrent state update for decode.

The chunked form computes intra-chunk interactions as attention-like
matmuls (MXU-friendly) and carries a (H, P, N) state across chunks with a
sequential `lax.scan`; decode carries the same state token-to-token, which
is what makes `long_500k` a fixed-memory cell for SSM/hybrid archs.

Projections are kept separate (z / x / BC / dt) rather than fused so the
tensor-parallel shard boundaries never cut through a logical split: the
wide d_inner tensors shard on 'model' head-aligned, while the small B/C/dt
projections replicate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import P
from repro.configs.base import ModelConfig

NEG_INF = -1e30


def mamba2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_state


def mamba2_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, n = mamba2_dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "w_z": P((d, di), ("w_embed", "w_mlp")),
        "w_x": P((d, di), ("w_embed", "w_mlp")),
        "w_bc": P((d, 2 * n), ("w_embed", None)),
        "w_dt": P((d, h), ("w_embed", None)),
        "conv_x_w": P((w, di), (None, "w_mlp"), scale=0.5),
        "conv_x_b": P((di,), ("w_mlp",), "zeros"),
        "conv_bc_w": P((w, 2 * n), (None, None), scale=0.5),
        "conv_bc_b": P((2 * n,), (None,), "zeros"),
        "a_log": P((h,), (None,), "ones"),
        "d_skip": P((h,), (None,), "ones"),
        "dt_bias": P((h,), (None,), "zeros"),
        "norm": P((di,), ("w_mlp",), "ones"),
        "w_out": P((di, d), ("w_mlp", "w_embed")),
    }


def _projections(params, u):
    z = jnp.einsum("bsd,de->bse", u, params["w_z"].astype(u.dtype))
    x = jnp.einsum("bsd,de->bse", u, params["w_x"].astype(u.dtype))
    bc = jnp.einsum("bsd,de->bse", u, params["w_bc"].astype(u.dtype))
    dt = jnp.einsum("bsd,de->bse", u, params["w_dt"].astype(u.dtype))
    return z, x, bc, dt


def causal_conv(w, b, x, conv_state=None):
    """Depthwise causal conv over time + silu. x: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    if conv_state is not None:  # decode: (B, W-1, C) rolling buffer
        window = jnp.concatenate([conv_state, x], axis=1)  # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
        return jax.nn.silu(out + b), window[:, 1:, :]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b), None


def _gated_norm(params, y, z, eps):
    dtype = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + eps) * params["norm"].astype(jnp.float32)
    return y.astype(dtype)


def mamba2_apply(params, u, cfg: ModelConfig, return_state: bool = False):
    """Chunked SSD. u: (B, S, d) -> (B, S, d)."""
    b, s, _ = u.shape
    di, nh, n = mamba2_dims(cfg)
    p = cfg.ssm_head_dim
    lc = min(cfg.ssm_chunk, s)
    while s % lc:
        lc //= 2
    nc = s // lc

    z, xr, bcr, dt = _projections(params, u)
    x, _ = causal_conv(params["conv_x_w"].astype(u.dtype),
                       params["conv_x_b"].astype(u.dtype), xr)
    bc, _ = causal_conv(params["conv_bc_w"].astype(u.dtype),
                        params["conv_bc_b"].astype(u.dtype), bcr)
    x = x.reshape(b, s, nh, p)
    bm, cm = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (h,)
    la = dt * a[None, None, :]  # (b, s, h) log-decay per step

    # chunk views
    xc = x.reshape(b, nc, lc, nh, p)
    bcn = bm.reshape(b, nc, lc, n)
    ccn = cm.reshape(b, nc, lc, n)
    dtc = dt.reshape(b, nc, lc, nh)
    lac = la.reshape(b, nc, lc, nh)
    acs = jnp.cumsum(lac, axis=2)  # (b, nc, lc, h) decay from chunk start (incl.)

    # ---- intra-chunk (quadratic within chunk, matmul form)
    cb = jnp.einsum("bcin,bcjn->bcij", ccn, bcn).astype(jnp.float32)
    seg = acs[:, :, :, None, :] - acs[:, :, None, :, :]  # (b,nc,i,j,h)
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    w_intra = cb[..., None] * m * dtc[:, :, None, :, :]  # (b,nc,i,j,h)
    y = jnp.einsum("bcijh,bcjhp->bcihp", w_intra.astype(u.dtype), xc)

    # ---- chunk-final states and cross-chunk recurrence
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs)  # (b,nc,lc,h)
    states = jnp.einsum(
        "bclh,bclh,bclhp,bcln->bchpn",
        decay_to_end.astype(u.dtype), dtc.astype(u.dtype), xc, bcn,
    )
    chunk_decay = jnp.exp(acs[:, :, -1, :]).astype(u.dtype)  # (b, nc, h)

    def step(carry, xs):
        st_in = carry  # (b, h, p, n)
        dec, st_c = xs  # (b, h), (b, h, p, n)
        st_out = st_in * dec[:, :, None, None] + st_c
        return st_out, st_in

    init = jnp.zeros((b, nh, p, n), u.dtype)
    final_state, states_in = jax.lax.scan(
        step, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # ---- cross-chunk contribution: state entering the chunk, decayed to i
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp",
        ccn, jnp.exp(acs).astype(u.dtype), states_in,
    )
    y = y + y_inter

    y = y + params["d_skip"].astype(u.dtype)[None, None, :, None] * xc
    y = y.reshape(b, s, di)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(u.dtype))
    if return_state:
        w = cfg.ssm_conv_width
        cache = {"state": final_state.astype(jnp.float32),
                 "conv_x": xr[:, s - (w - 1):, :],
                 "conv_bc": bcr[:, s - (w - 1):, :]}
        return out, cache
    return out


def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, nh, n = mamba2_dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_bc": jnp.zeros((batch, w - 1, 2 * n), dtype),
    }


def mamba2_decode(params, u, cache, cfg: ModelConfig):
    """One-token recurrent step. u: (B, 1, d)."""
    b = u.shape[0]
    di, nh, n = mamba2_dims(cfg)
    p = cfg.ssm_head_dim
    z, xr, bcr, dt = _projections(params, u)
    x, conv_x = causal_conv(params["conv_x_w"].astype(u.dtype),
                            params["conv_x_b"].astype(u.dtype), xr,
                            conv_state=cache["conv_x"])
    bc, conv_bc = causal_conv(params["conv_bc_w"].astype(u.dtype),
                              params["conv_bc_b"].astype(u.dtype), bcr,
                              conv_state=cache["conv_bc"])
    x = x.reshape(b, nh, p)
    bm = bc[:, 0, :n]
    cm = bc[:, 0, n:]
    dt = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (b, h)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a[None, :])  # (b, h)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x.astype(jnp.float32),
                     bm.astype(jnp.float32))
    state = cache["state"] * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), state)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(u.dtype))
    return y, {"state": state, "conv_x": conv_x, "conv_bc": conv_bc}
