"""Shared layer primitives: norms, RoPE, SwiGLU MLP, embeddings.

All layers follow the same convention: ``<layer>_schema(cfg) -> {name: P}``
and ``<layer>(params, x, ...) -> y``.  Weights use logical axis names that
:mod:`repro.runtime.sharding` resolves to mesh axes:

  w_embed   — the d_model dim of big weights (FSDP-sharded on 'data')
  w_vocab   — vocab dim (TP on 'model')
  w_heads / w_kv_heads / w_mlp / w_experts — TP/EP dims (on 'model')
  None      — replicated
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import P
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------- norms


def rmsnorm_schema(dim: int) -> dict:
    return {"scale": P((dim,), (None,), "ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_heads(scale, x, eps: float = 1e-5):
    """Per-head qk-norm (qwen3): x is (..., head_dim), scale (head_dim,)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with even D; positions: (B, S) int32.

    Angles/cos/sin are computed in f32 (large positions), but the rotation
    itself runs in x's dtype: an f32 rotation leaks f32 cotangents into
    every attention-weight gradient downstream (measured: f32 dW_qkv
    all-reduces on command-r-plus), doubling gradient-reduction bytes.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # (B, S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


# ---------------------------------------------------------------- MLP


def swiglu_schema(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    return {
        "w_gate": P((d, f), ("w_embed", "w_mlp")),
        "w_up": P((d, f), ("w_embed", "w_mlp")),
        "w_down": P((f, d), ("w_mlp", "w_embed")),
    }


def swiglu(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))


# ---------------------------------------------------------------- embedding


def embedding_schema(cfg: ModelConfig) -> dict:
    # rows padded to a shardable count (cfg.padded_vocab); ids never index
    # the padding, and unembed slices logits back to vocab_size.
    return {"table": P((cfg.padded_vocab, cfg.d_model),
                       ("w_vocab", "w_embed"), "embed")}


def embed(params, tokens, cfg: ModelConfig):
    return params["table"].astype(cfg.cdtype)[tokens]


def unembed_schema(cfg: ModelConfig) -> dict:
    return {"w_out": P((cfg.d_model, cfg.padded_vocab),
                       ("w_embed", "w_vocab"))}


def unembed(params, x, cfg: ModelConfig):
    # bf16 operands with f32 accumulation: logits stay f32 for a stable
    # softmax at large vocab, but the (huge, FSDP-gathered) vocab matrix
    # moves at bf16 width instead of being upcast before the matmul
    logits = jnp.einsum("bsd,dv->bsv", x, params["w_out"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits
