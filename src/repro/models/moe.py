"""Mixture-of-Experts with GShard-style grouped dispatch.

This is the in-model analogue of Flint's queue shuffle (DESIGN.md C2):
tokens are messages, experts are partitions, the capacity factor is the
queue's bounded buffer (overflow tokens are dropped and carried by the
residual — exactly the overflow-flush semantics of the paper's executors),
and the dispatch/combine einsums lower to `all_to_all` on the ICI when the
expert dim is sharded on the 'model' mesh axis (EP).

Two expert-compute paths:
  * einsum — dispatch tensors + dense per-expert matmuls (GShard);
  * gmm    — expert-sorted grouped matmul backed by the Pallas kernel
             (TPU target; ref path on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import P
from repro.configs.base import ModelConfig
from repro.models.layers import swiglu, swiglu_schema


def moe_schema(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "w_router": P((d, e), ("w_embed", None), scale=0.02),
        # expert-internal width gets its own logical axis: EP archs (deepseek)
        # shard the expert dim and replicate f; TP-in-expert archs (mixtral,
        # 8 experts < mesh model size) replicate experts and shard f.
        "w_gate": P((e, d, f), ("w_experts", "w_embed", "w_expert_mlp")),
        "w_up": P((e, d, f), ("w_experts", "w_embed", "w_expert_mlp")),
        "w_down": P((e, f, d), ("w_experts", "w_expert_mlp", "w_embed")),
    }
    if cfg.n_shared_experts:
        s["shared"] = swiglu_schema(cfg, cfg.n_shared_experts * cfg.moe_d_ff)
    return s


def _router(params, x, cfg: ModelConfig):
    """x: (..., d) -> (gates, idx) both (..., top_k); gates f32.

    bf16 inputs with f32 accumulation: casting x to f32 first makes GSPMD
    move f32 activations (2x the bytes) when it reshards around the router.
    """
    logits = jnp.einsum("...d,de->...e", x, params["w_router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return probs, gates, idx


def _aux_loss(probs, idx, cfg: ModelConfig):
    """Switch/GShard load-balancing loss."""
    e = cfg.n_experts
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    chosen = jax.nn.one_hot(idx.reshape(-1, idx.shape[-1]), e).sum(1)
    ce = jnp.mean(chosen, axis=0) / cfg.top_k
    return e * jnp.sum(me * ce)


def _capacity(group_len: int, cfg: ModelConfig) -> int:
    c = int(group_len * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    # keep MXU-friendly and never below top_k
    return max(cfg.top_k, -(-c // 8) * 8)


def moe_apply(params, x, cfg: ModelConfig, group_size: int = 1024):
    """x: (B, S, d) -> (y, aux_loss).

    Tokens are flattened and re-grouped to bounded 'queues' of
    ``group_size`` so the dispatch one-hots stay O(T * k * cf * group_size)
    rather than O(T * S) — the bounded-buffer trick.
    """
    b, s, d = x.shape
    t = b * s
    gs = min(group_size, t)
    while t % gs:
        gs //= 2
    g = t // gs
    xt = x.reshape(g, gs, d)

    probs, gates, idx = _router(params, xt, cfg)  # (g, gs, k)
    aux = _aux_loss(probs, idx, cfg) * cfg.router_aux_coef

    e, cap = cfg.n_experts, _capacity(gs, cfg)
    # queue slot of each (token, k) assignment within its expert's queue.
    # top_k returns DISTINCT experts per token, so each (token, expert)
    # pair has at most one assignment and the k axis collapses to a 0/1
    # (g, gs, e) membership BEFORE the cumsum — keeping the routing state
    # O(T*e) (the (g, gs*k, e) form costs top_k x more bytes), and letting
    # the expert dim carry EP sharding through the whole dispatch chain.
    from repro.runtime.sharding import constrain
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (g, gs, k, e)
    onehot_se = onehot.sum(axis=2)  # (g, gs, e) in {0, 1}
    onehot_se = constrain(onehot_se, "act_group", None, "act_experts")
    pos_se = jnp.cumsum(onehot_se, axis=1) * onehot_se - 1  # (g, gs, e)
    gate_se = jnp.einsum("gsk,gske->gse", gates.astype(x.dtype),
                         onehot.astype(x.dtype))
    # one_hot of -1 (unrouted) or >=cap (queue overflow -> dropped) is all-0
    disp = jax.nn.one_hot(pos_se, cap, dtype=x.dtype)  # (g, gs, e, cap)
    disp = constrain(disp, "act_group", None, "act_experts", None)
    comb = disp * gate_se[..., None]
    drop_frac = 1.0 - jnp.sum(disp) / (g * gs * cfg.top_k)

    # dispatch: (g, e, cap, d) — this einsum is the all_to_all under EP
    ex_in = jnp.einsum("gsec,gsd->gecd", disp, xt)
    ex_in = constrain(ex_in, "act_group", "act_experts", None, None)
    if cfg.moe_impl == "gmm":
        ex_out = _experts_gmm(params, ex_in, cfg)
    else:
        ex_out = _experts_einsum(params, ex_in, cfg)
    y = jnp.einsum("gsec,gecd->gsd", comb, ex_out)  # combine (all_to_all back)

    if cfg.n_shared_experts:
        y = y + swiglu(params["shared"], xt)
    return y.reshape(b, s, d), aux, drop_frac


def _experts_einsum(params, ex_in, cfg: ModelConfig):
    """ex_in: (g, e, cap, d) -> (g, e, cap, d); dense per-expert SwiGLU."""
    wg = params["w_gate"].astype(ex_in.dtype)
    wu = params["w_up"].astype(ex_in.dtype)
    wd = params["w_down"].astype(ex_in.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in, wg))
    h = h * jnp.einsum("gecd,edf->gecf", ex_in, wu)
    return jnp.einsum("gecf,efd->gecd", h, wd)


def _experts_gmm(params, ex_in, cfg: ModelConfig):
    """Grouped-matmul expert compute (Pallas kernel on TPU, ref on CPU)."""
    from repro.kernels import ops as kops
    g, e, cap, d = ex_in.shape
    flat = ex_in.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    sizes = jnp.full((e,), g * cap, jnp.int32)
    h = jax.nn.silu(kops.grouped_matmul(flat, params["w_gate"].astype(flat.dtype), sizes))
    h = h * kops.grouped_matmul(flat, params["w_up"].astype(flat.dtype), sizes)
    out = kops.grouped_matmul(h, params["w_down"].astype(flat.dtype), sizes)
    return out.reshape(e, g, cap, d).transpose(1, 0, 2, 3)


def moe_decode(params, x, cfg: ModelConfig):
    """Decode-time MoE on a (B, 1, d) token batch: tiny T, single group,
    generous capacity so nothing is dropped mid-generation."""
    b, s, d = x.shape
    xt = x.reshape(1, b * s, d)
    probs, gates, idx = _router(params, xt, cfg)
    e = cfg.n_experts
    cap = max(cfg.top_k, min(b * s, -(-b * s * cfg.top_k * 2 // e) // 8 * 8 + 8))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)
    flatoh = onehot.reshape(1, -1, e)
    pos_se = (jnp.cumsum(flatoh, axis=1) * flatoh - 1).reshape(
        1, b * s, cfg.top_k, e).max(axis=2)
    gate_se = jnp.einsum("gsk,gske->gse", gates.astype(x.dtype),
                         onehot.astype(x.dtype))
    disp = jax.nn.one_hot(pos_se, cap, dtype=x.dtype)
    comb = disp * gate_se[..., None]
    ex_in = jnp.einsum("gsec,gsd->gecd", disp, xt)
    ex_out = _experts_einsum(params, ex_in, cfg)
    y = jnp.einsum("gsec,gecd->gsd", comb, ex_out)
    if cfg.n_shared_experts:
        y = y + swiglu(params["shared"], xt)
    return y.reshape(b, s, d)
