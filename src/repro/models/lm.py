"""Top-level LM API: schema/init, loss (chunked CE), prefill, decode.

Pure functions over param pytrees; everything works under ``jax.eval_shape``
so the multi-pod dry-run never allocates.

Input batch conventions (matching ``launch.specs.input_specs``):
  * LM:      {"tokens": (B, S) int32}
  * VLM:     {"frontend": (B, F, d) cdtype, "tokens": (B, S-F) int32}
  * enc-dec: {"enc_embeds": (B, Se, d) cdtype, "tokens": (B, Sd) int32}
Decode:      token (B, 1) int32, pos scalar int32, caches pytree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import (embed, embedding_schema, rmsnorm,
                                 rmsnorm_schema, unembed, unembed_schema)

# tokens per CE chunk are sized so B*chunk*vocab stays bounded (~8G f32
# elements globally, ~134 MB/chip on the production mesh) — big vocabs never
# materialize (B, S, V), while chunks stay large enough that the per-chunk
# re-gather of the (sharded) unembed weight amortizes (a 2^29 budget cost
# command-r-plus 585 gathers of the f32 vocab matrix per step).
_CE_BUDGET = 1 << 33


def lm_schema(cfg: ModelConfig) -> dict:
    s = {"embed": embedding_schema(cfg), "stack": tfm.stack_schema_for(cfg),
         "ln_f": rmsnorm_schema(cfg.d_model)}
    if cfg.is_enc_dec:
        s["encoder"] = {
            "blocks": pm.stack_schema(
                tfm.decoder_block_schema(cfg, use_moe=False),
                cfg.encoder_layers),
            "ln_f": rmsnorm_schema(cfg.d_model),
        }
    if not cfg.tie_embeddings:
        s["unembed"] = unembed_schema(cfg)
    return s


def init(cfg: ModelConfig, key) -> dict:
    return pm.init_params(lm_schema(cfg), key, cfg.pdtype)


def abstract(cfg: ModelConfig) -> dict:
    return pm.abstract_params(lm_schema(cfg), cfg.pdtype)


def n_params(cfg: ModelConfig) -> int:
    return pm.param_count(lm_schema(cfg))


# ----------------------------------------------------------------- fwd


def _encode(params, batch, cfg: ModelConfig, attn_impl):
    enc = batch["enc_embeds"].astype(cfg.cdtype)
    pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), enc.shape[:2])

    def body(lp, x, i, extra):
        x, _, a, d = tfm.decoder_block_apply(lp, x, pos, cfg, use_moe=False,
                                             causal=False, attn_impl=attn_impl)
        return x, a, d, extra

    x, _, _, _ = tfm._scan_apply(body, params["encoder"]["blocks"], enc,
                                 cfg.encoder_layers, cfg)
    return rmsnorm(params["encoder"]["ln_f"], x, cfg.norm_eps), pos


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Returns (x, positions, n_prefix) where n_prefix = frontend positions
    carrying no next-token loss."""
    tok = embed(params["embed"], batch["tokens"], cfg)
    if cfg.frontend and "frontend" in batch:
        front = batch["frontend"].astype(cfg.cdtype)
        x = jnp.concatenate([front, tok], axis=1)
        n_prefix = front.shape[1]
    else:
        x, n_prefix = tok, 0
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    return x, pos, n_prefix


def hidden_states(params, batch, cfg: ModelConfig, attn_impl="auto"):
    """Full-sequence hidden states. Returns (h, n_prefix, aux, drop)."""
    memory = memory_pos = None
    if cfg.is_enc_dec:
        memory, memory_pos = _encode(params, batch, cfg, attn_impl)
    x, pos, n_prefix = _embed_inputs(params, batch, cfg)
    x, aux, drop = tfm.apply_stack(params["stack"], x, pos, cfg,
                                   memory=memory, memory_positions=memory_pos,
                                   attn_impl=attn_impl)
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), n_prefix, aux, drop


def _unembed_params(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {"w_out": params["embed"]["table"].T}
    return params["unembed"]


def forward(params, batch, cfg: ModelConfig, attn_impl="auto"):
    """Full logits — smoke tests / tiny models only (materializes (B,S,V))."""
    h, n_prefix, aux, drop = hidden_states(params, batch, cfg, attn_impl)
    return unembed(_unembed_params(params, cfg), h, cfg), n_prefix, aux, drop


def _chunked_ce(uparams, h, labels, cfg: ModelConfig):
    """Cross-entropy without materializing (B, S, V). labels < 0 are masked."""
    b, t, _ = h.shape
    chunk = max(1, min(t, _CE_BUDGET // max(1, b * cfg.vocab_size)))
    while t % chunk:
        chunk -= 1
    nc = t // chunk
    hc = h.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute the (b, chunk, V) logits block in backward
    def body(carry, xs):
        hx, lx = xs
        logits = unembed(uparams, hx, cfg)  # (b, chunk, V) f32
        lz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        mask = (lx >= 0).astype(jnp.float32)
        loss, cnt = carry
        return (loss + jnp.sum((lz - ll) * mask), cnt + jnp.sum(mask)), None

    (loss, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                  (hc, lc))
    return loss / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, attn_impl="auto"):
    """Next-token CE (+ MoE aux). Returns (loss, metrics)."""
    h, n_prefix, aux, drop = hidden_states(params, batch, cfg, attn_impl)
    tokens = batch["tokens"]
    # predictions for token i come from hidden state at position n_prefix+i-1
    start = n_prefix  # first token position in the packed sequence
    if start:
        h_pred = jax.lax.slice_in_dim(h, start - 1, h.shape[1] - 1, axis=1)
        labels = tokens
    else:
        h_pred, labels = h[:, :-1], tokens[:, 1:]
    if "loss_mask" in batch:
        m = batch["loss_mask"] if start else batch["loss_mask"][:, 1:]
        labels = jnp.where(m > 0, labels, -1)
    ce = _chunked_ce(_unembed_params(params, cfg), h_pred, labels, cfg)
    loss = ce + aux
    return loss, {"ce": ce, "moe_aux": aux, "moe_drop_frac": drop}


# ------------------------------------------------------------- serving


def prefill(params, batch, cfg: ModelConfig, attn_impl="auto"):
    """Returns (last_token_logits (B, V), caches)."""
    memory = memory_pos = None
    if cfg.is_enc_dec:
        memory, memory_pos = _encode(params, batch, cfg, attn_impl)
    x, pos, _ = _embed_inputs(params, batch, cfg)
    x, caches = tfm.prefill_stack(params["stack"], x, pos, cfg,
                                  memory=memory, memory_positions=memory_pos,
                                  attn_impl=attn_impl)
    h = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = unembed(_unembed_params(params, cfg), h, cfg)
    return logits[:, 0], caches


def init_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    return tfm.init_stack_cache(cfg, batch, max_len, cfg.cdtype,
                                enc_len=enc_len)


def decode_step(params, token, pos, caches, cfg: ModelConfig, *, kv_len: int):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits (B,V), caches)."""
    x = embed(params["embed"], token, cfg)
    x, caches = tfm.decode_stack(params["stack"], x, caches, pos, cfg,
                                 kv_len=kv_len)
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(_unembed_params(params, cfg), h, cfg)
    return logits[:, 0], caches


def generate(params, batch, cfg: ModelConfig, n_steps: int, *,
             temperature: float = 0.0, key=None):
    """Greedy/sampled generation driven by lax.scan (for tests/examples)."""
    logits, caches = prefill(params, batch, cfg)
    start = batch["tokens"].shape[1] + (
        batch["frontend"].shape[1] if (cfg.frontend and "frontend" in batch)
        else 0)
    kv_len = start + n_steps

    # prefill caches have length `start` (or the SWA window); decode needs
    # room for n_steps more — grow along the time axis where applicable.
    caches = _grow_caches(caches, cfg, kv_len)

    def pick(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok0 = pick(logits, key)

    def body(carry, i):
        tok, caches, key = carry
        key, sub = jax.random.split(key)
        logits, caches = decode_step(params, tok[:, None], start + i, caches,
                                     cfg, kv_len=kv_len)
        nxt = pick(logits, sub)
        return (nxt, caches, key), nxt

    (_, caches, _), toks = jax.lax.scan(
        body, (tok0, caches, key), jnp.arange(n_steps - 1))
    return jnp.concatenate([tok0[:, None], toks.T], axis=1)


def _grow_caches(caches, cfg: ModelConfig, kv_len: int):
    """Pad attention caches along their time axis up to kv_len (no-op for
    state caches and rolling SWA windows)."""

    def grow(path, a):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        leaf = names[-1] if names else ""
        if "cross" in names:  # encoder memory is fixed-length — never grow
            return a
        if leaf in ("k", "v") and a.ndim == 5:  # (L, B, S, K, D) stacked
            s = a.shape[2]
            tgt = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
            if s < tgt:
                padding = [(0, 0)] * a.ndim
                padding[2] = (0, tgt - s)
                return jnp.pad(a, padding)
        if leaf in ("k", "v") and a.ndim == 4:  # unstacked (B, S, K, D)
            s = a.shape[1]
            tgt = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
            if s < tgt:
                padding = [(0, 0)] * a.ndim
                padding[1] = (0, tgt - s)
                return jnp.pad(a, padding)
        if cfg.attn_type == "mla" and leaf in ("c", "k_rope"):
            axis = a.ndim - 2  # (L, B, S, R) stacked or (B, S, R) prefix
            s = a.shape[axis]
            if s < kv_len:
                padding = [(0, 0)] * a.ndim
                padding[axis] = (0, kv_len - s)
                return jnp.pad(a, padding)
        return a

    return jax.tree_util.tree_map_with_path(grow, caches)
