"""Attention variants: GQA (with qk-norm / bias / sliding window) and MLA.

Three execution paths per variant:

* ``full``    — materialized scores; used for short sequences (train_4k).
* ``chunked`` — pure-JAX online-softmax scan over KV chunks; memory O(S*C)
                instead of O(S^2); used for long prefill in the dry-run and
                anywhere Pallas is unavailable (CPU hosts).
* ``pallas``  — the flash-attention TPU kernel in repro/kernels (TPU target;
                validated under interpret=True in tests).

Decode reads a cache: GQA caches (k, v); MLA caches the 512-d latent +
shared rope key (the paper-era "cache the compressed thing" optimization),
with an optional weight-absorbed score path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import P
from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rmsnorm_heads

NEG_INF = -1e30


# =================================================================== GQA


def gqa_schema(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "w_q": P((d, h, hd), ("w_embed", "w_heads", None)),
        "w_k": P((d, k, hd), ("w_embed", "w_kv_heads", None)),
        "w_v": P((d, k, hd), ("w_embed", "w_kv_heads", None)),
        "w_o": P((h, hd, d), ("w_heads", None, "w_embed")),
    }
    if cfg.attn_bias:
        s["b_q"] = P((h, hd), ("w_heads", None), "zeros")
        s["b_k"] = P((k, hd), ("w_kv_heads", None), "zeros")
        s["b_v"] = P((k, hd), ("w_kv_heads", None), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), (None,), "ones")
        s["k_norm"] = P((hd,), (None,), "ones")
    del cross
    return s


def _project_qkv(params, x, kv_x, cfg: ModelConfig, q_pos, kv_pos):
    """Project + (optionally) bias/norm/rope q, k, v."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bsd,dke->bske", kv_x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", kv_x, params["w_v"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + params["b_q"].astype(x.dtype)
        k = k + params["b_k"].astype(x.dtype)
        v = v + params["b_v"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm_heads(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_heads(params["k_norm"], k, cfg.norm_eps)
    if q_pos is not None:  # rope (self-attention); cross-attn passes None
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos, kv_pos, causal: bool, window: int) -> jax.Array:
    """(B, Sq, Skv) additive mask. q_pos/kv_pos: (B, S)."""
    d = q_pos[:, :, None] - kv_pos[:, None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_full(q, k, v, mask_bias):
    """q:(B,Sq,H,D) k:(B,Skv,K,D) v:(B,Skv,K,Dv); grouped-query attention.
    Dv may differ from D (MLA)."""
    b, sq, h, dh = q.shape
    kk = k.shape[2]
    g = h // kk
    q = q.reshape(b, sq, kk, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    scores = scores + mask_bias[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, sq, h, v.shape[-1])


def _sdpa_chunked(q, k, v, q_pos, kv_pos, causal, window, chunk=512):
    """Memory-efficient attention: scan over QUERY chunks with per-step
    remat. K/V are loop-invariant (saved once); each step materializes only
    a (B, heads, chunk, Skv) score block and recomputes it in the backward
    pass — flash-attention memory semantics in pure JAX, with no
    O(S^2/chunk) stacked scan carries."""
    b, sq, h, dh = q.shape
    dv = v.shape[-1]
    kk = k.shape[2]
    g = h // kk
    nchunks = -(-sq // chunk)
    pad = nchunks * chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-(10**9))
    qc = q.reshape(b, nchunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(b, nchunks, chunk).transpose(1, 0, 2)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    @jax.checkpoint
    def step(carry, xs):
        qb, pb = xs  # (B, C, H, D), (B, C)
        qg = qb.reshape(b, chunk, kk, g, dh)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
        d = pb[:, None, None, :, None] - kv_pos[:, None, None, None, :]
        ok = jnp.ones(d.shape, bool)
        if causal:
            ok &= d >= 0
        if window:
            ok &= d < window
        s = jnp.where(ok, s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v.dtype), v)
        out = pv.astype(jnp.float32) / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4).reshape(
            b, chunk, h, dv).astype(qb.dtype)

    _, out = jax.lax.scan(step, (), (qc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, h, dv)
    return out[:, :sq]


# chunked path kicks in above this many KV positions (keeps train_4k on the
# fused-friendly full path, forces prefill_32k+ onto O(S*C) memory).
CHUNKED_THRESHOLD = 8_192


def gqa_attend(params, x, cfg: ModelConfig, *, positions, causal=True,
               kv_x=None, kv_positions=None, attn_impl: str = "auto"):
    """Full-sequence (train/prefill) attention. Returns (out, kv) so callers
    may build a cache from kv."""
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(params, x, kv_x, cfg,
                           None if cross else positions,
                           None if cross else kv_positions)
    window = cfg.sliding_window
    skv = k.shape[1]
    if attn_impl == "auto":
        attn_impl = "chunked" if skv > CHUNKED_THRESHOLD else "full"
    if attn_impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal and not cross,
                                   window=window)
    elif attn_impl == "chunked":
        out = _sdpa_chunked(q, k, v, positions, kv_positions,
                            causal and not cross, window)
    else:
        mb = _mask_bias(positions, kv_positions, causal and not cross, window)
        out = _sdpa_full(q, k, v, mb)
    y = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return y, (k, v)


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """SWA archs roll a window-sized cache; full attention keeps max_len."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, k, hd), dtype),
        "v": jnp.zeros((batch, length, k, hd), dtype),
    }


def gqa_decode(params, x, cache, pos, cfg: ModelConfig, *, kv_len):
    """One-token decode. x: (B, 1, d). pos: scalar int32 current position.
    kv_len: static max positions represented in the cache."""
    q, k, v = _project_qkv(
        params, x, x, cfg,
        jnp.broadcast_to(pos, (x.shape[0], 1)),
        jnp.broadcast_to(pos, (x.shape[0], 1)),
    )
    length = cache["k"].shape[1]
    rolling = bool(cfg.sliding_window) and length < kv_len  # static
    slot = pos % length if rolling else jnp.minimum(pos, length - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # positions held in each cache slot (for masking): rolling for SWA.
    idx = jnp.arange(length)
    if rolling:
        base = pos - (pos % length)
        slot_pos = jnp.where(idx <= pos % length, base + idx, base - length + idx)
    else:
        slot_pos = idx
    valid = slot_pos <= pos
    if cfg.sliding_window:
        valid &= slot_pos > pos - cfg.sliding_window
    b, _, h, dh = q.shape
    kk = ck.shape[2]
    g = h // kk
    qg = q.reshape(b, 1, kk, g, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32)
    s = s / jnp.sqrt(dh).astype(jnp.float32)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cv).reshape(b, 1, h, dh)
    y = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


# =================================================================== MLA


def mla_schema(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d = cfg.resolved_head_dim, cfg.rope_head_dim
    vdim = cfg.resolved_v_head_dim
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    s = {
        # KV joint compression: d -> latent(r_kv) + shared rope key
        "w_dkv": P((d, r_kv + rope_d), ("w_embed", None)),
        "kv_norm": P((r_kv,), (None,), "ones"),
        "w_uk": P((r_kv, h, nope), (None, "w_heads", None)),
        "w_uv": P((r_kv, h, vdim), (None, "w_heads", None)),
        "w_o": P((h, vdim, d), ("w_heads", None, "w_embed")),
    }
    if r_q:
        s["w_dq"] = P((d, r_q), ("w_embed", None))
        s["q_norm"] = P((r_q,), (None,), "ones")
        s["w_uq"] = P((r_q, h, nope + rope_d), (None, "w_heads", None))
    else:
        s["w_q"] = P((d, h, nope + rope_d), ("w_embed", "w_heads", None))
    return s


def _mla_q(params, x, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm
    nope, rope_d = cfg.resolved_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(x.dtype))
        cq = rmsnorm({"scale": params["q_norm"]}, cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", cq, params["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    del rope_d
    return q_nope, q_rope


def _mla_latent(params, x, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm
    r_kv = cfg.kv_lora_rank
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(x.dtype))
    c, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
    c = rmsnorm({"scale": params["kv_norm"]}, c, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c, k_rope


def mla_attend(params, x, cfg: ModelConfig, *, positions, attn_impl="auto"):
    """Prefill/train MLA.

    The per-head key never materializes as concat(k_nope, rope(k)) — under
    TP that concat mixes a head-sharded tensor with a broadcast one and
    GSPMD reshards the full (B, S, H, D) key across 'model' (measured:
    ~1.2 TiB/device/step of all-gather on deepseek-v2 train_4k). Instead
    the score splits into two head-sharded einsums:
        q.k = q_nope . k_nope + q_rope . k_rope.
    """
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    c, k_rope = _mla_latent(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhe->bshe", c, params["w_uv"].astype(x.dtype))
    skv = k_nope.shape[1]
    if attn_impl == "auto":
        attn_impl = "chunked" if skv > CHUNKED_THRESHOLD else "full"
    if attn_impl == "chunked":
        # long prefill (forward-only): the concat costs one bf16 gather per
        # layer, while split-score inside the q-chunk scan reshards per
        # step — measured 3x worse on deepseek-v2 prefill_32k. The split
        # form wins where it matters: training, where the concat's f32
        # cotangent resharding dominates.
        h = cfg.n_heads
        q = jnp.concatenate([q_nope, q_rope], -1)
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    k_rope.shape[:2] + (h, k_rope.shape[-1]))
        k = jnp.concatenate([k_nope, k_rope_h], -1)
        out = _sdpa_chunked(q, k, v, positions, positions, True, 0)
    else:
        out = _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, positions,
                        cfg, chunked=False)
    y = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return y, (c, k_rope)


def _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, positions, cfg: ModelConfig,
              *, chunked: bool, chunk: int = 512):
    """Split-score MLA attention; rope key stays a (B, S, E) broadcast."""
    b, sq, h, dn = q_nope.shape
    scale = 1.0 / jnp.sqrt(dn + cfg.rope_head_dim).astype(jnp.float32)

    def block(qn, qr, pos_q):  # qn: (b, C, h, dn); attends over full kv
        s = jnp.einsum("bshd,bthd->bhst", qn, k_nope).astype(jnp.float32)
        s = s + jnp.einsum("bshe,bte->bhst", qr, k_rope).astype(jnp.float32)
        s = s * scale
        causal_ok = pos_q[:, None, :, None] >= positions[:, None, None, :]
        s = jnp.where(causal_ok, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, v)

    if not chunked:
        return block(q_nope, q_rope, positions)

    nchunks = -(-sq // chunk)
    pad = nchunks * chunk - sq
    if pad:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions_q = jnp.pad(positions, ((0, 0), (0, pad)),
                              constant_values=-(10**9))
    else:
        positions_q = positions
    qnc = q_nope.reshape(b, nchunks, chunk, h, dn).transpose(1, 0, 2, 3, 4)
    qrc = q_rope.reshape(b, nchunks, chunk, h, -1).transpose(1, 0, 2, 3, 4)
    pc = positions_q.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, xs):
        qn, qr, pq = xs
        return carry, block(qn, qr, pq)

    _, out = jax.lax.scan(step, (), (qnc, qrc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, h, -1)
    return out[:, :sq]


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
    }


def mla_decode(params, x, cache, pos, cfg: ModelConfig, *, absorb=True):
    """One-token MLA decode against the latent cache.

    ``absorb=True`` folds W_uk into the query and attends directly in latent
    space (never materializing per-head K/V for the whole cache) — DeepSeek's
    decode-time optimization; ``absorb=False`` is the naive expand path used
    as the §Perf baseline.
    """
    b = x.shape[0]
    posb = jnp.broadcast_to(pos, (b, 1))
    q_nope, q_rope = _mla_q(params, x, cfg, posb)
    c_new, kr_new = _mla_latent(params, x, cfg, posb)
    ck = jax.lax.dynamic_update_slice(cache["c"], c_new, (0, pos, 0))
    ckr = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))
    length = ck.shape[1]
    valid = jnp.arange(length) <= pos
    scale = 1.0 / jnp.sqrt(cfg.resolved_head_dim + cfg.rope_head_dim).astype(jnp.float32)
    if absorb:
        # q_lat[b,h,r] = q_nope . W_uk ; scores over latent cache directly
        q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, params["w_uk"].astype(x.dtype))
        s = jnp.einsum("bshr,btr->bhst", q_lat, ck).astype(jnp.float32)
        s = s + jnp.einsum("bshe,bte->bhst", q_rope, ckr).astype(jnp.float32)
        s = jnp.where(valid[None, None, None, :], s * scale, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", w, ck)
        out = jnp.einsum("bshr,rhe->bshe", o_lat, params["w_uv"].astype(x.dtype))
    else:
        k_nope = jnp.einsum("btr,rhe->bthe", ck, params["w_uk"].astype(x.dtype))
        v = jnp.einsum("btr,rhe->bthe", ck, params["w_uv"].astype(x.dtype))
        s = jnp.einsum("bshe,bthe->bhst", q_nope, k_nope).astype(jnp.float32)
        s = s + jnp.einsum("bshe,bte->bhst", q_rope, ckr).astype(jnp.float32)
        s = jnp.where(valid[None, None, None, :], s * scale, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthe->bshe", w, v)
    y = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return y, {"c": ck, "k_rope": ckr}
