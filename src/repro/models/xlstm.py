"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections).

mLSTM's stabilized parallel form has the flash-attention structure (running
max + rescaled accumulators with an additive log-decay), so train/prefill
uses an online chunked scan over the KV axis; decode is a rank-1 state
update on the (H, P, P) matrix memory.  sLSTM is inherently sequential
(hidden-to-gate recurrence) and scans over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import P
from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ================================================================ mLSTM


def mlstm_dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model  # projection factor 2 (paper)
    h = cfg.n_heads
    return di, h, di // h


def mlstm_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, p = mlstm_dims(cfg)
    return {
        "w_up": P((d, di), ("w_embed", "w_mlp")),
        "w_gate_up": P((d, di), ("w_embed", "w_mlp")),
        "conv_w": P((cfg.ssm_conv_width, di), (None, "w_mlp"), scale=0.5),
        "conv_b": P((di,), ("w_mlp",), "zeros"),
        "w_q": P((di, di), (None, "w_mlp")),
        "w_k": P((di, di), (None, "w_mlp")),
        "w_v": P((di, di), (None, "w_mlp")),
        "w_i": P((di, h), ("w_mlp", None), scale=0.02),
        "b_i": P((h,), (None,), "zeros"),
        "w_f": P((di, h), ("w_mlp", None), scale=0.02),
        "b_f": P((h,), (None,), "ones"),  # bias toward remembering
        "norm": P((di,), ("w_mlp",), "ones"),
        "w_down": P((di, d), ("w_mlp", "w_embed")),
    }


def _mlstm_qkv_gates(params, x, cfg: ModelConfig, conv_state=None):
    di, h, p = mlstm_dims(cfg)
    b, s, _ = x.shape
    xin = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(x.dtype))
    xg = jnp.einsum("bsd,de->bse", x, params["w_gate_up"].astype(x.dtype))
    # causal conv feeding q/k (paper: conv + swish before q, k)
    w = params["conv_w"].astype(x.dtype)
    width = w.shape[0]
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xin], axis=1)
        conv = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
        new_conv_state = window[:, 1:, :]
    else:
        pad = jnp.pad(xin, ((0, 0), (width - 1, 0), (0, 0)))
        conv = sum(pad[:, i : i + s, :] * w[i][None, None, :] for i in range(width))
        new_conv_state = None
    conv = jax.nn.silu(conv + params["conv_b"].astype(x.dtype))
    q = jnp.einsum("bse,ef->bsf", conv, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bse,ef->bsf", conv, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bse,ef->bsf", xin, params["w_v"].astype(x.dtype))
    q = q.reshape(b, s, h, p)
    k = k.reshape(b, s, h, p) / jnp.sqrt(p).astype(x.dtype)
    v = v.reshape(b, s, h, p)
    log_i = jnp.einsum("bse,eh->bsh", xin, params["w_i"].astype(x.dtype)).astype(
        jnp.float32) + params["b_i"].astype(jnp.float32)
    f_pre = jnp.einsum("bse,eh->bsh", xin, params["w_f"].astype(x.dtype)).astype(
        jnp.float32) + params["b_f"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre)
    return xg, q, k, v, log_i, log_f, new_conv_state, xin


def _mlstm_cell_chunked(q, k, v, log_i, log_f, chunk=256):
    """Stabilized parallel mLSTM, scanned over QUERY chunks with per-step
    remat (flash-attention memory semantics — see models/attention.py):

    h_i = sum_{j<=i} (q_i . k_j) exp(F_i + t_j - m_i) v_j / n_i
    with t_j = log_i_j - F_j, F = cumsum(log_f), m_i = max_j (F_i + t_j),
    and n_i = max(|sum_j w_ij|, exp(-m_i)).
    """
    b, s, h, p = q.shape
    f_cum = jnp.cumsum(log_f, axis=1)  # (b, s, h)
    t = (log_i - f_cum).transpose(0, 2, 1)  # (b, h, s) kv-side log weights
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        f_cum = jnp.pad(f_cum, ((0, 0), (0, pad), (0, 0)))
    qc = q.reshape(b, nchunks, chunk, h, p).transpose(1, 0, 2, 3, 4)
    fc = f_cum.reshape(b, nchunks, chunk, h).transpose(1, 0, 2, 3)
    pos_kv = jnp.arange(s)
    pc = jnp.arange(nchunks * chunk).reshape(nchunks, chunk)

    @jax.checkpoint
    def step(carry, xs):
        qb, fb, pb = xs  # (b, C, h, p), (b, C, h), (C,)
        logits = fb.transpose(0, 2, 1)[:, :, :, None] + t[:, :, None, :]
        causal = pb[None, None, :, None] >= pos_kv[None, None, None, :]
        logits = jnp.where(causal, logits, NEG_INF)  # (b, h, C, s)
        m = jnp.max(logits, axis=-1)
        qk = jnp.einsum("bshp,bthp->bhst", qb, k).astype(jnp.float32)
        w = qk * jnp.exp(logits - m[..., None])
        acc = jnp.einsum("bhst,bthp->bhsp", w.astype(v.dtype), v)
        n = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1)), jnp.exp(-m))
        out = acc.astype(jnp.float32) / n[..., None]
        return carry, out.transpose(0, 2, 1, 3).astype(qb.dtype)

    _, out = jax.lax.scan(step, (), (qc, fc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, h, p)
    return out[:, :s]  # (b, s, h, p)


def _group_rmsnorm(scale, y, eps, nheads):
    """Per-head group norm over the head dim."""
    b, s, di = y.shape
    p = di // nheads
    yh = y.astype(jnp.float32).reshape(b, s, nheads, p)
    var = jnp.mean(jnp.square(yh), axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + eps)
    return (yh.reshape(b, s, di) * scale.astype(jnp.float32)).astype(y.dtype)


def mlstm_apply(params, x, cfg: ModelConfig, return_state: bool = False):
    di, h, p = mlstm_dims(cfg)
    xg, q, k, v, log_i, log_f, _, xin = _mlstm_qkv_gates(params, x, cfg)
    out = _mlstm_cell_chunked(q, k, v, log_i, log_f, chunk=cfg.ssm_chunk)
    y = _group_rmsnorm(params["norm"], out.reshape(*x.shape[:2], di),
                       cfg.norm_eps, h)
    y = y * jax.nn.silu(xg)
    y = jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype))
    if not return_state:
        return y
    # closed-form end-of-sequence state for prefill -> decode handoff:
    # C_S = sum_j exp(F_S - F_j) i_j k_j v_j^T  (stabilized by m_S)
    f_cum = jnp.cumsum(log_f, axis=1)  # (b, s, h)
    t = log_i - f_cum
    m_end = f_cum[:, -1] + jnp.max(t, axis=1)  # (b, h)
    w = jnp.exp(f_cum[:, -1][:, None] + t - m_end[:, None])  # (b, s, h)
    c = jnp.einsum("bsh,bshp,bshq->bhpq", w, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshp->bhp", w, k.astype(jnp.float32))
    width = cfg.ssm_conv_width
    cache = {"c": c, "n": n, "m": m_end,
             "conv": xin[:, x.shape[1] - (width - 1):, :]}
    return y, cache


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, h, p = mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, p, p), jnp.float32),
        "n": jnp.zeros((batch, h, p), jnp.float32),
        "m": jnp.full((batch, h), NEG_INF, jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
    }


def mlstm_decode(params, x, cache, cfg: ModelConfig):
    di, h, p = mlstm_dims(cfg)
    xg, q, k, v, log_i, log_f, conv_state, _ = _mlstm_qkv_gates(
        params, x, cfg, conv_state=cache["conv"])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]  # (b, h, p)
    li, lf = log_i[:, 0], log_f[:, 0]  # (b, h)
    m_new = jnp.maximum(lf + cache["m"], li)
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + cache["m"] - m_new)
    c = cache["c"] * f_s[..., None, None] + i_s[..., None, None] * jnp.einsum(
        "bhp,bhq->bhpq", k1.astype(jnp.float32), v1.astype(jnp.float32))
    n = cache["n"] * f_s[..., None] + i_s[..., None] * k1.astype(jnp.float32)
    num = jnp.einsum("bhp,bhpq->bhq", q1.astype(jnp.float32), c)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhp,bhp->bh", q1.astype(jnp.float32), n)),
        jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype).reshape(x.shape[0], 1, di)
    y = _group_rmsnorm(params["norm"], out, cfg.norm_eps, h)
    y = y * jax.nn.silu(xg)
    y = jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype))
    return y, {"c": c, "n": n, "m": m_new, "conv": conv_state}


# ================================================================ sLSTM


def slstm_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    dff = int(d * 4 / 3 / 64) * 64 * 2  # paper: pf=4/3, GeGLU (2 mats fused)
    s = {}
    for g in ("i", "f", "z", "o"):
        s[f"w_{g}"] = P((d, d), ("w_embed", "w_mlp"), scale=0.02)
        s[f"r_{g}"] = P((h, p, p), (None, None, None), scale=0.02)
        s[f"b_{g}"] = P((d,), (None,), "ones" if g == "f" else "zeros")
    s["norm"] = P((d,), (None,), "ones")
    s["w_ff_up"] = P((d, dff), ("w_embed", "w_mlp"))
    s["w_ff_down"] = P((dff // 2, d), ("w_mlp", "w_embed"))
    return s


def _slstm_x_proj(params, x):
    """Precompute the input half of all 4 gate preactivations in one pass
    (keeps the big matmuls out of the sequential scan): (b, s, 4, d)."""
    w = jnp.stack([params[f"w_{g}"] for g in ("i", "f", "z", "o")], 0)
    b = jnp.stack([params[f"b_{g}"] for g in ("i", "f", "z", "o")], 0)
    return (jnp.einsum("bsd,gde->bsge", x, w.astype(x.dtype))
            + b.astype(x.dtype)[None, None])


def _slstm_step(params, xg_t, state, nheads):
    """xg_t: (b, 4, d) precomputed input preacts; recurrent part added here."""
    c, n, m, h = state
    b, d = h.shape
    p = d // nheads
    hh = h.reshape(b, nheads, p)
    r = jnp.stack([params[f"r_{g}"] for g in ("i", "f", "z", "o")], 0)
    rec = jnp.einsum("bhp,ghpq->bghq", hh, r.astype(h.dtype)).reshape(b, 4, d)
    pi, pf, pz, po = [t[:, 0] for t in jnp.split(
        (xg_t + rec).astype(jnp.float32), 4, axis=1)]
    m_new = jnp.maximum(pf + m, pi)
    i_s = jnp.exp(pi - m_new)
    f_s = jnp.exp(pf + m - m_new)
    z = jnp.tanh(pz)
    o = jax.nn.sigmoid(po)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, m_new, h_new.astype(h.dtype)


def slstm_cell(params, x, cfg: ModelConfig, state=None):
    """x: (b, s, d); sequential scan over time."""
    b, s, d = x.shape
    if state is None:
        z32 = jnp.zeros((b, d), jnp.float32)
        state = (z32, z32, jnp.full((b, d), NEG_INF, jnp.float32),
                 jnp.zeros((b, d), x.dtype))

    xg = _slstm_x_proj(params, x)  # (b, s, 4, d)

    def step(carry, xg_t):
        new = _slstm_step(params, xg_t, carry, cfg.n_heads)
        return new, new[3]

    state, hs = jax.lax.scan(step, state, xg.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2), state


def slstm_apply(params, x, cfg: ModelConfig, return_state: bool = False):
    h, state = slstm_cell(params, x, cfg)
    y = _group_rmsnorm(params["norm"], h, cfg.norm_eps, cfg.n_heads)
    up = jnp.einsum("bsd,df->bsf", y, params["w_ff_up"].astype(x.dtype))
    u1, u2 = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(u1) * u2,
                   params["w_ff_down"].astype(x.dtype))
    if return_state:
        return y, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    return y


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    z32 = jnp.zeros((batch, d), jnp.float32)
    return {"c": z32, "n": z32, "m": jnp.full((batch, d), NEG_INF, jnp.float32),
            "h": jnp.zeros((batch, d), dtype)}


def slstm_decode(params, x, cache, cfg: ModelConfig):
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    xg = _slstm_x_proj(params, x)[:, 0]  # (b, 4, d)
    new = _slstm_step(params, xg, state, cfg.n_heads)
    h = new[3][:, None, :]
    y = _group_rmsnorm(params["norm"], h, cfg.norm_eps, cfg.n_heads)
    up = jnp.einsum("bsd,df->bsf", y, params["w_ff_up"].astype(x.dtype))
    u1, u2 = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(u1) * u2,
                   params["w_ff_down"].astype(x.dtype))
    return y, {"c": new[0], "n": new[1], "m": new[2], "h": new[3]}
