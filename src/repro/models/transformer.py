"""Block wiring: residual blocks per family + scan-over-layers assembly.

All stacks lower to a single `lax.scan` over stacked layer params (compact
HLO — essential for compiling 60-80 layer models quickly on the dry-run
host), with `jax.checkpoint` remat applied to the block body.

Heterogeneous stacks:
  * deepseek-v2: dense-MLP prefix layers are unrolled outside the MoE scan
    (their params differ structurally);
  * zamba2: mamba scan with a weight-shared attention block applied on a
    cadence via `lax.cond` (shared weights enter the scan as constants);
  * xlstm: scan over (mLSTM, sLSTM) pairs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.param import P, stack_schema
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.models.layers import rmsnorm, rmsnorm_schema, swiglu, swiglu_schema


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------- blocks


def decoder_block_schema(cfg: ModelConfig, *, use_moe: bool,
                         cross: bool = False, causal: bool = True) -> dict:
    del causal
    s: dict[str, Any] = {"ln1": rmsnorm_schema(cfg.d_model)}
    s["attn"] = (attn.mla_schema(cfg) if cfg.attn_type == "mla"
                 else attn.gqa_schema(cfg))
    if cross:
        s["ln_x"] = rmsnorm_schema(cfg.d_model)
        s["xattn"] = attn.gqa_schema(cfg, cross=True)
    s["ln2"] = rmsnorm_schema(cfg.d_model)
    s["mlp"] = moe_mod.moe_schema(cfg) if use_moe else swiglu_schema(cfg)
    return s


def decoder_block_apply(params, x, positions, cfg: ModelConfig, *,
                        use_moe: bool, causal: bool = True,
                        memory=None, memory_positions=None,
                        attn_impl: str = "auto"):
    """Returns (x, kv_for_cache, aux_loss, drop_frac).

    kv_for_cache = {"self": ..., "cross": ...} — self is (k, v) for GQA or
    (latent, k_rope) for MLA; cross present only under enc-dec.
    """
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, kv_self = attn.mla_attend(params["attn"], h, cfg,
                                     positions=positions, attn_impl=attn_impl)
    else:
        a, kv_self = attn.gqa_attend(params["attn"], h, cfg,
                                     positions=positions, causal=causal,
                                     attn_impl=attn_impl)
    x = x + a
    kv = {"self": kv_self}
    if memory is not None:
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        a, kv_cross = attn.gqa_attend(params["xattn"], h, cfg,
                                      positions=positions,
                                      causal=False, kv_x=memory,
                                      kv_positions=memory_positions,
                                      attn_impl=attn_impl)
        kv["cross"] = kv_cross
        x = x + a
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if use_moe:
        m, aux, drop = moe_mod.moe_apply(params["mlp"], h, cfg)
    else:
        m, aux, drop = swiglu(params["mlp"], h), 0.0, 0.0
    return x + m, kv, jnp.asarray(aux, jnp.float32), jnp.asarray(drop, jnp.float32)


def decoder_block_decode(params, x, cache, pos, cfg: ModelConfig, *,
                         use_moe: bool, kv_len: int, cross_cache=None):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_decode(params["attn"], h, cache, pos, cfg)
    else:
        a, new_cache = attn.gqa_decode(params["attn"], h, cache, pos, cfg,
                                       kv_len=kv_len)
    x = x + a
    if cross_cache is not None:
        # cross K/V precomputed at prefill; plain attention over memory
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        a = _cross_decode(params["xattn"], h, cross_cache, cfg)
        x = x + a
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    m = (moe_mod.moe_decode(params["mlp"], h, cfg) if use_moe
         else swiglu(params["mlp"], h))
    return x + m, new_cache


def _cross_decode(params, h, cross_cache, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhe->bshe", h, params["w_q"].astype(h.dtype))
    if cfg.attn_bias:
        q = q + params["b_q"].astype(h.dtype)
    k, v = cross_cache["k"], cross_cache["v"]
    b, _, hh, dh = q.shape
    kk = k.shape[2]
    qg = q.reshape(b, 1, kk, hh // kk, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    w = jax.nn.softmax(s / jnp.sqrt(dh), axis=-1).astype(h.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(b, 1, hh, dh)
    return jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(h.dtype))


def mamba_block_schema(cfg: ModelConfig) -> dict:
    return {"ln": rmsnorm_schema(cfg.d_model), "mix": ssm.mamba2_schema(cfg)}


def shared_attn_block_schema(cfg: ModelConfig) -> dict:
    return {
        "ln_a": rmsnorm_schema(cfg.d_model),
        "attn": attn.gqa_schema(cfg),
        "ln_m": rmsnorm_schema(cfg.d_model),
        "mlp": swiglu_schema(cfg),
    }


def xlstm_pair_schema(cfg: ModelConfig) -> dict:
    return {
        "ln_m": rmsnorm_schema(cfg.d_model),
        "mlstm": xlstm.mlstm_schema(cfg),
        "ln_s": rmsnorm_schema(cfg.d_model),
        "slstm": xlstm.slstm_schema(cfg),
    }


# ------------------------------------------------------------- assembly


def stack_config(cfg: ModelConfig) -> dict:
    """Static description of the layer stack (what is scanned vs unrolled)."""
    if cfg.block_pattern == "xlstm_pair":
        return {"kind": "xlstm", "scan_len": cfg.n_layers // 2}
    if cfg.block_pattern == "mamba_shared_attn":
        return {"kind": "zamba", "scan_len": cfg.n_layers,
                "n_shared": -(-cfg.n_layers // cfg.shared_attn_every)}
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.n_experts else 0
    return {"kind": "attn", "scan_len": (n_moe or cfg.n_layers),
            "prefix": cfg.first_dense_layers if cfg.n_experts else 0}


def stack_schema_for(cfg: ModelConfig) -> dict:
    sc = stack_config(cfg)
    if sc["kind"] == "xlstm":
        return {"pairs": stack_schema(xlstm_pair_schema(cfg), sc["scan_len"])}
    if sc["kind"] == "zamba":
        return {
            "mamba": stack_schema(mamba_block_schema(cfg), sc["scan_len"]),
            "shared": shared_attn_block_schema(cfg),
        }
    s: dict[str, Any] = {}
    if sc["prefix"]:
        dense = decoder_block_schema(cfg, use_moe=False)
        s["prefix"] = [dense for _ in range(sc["prefix"])]
    s["blocks"] = stack_schema(
        decoder_block_schema(cfg, use_moe=bool(cfg.n_experts),
                             cross=cfg.is_enc_dec), sc["scan_len"])
    return s


def _scan_apply(body, stacked_params, x, n, cfg: ModelConfig, extra_carry=None):
    """Scan ``body`` over stacked layer params; body returns (x, aux, drop).

    The carry (= the remat-saved layer input) is sequence-sharded across
    'model' at every boundary (Megatron-SP): per-layer saved residuals are
    the dominant train-time memory term and must not replicate across TP.
    """
    from repro.runtime.sharding import constrain
    body = _remat(body, cfg)

    def step(carry, xs):
        x, aux, drop, extra = carry
        lp, i = xs
        x, a, dr, extra = body(lp, x, i, extra)
        x = constrain(x, "act_batch", "act_seq", None)
        return (x, aux + a, drop + dr, extra), None

    idx = jnp.arange(n)
    x = constrain(x, "act_batch", "act_seq", None)
    carry0 = (x, jnp.float32(0.0), jnp.float32(0.0), extra_carry)
    (x, aux, drop, extra), _ = jax.lax.scan(step, carry0, (stacked_params, idx))
    return x, aux, drop / max(n, 1), extra


def apply_stack(params, x, positions, cfg: ModelConfig, *,
                memory=None, memory_positions=None, attn_impl="auto"):
    """Full-sequence pass through the layer stack. Returns (x, aux, drop)."""
    sc = stack_config(cfg)

    if sc["kind"] == "xlstm":
        def body(lp, x, i, extra):
            h = rmsnorm(lp["ln_m"], x, cfg.norm_eps)
            x = x + xlstm.mlstm_apply(lp["mlstm"], h, cfg)
            h = rmsnorm(lp["ln_s"], x, cfg.norm_eps)
            x = x + xlstm.slstm_apply(lp["slstm"], h, cfg)
            return x, 0.0, 0.0, extra
        x, aux, drop, _ = _scan_apply(body, params["pairs"], x, sc["scan_len"], cfg)
        return x, aux, drop

    if sc["kind"] == "zamba":
        shared = params["shared"]

        def body(lp, x, i, extra):
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            x = x + ssm.mamba2_apply(lp["mix"], h, cfg)

            def with_shared(x):
                h = rmsnorm(shared["ln_a"], x, cfg.norm_eps)
                a, _ = attn.gqa_attend(shared["attn"], h, cfg,
                                       positions=positions, attn_impl=attn_impl)
                x = x + a
                h = rmsnorm(shared["ln_m"], x, cfg.norm_eps)
                return x + swiglu(shared["mlp"], h)

            x = jax.lax.cond(i % cfg.shared_attn_every == 0, with_shared,
                             lambda x: x, x)
            return x, 0.0, 0.0, extra
        x, aux, drop, _ = _scan_apply(body, params["mamba"], x, sc["scan_len"], cfg)
        return x, aux, drop

    # standard attention stacks (dense / moe / enc-dec decoder)
    aux0 = jnp.float32(0.0)
    drop0 = jnp.float32(0.0)
    for lp in params.get("prefix", []):
        x, _, a, d = decoder_block_apply(lp, x, positions, cfg, use_moe=False,
                                         memory=memory,
                                         memory_positions=memory_positions,
                                         attn_impl=attn_impl)
        aux0, drop0 = aux0 + a, drop0 + d

    def body(lp, x, i, extra):
        x, _, a, d = decoder_block_apply(lp, x, positions, cfg,
                                         use_moe=bool(cfg.n_experts),
                                         memory=memory,
                                         memory_positions=memory_positions,
                                         attn_impl=attn_impl)
        return x, a, d, extra

    x, aux, drop, _ = _scan_apply(body, params["blocks"], x, sc["scan_len"], cfg)
    return x, aux + aux0, drop + drop0


# --------------------------------------------------------------- caches


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                     enc_len: int = 0):
    """Abstract-safe cache construction (works under jax.eval_shape)."""
    sc = stack_config(cfg)
    if sc["kind"] == "xlstm":
        n = sc["scan_len"]
        one = {
            "mlstm": xlstm.mlstm_init_cache(cfg, batch, dtype),
            "slstm": xlstm.slstm_init_cache(cfg, batch, dtype),
        }
        return {"pairs": jax.tree.map(lambda a: _tile(a, n), one)}
    if sc["kind"] == "zamba":
        m = jax.tree.map(lambda a: _tile(a, sc["scan_len"]),
                         ssm.mamba2_init_cache(cfg, batch, dtype))
        sh = jax.tree.map(lambda a: _tile(a, sc["n_shared"]),
                          attn.gqa_init_cache(cfg, batch, max_len, dtype))
        return {"mamba": m, "shared": sh}
    init_one = (attn.mla_init_cache if cfg.attn_type == "mla"
                else attn.gqa_init_cache)
    one = init_one(cfg, batch, max_len, dtype)
    out = {}
    if sc["prefix"]:
        out["prefix"] = [init_one(cfg, batch, max_len, dtype)
                         for _ in range(sc["prefix"])]
    out["blocks"] = jax.tree.map(lambda a: _tile(a, sc["scan_len"]), one)
    if cfg.is_enc_dec and enc_len:
        xkv = {
            "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                            cfg.resolved_head_dim), dtype),
        }
        out["cross"] = jax.tree.map(lambda a: _tile(a, sc["scan_len"]), xkv)
    return out


def _tile(a, n):
    return jnp.broadcast_to(a[None], (n,) + a.shape)


def prefill_stack(params, x, positions, cfg: ModelConfig, *,
                  memory=None, memory_positions=None, attn_impl="auto"):
    """Full-sequence pass that also builds the decode caches.

    Returns (x, caches) with the same cache structure init_stack_cache
    produces (SWA archs get a rolling window-sized cache).
    """
    sc = stack_config(cfg)
    s = x.shape[1]

    def roll(k):  # window-slice for SWA caches
        w = cfg.sliding_window
        if w and s >= w:
            assert s % w == 0, "prefill length must be a multiple of the window"
            return k[:, s - w:]
        return k

    if sc["kind"] == "xlstm":
        def body(x, lp):
            h = rmsnorm(lp["ln_m"], x, cfg.norm_eps)
            a, cm = xlstm.mlstm_apply(lp["mlstm"], h, cfg, return_state=True)
            x = x + a
            h = rmsnorm(lp["ln_s"], x, cfg.norm_eps)
            a, cs = xlstm.slstm_apply(lp["slstm"], h, cfg, return_state=True)
            return x + a, {"mlstm": cm, "slstm": cs}
        x, states = jax.lax.scan(body, x, params["pairs"])
        return x, {"pairs": states}

    if sc["kind"] == "zamba":
        shared = params["shared"]
        every = cfg.shared_attn_every
        kv_buf = jax.tree.map(
            lambda a: _tile(a, sc["n_shared"]),
            attn.gqa_init_cache(cfg, x.shape[0], s, x.dtype))

        def body(carry, xs):
            x, kv_buf = carry
            lp, i = xs
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            a, cm = ssm.mamba2_apply(lp["mix"], h, cfg, return_state=True)
            x = x + a

            def with_shared(operand):
                x, kv_buf = operand
                inv = i // every
                h = rmsnorm(shared["ln_a"], x, cfg.norm_eps)
                a, (k, v) = attn.gqa_attend(shared["attn"], h, cfg,
                                            positions=positions,
                                            attn_impl=attn_impl)
                x = x + a
                h = rmsnorm(shared["ln_m"], x, cfg.norm_eps)
                x = x + swiglu(shared["mlp"], h)
                new = {"k": roll(k), "v": roll(v)}
                kv_buf = jax.tree.map(
                    lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                        buf, n.astype(buf.dtype), inv, 0), kv_buf, new)
                return x, kv_buf

            x, kv_buf = jax.lax.cond(i % every == 0, with_shared,
                                     lambda o: o, (x, kv_buf))
            return (x, kv_buf), cm

        idx = jnp.arange(sc["scan_len"])
        (x, kv_buf), mstates = jax.lax.scan(body, (x, kv_buf),
                                            (params["mamba"], idx))
        return x, {"mamba": mstates, "shared": kv_buf}

    use_moe = bool(cfg.n_experts)
    prefix_caches = []
    for lp in params.get("prefix", []):
        x, kv, _, _ = decoder_block_apply(lp, x, positions, cfg, use_moe=False,
                                          memory=memory,
                                          memory_positions=memory_positions,
                                          attn_impl=attn_impl)
        prefix_caches.append(_kv_to_cache(kv["self"], cfg, roll))

    def body(x, lp):
        x, kv, _, _ = decoder_block_apply(lp, x, positions, cfg,
                                          use_moe=use_moe, memory=memory,
                                          memory_positions=memory_positions,
                                          attn_impl=attn_impl)
        ys = {"self": _kv_to_cache(kv["self"], cfg, roll)}
        if "cross" in kv:
            k, v = kv["cross"]
            ys["cross"] = {"k": k, "v": v}
        return x, ys

    x, ys = jax.lax.scan(body, x, params["blocks"])
    out = {"blocks": ys["self"]}
    if prefix_caches:
        out["prefix"] = prefix_caches
    if "cross" in ys:
        out["cross"] = ys["cross"]
    return x, out


def _kv_to_cache(kv_self, cfg: ModelConfig, roll):
    if cfg.attn_type == "mla":
        c, k_rope = kv_self
        return {"c": c, "k_rope": k_rope}
    k, v = kv_self
    return {"k": roll(k), "v": roll(v)}


def decode_stack(params, x, caches, pos, cfg: ModelConfig, *, kv_len: int):
    """One-token pass; returns (x, new_caches)."""
    sc = stack_config(cfg)

    if sc["kind"] == "xlstm":
        def body(x, xs):
            lp, c = xs
            h = rmsnorm(lp["ln_m"], x, cfg.norm_eps)
            a, cm = xlstm.mlstm_decode(lp["mlstm"], h, c["mlstm"], cfg)
            x = x + a
            h = rmsnorm(lp["ln_s"], x, cfg.norm_eps)
            a, cs = xlstm.slstm_decode(lp["slstm"], h, c["slstm"], cfg)
            return x + a, {"mlstm": cm, "slstm": cs}
        x, new = jax.lax.scan(body, x, (params["pairs"], caches["pairs"]))
        return x, {"pairs": new}

    if sc["kind"] == "zamba":
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            x, sh_caches = carry
            lp, c, i = xs
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            a, cm = ssm.mamba2_decode(lp["mix"], h, c, cfg)
            x = x + a

            def with_shared(operand):
                x, sh_caches = operand
                inv = i // every
                ci = jax.tree.map(lambda a: a[inv], sh_caches)
                h = rmsnorm(shared["ln_a"], x, cfg.norm_eps)
                a, cnew = attn.gqa_decode(shared["attn"], h, ci, pos, cfg,
                                          kv_len=kv_len)
                x = x + a
                h = rmsnorm(shared["ln_m"], x, cfg.norm_eps)
                x = x + swiglu(shared["mlp"], h)
                sh_caches = jax.tree.map(
                    lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                        buf, new.astype(buf.dtype), inv, 0),
                    sh_caches, cnew)
                return x, sh_caches

            x, sh_caches = jax.lax.cond(i % every == 0, with_shared,
                                        lambda o: o, (x, sh_caches))
            return (x, sh_caches), cm

        idx = jnp.arange(sc["scan_len"])
        (x, sh), new_m = jax.lax.scan(
            body, (x, caches["shared"]), (params["mamba"], caches["mamba"], idx))
        return x, {"mamba": new_m, "shared": sh}

    use_moe = bool(cfg.n_experts)
    new_prefix = []
    for lp, c in zip(params.get("prefix", []), caches.get("prefix", [])):
        x, cn = decoder_block_decode(lp, x, c, pos, cfg, use_moe=False,
                                     kv_len=kv_len)
        new_prefix.append(cn)

    has_cross = "cross" in caches

    def body(x, xs):
        if has_cross:
            lp, c, xc = xs
        else:
            lp, c = xs
            xc = None
        x, cn = decoder_block_decode(lp, x, c, pos, cfg, use_moe=use_moe,
                                     kv_len=kv_len, cross_cache=xc)
        return x, cn

    xs = ((params["blocks"], caches["blocks"], caches["cross"]) if has_cross
          else (params["blocks"], caches["blocks"]))
    x, new_blocks = jax.lax.scan(body, x, xs)
    out = {"blocks": new_blocks}
    if new_prefix:
        out["prefix"] = new_prefix
    if has_cross:
        out["cross"] = caches["cross"]
    return x, out
