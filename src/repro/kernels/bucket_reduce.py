"""bucket_reduce — Flint's queue shuffle as a TPU kernel.

The paper's C2 pipeline is: hash each record to a partition queue, then
aggregate per partition. On a systolic array that whole pattern collapses
into a one-hot matmul: build the (block, P) dispatch one-hot in VREGs from
an iota==ids compare, and let the MXU do `onehot.T @ values` — "the
shuffle is a matmul" (DESIGN.md §2). This is also exactly the GShard MoE
dispatch primitive, which is why the same kernel services reduceByKey-style
aggregation and expert dispatch.

Grid (N/bn,): the (P, D) accumulator persists in VMEM scratch across the
sequential grid and is written out once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vals_ref, o_ref, acc_ref, *, n_buckets: int, bn: int,
            nblocks: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]  # (bn,) int32; -1 = padding
    vals = vals_ref[...].astype(jnp.float32)  # (bn, d)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (bn, n_buckets), 1)
    onehot = (ids[:, None] == buckets).astype(jnp.float32)  # (bn, P)
    # MXU: (P, bn) @ (bn, d) accumulated in f32 VMEM scratch
    acc_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())))

    @pl.when(step == nblocks - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bucket_reduce(values, bucket_ids, n_buckets: int, *, block: int = 512,
                  interpret: bool = False):
    """values: (N, D); bucket_ids: (N,) int32 in [0, n_buckets).
    Returns per-bucket sums (n_buckets, D)."""
    n, d = values.shape
    bn = min(block, n)
    pad = (-n) % bn
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        bucket_ids = jnp.pad(bucket_ids, (0, pad), constant_values=-1)
    nblocks = (n + pad) // bn
    return pl.pallas_call(
        functools.partial(_kernel, n_buckets=n_buckets, bn=bn,
                          nblocks=nblocks),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_buckets, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_buckets, d), values.dtype),
        scratch_shapes=[pltpu.VMEM((n_buckets, d), jnp.float32)],
        interpret=interpret,
    )(bucket_ids, values)
