"""jit'd public wrappers for the Pallas kernels.

Backend policy: on TPU the Mosaic kernels run natively; on CPU (this
container) `interpret=True` executes the kernel bodies in Python for
correctness, and the pure-jnp refs remain the oracles. The model code
calls these wrappers; tests sweep shapes/dtypes against repro.kernels.ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bucket_reduce import bucket_reduce as _bucket_reduce
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gmm import grouped_matmul as _gmm


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def _fa_with_vjp(causal: bool, window: int, interpret: bool):
    """pallas_call is not reverse-differentiable; forward runs the kernel,
    backward recomputes attention with the jnp reference (the train path
    uses the chunked pure-JAX attention anyway — the kernel serves the
    prefill/serving plane)."""

    def fwd_impl(q, k, v):
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        sq, skv = qt.shape[2], kt.shape[2]
        bq = 128 if sq % 128 == 0 else _largest_block(sq)
        bk = 128 if skv % 128 == 0 else _largest_block(skv)
        out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   bq=bq, bk=bk, interpret=interpret)
        return out.transpose(0, 2, 1, 3)

    @jax.custom_vjp
    def fa(q, k, v):
        return fwd_impl(q, k, v)

    def fwd(q, k, v):
        return fwd_impl(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=causal,
                                                    window=window), q, k, v)
        return vjp(g.astype(q.dtype))

    fa.defvjp(fwd, bwd)
    return fa


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """q: (B, S, H, D) k/v: (B, S, K, D) — model layout; kernel runs BHSD."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa_with_vjp(causal, int(window), interpret)(q, k, v)


def _largest_block(n: int, cap: int = 128) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def bucket_reduce(values, bucket_ids, n_buckets: int, *,
                  interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _bucket_reduce(values, bucket_ids, n_buckets, interpret=interpret)


def grouped_reduce(values, bucket_ids, n_buckets: int, *,
                   interpret: bool | None = None):
    """int64 grouped sum for the vectorized SQL engine
    (FLINT_VECTOR_BACKEND=jax). Integer addition is associative, so an
    order-free reduction is EXACT as long as nothing can overflow:

      * sum(|v|) < 2**24  — every value and every partial is an exact
        f32 integer, so the bucket_reduce one-hot-matmul kernel (f32
        MXU accumulation) gives bit-exact results;
      * sum(|v|) <= 2**62 — an x64 segment sum accumulates in int64
        with no possible wrap;
      * otherwise returns None and the caller keeps its exact path
        (the numpy engine falls back to Python bigint folds).

    Returns a (n_buckets,) numpy int64 array, or None."""
    import numpy as np
    vals = np.asarray(values, dtype=np.int64)
    ids = np.asarray(bucket_ids)
    if vals.shape[0] == 0:
        return np.zeros(n_buckets, dtype=np.int64)
    abs_sum = float(np.abs(vals).astype(np.float64).sum())
    if abs_sum > float(2**62):
        return None
    if abs_sum < float(2**24):
        out = bucket_reduce(vals.astype(np.float32)[:, None],
                            ids.astype(np.int32), n_buckets,
                            interpret=interpret)
        return np.asarray(out, dtype=np.int64)[:, 0]
    from jax.experimental import enable_x64
    with enable_x64():
        seg = jax.ops.segment_sum(jnp.asarray(vals, dtype=jnp.int64),
                                  jnp.asarray(ids, dtype=jnp.int32),
                                  num_segments=n_buckets)
        return np.asarray(seg, dtype=np.int64)


def grouped_matmul(x, w, sizes=None, *, interpret: bool | None = None):
    """x: (E, T, D) @ w: (E, D, F). `sizes` accepted for API compatibility
    (rows past a group's size are zero in the dispatch buffers)."""
    del sizes
    interpret = (not _on_tpu()) if interpret is None else interpret
    e, t, d = x.shape
    f = w.shape[2]
    if t % 8 or d % 8 or f % 8:  # tiny/test shapes: use the oracle
        return ref.grouped_matmul_ref(x, w)
    bt = _largest_block(t)
    bf = _largest_block(f)
    bd = _largest_block(d, 512)
    return _gmm(x, w, bt=bt, bf=bf, bd=bd, interpret=interpret)
