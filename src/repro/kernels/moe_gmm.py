"""Grouped (per-expert) matmul Pallas kernel for the MoE expert compute.

x: (E, T, D) expert-major token buffers (the dispatch output), w: (E, D, F)
stacked expert weights. Grid (E, T/bt, F/bf, D/bd) with the innermost
contraction axis accumulating into a (bt, bf) f32 VMEM scratch tile —
one output tile is live at a time, tiles are MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    kstep = pl.program_id(3)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bt, bd)
    w = w_ref[0]  # (bd, bf)
    acc_ref[...] += jax.lax.dot(x.astype(jnp.float32), w.astype(jnp.float32))

    @pl.when(kstep == nd - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, bt: int = 128, bf: int = 128, bd: int = 512,
                   interpret: bool = False):
    """x: (E, T, D) @ w: (E, D, F) -> (E, T, F)."""
    e, t, d = x.shape
    f = w.shape[2]
    bt, bf, bd = min(bt, t), min(bf, f), min(bd, d)
    assert t % bt == 0 and f % bf == 0 and d % bd == 0, \
        "pad T/F/D to block multiples"
    grid = (e, t // bt, f // bf, d // bd)
    return pl.pallas_call(
        functools.partial(_kernel, nd=d // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda ei, ti, fi, ki: (ei, ti, ki)),
            pl.BlockSpec((1, bd, bf), lambda ei, ti, fi, ki: (ei, ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf), lambda ei, ti, fi, ki: (ei, ti, fi)),
        out_shape=jax.ShapeDtypeStruct((e, t, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
