"""Flash attention as a Pallas TPU kernel.

Grid (B*H, Sq/bq, Skv/bk): the innermost kv-block axis runs sequentially on
TPU, so the online-softmax state (m, l, acc) lives in VMEM scratch and
persists across kv blocks; the output block is written once, on the last
kv step. BlockSpecs keep one (bq, D) query tile, one (bk, D) kv tile and
the (bq, D) f32 accumulator in VMEM — MXU-aligned tile sizes (multiples of
128) are chosen by the wrapper in ops.py.

GQA is handled with no KV expansion copy: the kv BlockSpec index_map sends
query-head `h` to kv-head `h // group`, so each kv tile is fetched once
per group from HBM.

Causal/SWA masking is block-sparse: fully-masked kv blocks are skipped via
pl.when (no MXU work), partially-masked blocks apply an iota mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, q_offset: int):
    i = pl.program_id(1)  # query block
    j = pl.program_id(2)  # kv block (sequential innermost)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    q_lo = i * bq + q_offset  # first query's absolute position
    k_lo = j * bk
    # block-level reachability (skip fully-masked tiles)
    reachable = True
    if causal:
        reachable = q_lo + bq - 1 >= k_lo  # some query can see some key
    if window:
        reachable = jnp.logical_and(
            reachable, k_lo + bk - 1 > q_lo - window) if causal else reachable

    @pl.when(reachable if (causal or window) else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal or window:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            delta = qpos - kpos
            ok = jnp.ones((bq, bk), jnp.bool_)
            if causal:
                ok &= delta >= 0
            if window:
                ok &= delta < window
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot(p, v))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = False):
    """q: (B, H, Sq, D); k/v: (B, K, Skv, D). Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kk, skv = k.shape[1], k.shape[2]
    g = h // kk
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad seq to block multiple"
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)
    q_offset = skv - sq  # decode: queries sit at the end of the kv span

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kk, skv, d)
    vf = v.reshape(b * kk, skv, d)

    def kv_index(bh, i, j):
        return (bh // h) * kk + (bh % h) // g, j, 0

    grid = (b * h, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),    # m (running max)
            pltpu.VMEM((bq,), jnp.float32),    # l (running denom)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
