"""Pure-jnp oracles for every Pallas kernel (the correctness contract the
interpret-mode sweeps assert against)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D) with H % K == 0 (GQA).
    Positions are implicit: q row i sits at absolute position
    (Skv - Sq + i) so prefill (Sq == Skv) and decode both work."""
    b, sq, h, d = q.shape
    kk = k.shape[2]
    g = h // kk
    qg = q.reshape(b, sq, kk, g, d)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(d)
    qpos = jnp.arange(sq) + (k.shape[1] - sq)
    kpos = jnp.arange(k.shape[1])
    delta = qpos[:, None] - kpos[None, :]
    ok = jnp.ones_like(delta, dtype=bool)
    if causal:
        ok &= delta >= 0
    if window:
        ok &= delta < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def bucket_reduce_ref(values, bucket_ids, n_buckets: int):
    """values: (N, D), bucket_ids: (N,) int32 in [0, n_buckets).
    Returns (n_buckets, D) per-bucket sums — reduceByKey after the hash
    partitioner, the paper's shuffle+aggregate collapsed into one op."""
    onehot = jax.nn.one_hot(bucket_ids, n_buckets, dtype=jnp.float32)
    return jnp.einsum("np,nd->pd", onehot,
                      values.astype(jnp.float32)).astype(values.dtype)


def grouped_matmul_ref(x, w):
    """x: (E, T, D), w: (E, D, F) -> (E, T, F): per-expert matmul."""
    return jnp.einsum("etd,edf->etf", x, w)
