"""Deterministic synthetic data: token streams for LM training and a
NYC-taxi-like CSV for the paper's Table I queries.

Training batches are a pure function of (seed, step) — the fault-tolerance
contract: after any restart, batch `i` is bit-identical, so lease-chained
training replays exactly (Flint C3 applied to the input pipeline; no
shuffle-buffer state to checkpoint).
"""

from __future__ import annotations

import numpy as np


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    """Zipf-ish token batch, deterministic in (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # mixture: frequent head tokens + uniform tail, mild docwise structure
    z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    tokens = (z + rng.integers(0, 17, size=(batch, seq))) % vocab
    return {"tokens": tokens.astype(np.int32)}


def lm_batch_stream(seed: int, batch: int, seq: int, vocab: int,
                    start_step: int = 0):
    step = start_step
    while True:
        yield step, lm_batch(seed, step, batch, seq, vocab)
        step += 1


# --------------------------------------------------------------- taxi CSV

PAYMENT_TYPES = ["credit", "cash", "no charge", "dispute"]
# rough bounding boxes (lon, lat) for the paper's two query targets
GOLDMAN = (-74.0144, 40.7147, -74.0134, 40.7157)  # 200 West St
CITIGROUP = (-74.0122, 40.7197, -74.0112, 40.7207)  # 388 Greenwich St


def taxi_csv(n_rows: int, seed: int = 0) -> bytes:
    """pickup_dt, dropoff_dt, dropoff_lon, dropoff_lat, trip_miles,
    payment_type, tip, total, precip_mm, taxi_color"""
    rng = np.random.default_rng(seed)
    months = rng.integers(1, 13, n_rows)
    days = rng.integers(1, 29, n_rows)
    hours = rng.integers(0, 24, n_rows)
    mins = rng.integers(0, 60, n_rows)
    lon = rng.uniform(-74.03, -73.75, n_rows)
    lat = rng.uniform(40.60, 40.90, n_rows)
    # plant drop-offs at the two HQs so Q1/Q2 have non-trivial answers
    hq = rng.random(n_rows)
    gl = hq < 0.004
    cg = (hq >= 0.004) & (hq < 0.007)
    lon[gl] = rng.uniform(GOLDMAN[0], GOLDMAN[2], gl.sum())
    lat[gl] = rng.uniform(GOLDMAN[1], GOLDMAN[3], gl.sum())
    lon[cg] = rng.uniform(CITIGROUP[0], CITIGROUP[2], cg.sum())
    lat[cg] = rng.uniform(CITIGROUP[1], CITIGROUP[3], cg.sum())
    miles = np.round(rng.gamma(2.0, 1.6, n_rows), 2)
    pay = rng.choice(len(PAYMENT_TYPES), n_rows, p=[0.62, 0.35, 0.02, 0.01])
    tip = np.round(np.where(pay == 0, rng.gamma(2.0, 1.4, n_rows), 0.0), 2)
    total = np.round(3.0 + miles * 2.5 + tip, 2)
    precip = np.round(np.maximum(rng.normal(-2.0, 4.0, n_rows), 0.0), 1)
    color = rng.choice(["yellow", "green"], n_rows, p=[0.8, 0.2])

    rows = []
    for i in range(n_rows):
        pickup = (f"2015-{months[i]:02d}-{days[i]:02d} "
                  f"{hours[i]:02d}:{mins[i]:02d}:00")
        dropoff = (f"2015-{months[i]:02d}-{days[i]:02d} "
                   f"{(hours[i] + 1) % 24:02d}:{mins[i]:02d}:00")
        rows.append(
            f"{pickup},{dropoff},{lon[i]:.6f},{lat[i]:.6f},{miles[i]},"
            f"{PAYMENT_TYPES[pay[i]]},{tip[i]},{total[i]},{precip[i]},"
            f"{color[i]}")
    return ("\n".join(rows) + "\n").encode()
