"""Flint-engine-backed input pipeline: the paper's queue shuffle as the
data-plane substrate for training.

``shuffle_shards`` hash-partitions a tokenized corpus into training shards
through the serverless engine (stage 0 reads S3 ranges, the shuffle rides
SQS, stage 1 writes shard objects) — the exact C2 mechanism, reused.
"""

from __future__ import annotations

import numpy as np

from repro.core import FlintContext


def shuffle_shards(ctx: FlintContext, corpus_key: str, n_shards: int,
                   read_partitions: int = 8) -> list[str]:
    """Hash-shuffle corpus lines into n_shards objects; returns keys."""
    rdd = (ctx.textFile(corpus_key, read_partitions)
           .map(lambda line: (hash(line) % (1 << 30), line))
           .groupByKey(n_shards)
           .flatMap(lambda kv: kv[1]))
    return rdd.saveAsTextFile(f"{corpus_key}.shards")


def shard_token_stream(ctx: FlintContext, shard_keys: list[str],
                       tokenizer, seq: int, batch: int):
    """Yield {'tokens': (batch, seq)} batches from shuffled shards —
    deterministic given shard contents (resume = skip to batch index)."""
    buf: list[int] = []
    batch_rows: list[np.ndarray] = []
    for key in shard_keys:
        text = ctx.store.get(key).decode()
        for line in text.splitlines():
            buf.extend(tokenizer(line))
            while len(buf) >= seq:
                batch_rows.append(np.asarray(buf[:seq], np.int32))
                buf = buf[seq:]
                if len(batch_rows) == batch:
                    yield {"tokens": np.stack(batch_rows)}
                    batch_rows = []


def byte_tokenizer(line: str) -> list[int]:
    return list(line.encode("utf-8")[:1024]) + [10]
