"""Sharded, atomically-committed, mesh-elastic checkpointing.

This is the training-plane realization of Flint's executor chaining (C3):
all state an executor needs to continue lives OUTSIDE the executor. A
checkpoint is a directory of flat-key .npy blobs plus a manifest committed
by atomic rename — a torn write can never be mistaken for a checkpoint.

Restore is mesh-shape-agnostic (elastic): arrays are loaded on host and
device_put against whatever sharding the *new* mesh prescribes, so the
same checkpoint resumes on 1 device or 512.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str | os.PathLike, step: int, tree,
                    *, keep: int = 3) -> str:
    """Write `tree` under directory/step_<n>; atomic via tmp+rename."""
    base = pathlib.Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=base))
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _gc(base, keep)
    return str(final)


def _gc(base: pathlib.Path, keep: int):
    steps = sorted(p for p in base.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in base.glob(".tmp_ckpt_*"):
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    base = pathlib.Path(directory)
    if not base.exists():
        return None
    steps = sorted(base.glob("step_*"))
    for cand in reversed(steps):
        if (cand / "manifest.json").exists():
            return int(cand.name.split("_")[1])
    return None


def restore_checkpoint(directory: str | os.PathLike, step: int, like,
                       shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching tree of shardings for
    elastic placement onto the current mesh."""
    base = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((base / "manifest.json").read_text())
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_like) - set(manifest["keys"])
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    restored = {}
    for key, leaf in flat_like.items():
        meta = manifest["keys"][key]
        arr = np.load(base / meta["file"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        sh = flat_shard.get(key)
        restored[key] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr, dtype=leaf.dtype))
    # rebuild the tree in `like`'s structure
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    keys_in_order = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                  for p in path)
        for path, _ in leaves_with_path[0]]
    return jax.tree_util.tree_unflatten(
        leaves_with_path[1], [restored[k] for k in keys_in_order])


class CheckpointManager:
    """Async wrapper: snapshot to host in the caller, write in a thread —
    the training loop never blocks on the filesystem."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = str(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree, blocking: bool = False):
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree, keep=self.keep)
            self.saved_steps.append(step)

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self) -> int | None:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like, step: int | None = None, shardings=None):
        self.wait()
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return restore_checkpoint(self.directory, step, like, shardings)
