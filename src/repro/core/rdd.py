"""RDD lineage — the user-facing dataflow API (PySpark-compatible subset).

An RDD is a lazy lineage node; nothing executes until an action. The DAG
scheduler (core.dag) cuts the lineage into stages at wide dependencies,
exactly as the paper describes reusing Spark's physical planning. Because
every wide dependency's producer task count is fixed at plan time, stage
plans carry those counts down to the scheduler, which pipelines consumer
stages concurrently with their producers (EOS shuffle protocol — see
docs/eos_shuffle.md) instead of barrier-scheduling them.

Supported transformations: map, filter, flatMap, mapPartitions (narrow);
reduceByKey, groupByKey, join, repartition (wide); union; cache (lineage
materialization). Actions: collect, count, take, reduce, saveAsTextFile.
Shared lineages (self-joins, diamonds, unions of two derivations) are
planned once via shuffle CSE — see docs/dag_fanout.md.

``toDF(schema)`` lifts an RDD of tuples onto the structured DataFrame
surface (repro.sql, docs/dataframe.md), whose optimizer lowers back onto
this lineage API. Wide ops accept a ``batch_schema`` declaring the typed
columnar wire format at plan time — the SQL lowering knows its row types,
so its shuffles skip per-batch type sniffing.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable

_next_id = itertools.count()


class RDD:
    #: set by .cache() — the planner materializes this node's partitions
    #: to content-addressed object-store keys on first evaluation and
    #: reads them back on later actions (docs/dag_fanout.md)
    cached = False

    def __init__(self, ctx, nparts: int):
        self.ctx = ctx
        self.id = next(_next_id)
        self.nparts = nparts

    # ------------------------------------------------------ transformations
    def map(self, fn: Callable) -> "RDD":
        return Narrow(self, "map", fn)

    def filter(self, fn: Callable) -> "RDD":
        return Narrow(self, "filter", fn)

    def flatMap(self, fn: Callable) -> "RDD":
        return Narrow(self, "flatmap", fn)

    def mapPartitions(self, fn: Callable) -> "RDD":
        return Narrow(self, "mappartitions", fn)

    def mapBatches(self, fn: Callable) -> "RDD":
        """Batch-level narrow op: ``fn(record_iter)`` consumes a whole
        partition and yields records OR column-major ``KVBatch`` carriers
        (core.shuffle.KVBatch). The vectorized SQL lowering fuses
        scan→filter→project→partial-agg chains into one such operator so
        data stays columnar from the scan to the shuffle pack
        (docs/vectorized_execution.md); executors expand any KVBatch back
        to rows wherever a row consumer needs them."""
        return Narrow(self, "mapbatches", fn)

    def reduceByKey(self, fn: Callable, numPartitions: int | None = None,
                    transport: str | None = None,
                    batch_schema: tuple | None = None) -> "RDD":
        return ShuffleAgg(self, fn, numPartitions or self.nparts,
                          map_side_combine=True, transport=transport,
                          batch_schema=batch_schema)

    def groupByKey(self, numPartitions: int | None = None,
                   transport: str | None = None,
                   batch_schema: tuple | None = None) -> "RDD":
        return ShuffleAgg(self, None, numPartitions or self.nparts,
                          map_side_combine=False, transport=transport,
                          batch_schema=batch_schema)

    def join(self, other: "RDD", numPartitions: int | None = None,
             transport: str | None = None,
             batch_schemas: tuple | None = None,
             how: str = "inner") -> "RDD":
        if how not in ("inner", "left", "right", "outer"):
            raise ValueError(f"unsupported join how={how!r}")
        return Join(self, other,
                    numPartitions or max(self.nparts, other.nparts),
                    transport=transport, batch_schemas=batch_schemas,
                    how=how)

    def repartition(self, numPartitions: int,
                    transport: str | None = None,
                    partition_fn: Callable | None = None) -> "RDD":
        """``partition_fn(record) -> int`` routes each record to a
        partition index (modulo numPartitions) instead of the default
        round-robin — the range partitioner behind distributed orderBy."""
        return Repartition(self, numPartitions, transport=transport,
                           partition_fn=partition_fn)

    def union(self, other: "RDD") -> "RDD":
        return Union(self, other)

    def cache(self) -> "RDD":
        """Materialize this RDD's partitions (columnar batches under
        ``_cache/``) the first time an action evaluates them; later
        actions on the same lineage read the materialization instead of
        replanning upstream stages. Storage is billed through the cost
        ledger and reclaimed by ``ctx.clear_cache()`` (stale entries by
        the job-scoped GC)."""
        self.cached = True
        return self

    def cache_token(self) -> str | None:
        """Content-addressed identity of this lineage's cache() entry
        (None when the lineage holds an unserializable callable — such
        lineages never materialize)."""
        from repro.core.dag import cache_token  # lazy: dag imports rdd
        return cache_token(self)

    def uncache(self) -> int:
        """Drop this RDD's cache() materialization and registration
        (clears the ``cached`` mark too, so the next action recomputes
        from source without re-materializing); returns the number of
        store keys removed."""
        self.cached = False
        token = self.cache_token()
        return self.ctx.uncache(token) if token else 0

    def toDF(self, schema) -> "Any":
        """Lift an RDD whose records are tuples matching ``schema`` (a
        repro.sql Schema or a list of (name, dtype) pairs) onto the
        DataFrame surface — see docs/dataframe.md."""
        from repro.sql import DataFrame  # lazy: sql imports core
        return DataFrame.from_rdd(self, schema)

    # ------------------------------------------------------------- actions
    def collect(self) -> list:
        return self.ctx.run_action(self, "collect")

    def count(self) -> int:
        return self.ctx.run_action(self.mapPartitions(_count_iter), "sum")

    def reduce(self, fn: Callable):
        partials = self.ctx.run_action(self.mapPartitions(_reduce_with(fn)),
                                       "collect")
        vals = [p for p in partials if p is not _EMPTY]
        out = vals[0]
        for v in vals[1:]:
            out = fn(out, v)
        return out

    def take(self, n: int) -> list:
        """First n records in partition order. Plans a per-partition
        ``limit`` op (each partition stops evaluating — and a source task
        stops READING — after its first n records) and short-circuits the
        action merge at n, instead of the old full collect()."""
        if n <= 0:
            return []
        return self.ctx.run_action(Narrow(self, "limit", n), "collect",
                                   limit=n)

    def saveAsTextFile(self, key_prefix: str):
        return self.ctx.run_action(self, "save", save_prefix=key_prefix)


class _Empty:
    def __repr__(self):
        return "<empty>"


_EMPTY = _Empty()


def _count_iter(it):
    n = 0
    for _ in it:
        n += 1
    yield n


def _reduce_with(fn):
    def part_reduce(it):
        acc = _EMPTY
        for x in it:
            acc = x if acc is _EMPTY else fn(acc, x)
        yield acc
    return part_reduce


class Source(RDD):
    """Byte-range-partitioned text object in the object store."""

    def __init__(self, ctx, key: str, nparts: int):
        super().__init__(ctx, nparts)
        self.key = key


class ParallelCollection(RDD):
    """Driver-side data distributed into partitions (ctx.parallelize)."""

    def __init__(self, ctx, key: str, nparts: int):
        super().__init__(ctx, nparts)
        self.key = key  # pre-uploaded pickled partitions under this prefix


class Narrow(RDD):
    def __init__(self, parent: RDD, kind: str, fn: Callable):
        super().__init__(parent.ctx, parent.nparts)
        self.parent = parent
        self.kind = kind
        self.fn = fn


class ShuffleAgg(RDD):
    """reduceByKey / groupByKey. ``transport`` is the per-shuffle backend
    hint (core.shuffle registry name); None defers to the engine default
    (which may be the planner's cost-model choice — docs/dataframe.md).
    ``batch_schema`` is an optional declared (key, value) column-schema
    pair for the shuffle's typed columnar batches."""

    def __init__(self, parent: RDD, fn, nparts: int, *,
                 map_side_combine: bool, transport: str | None = None,
                 batch_schema: tuple | None = None):
        super().__init__(parent.ctx, nparts)
        self.parent = parent
        self.fn = fn
        self.map_side_combine = map_side_combine
        self.transport = transport
        self.batch_schema = batch_schema


class Repartition(RDD):
    def __init__(self, parent: RDD, nparts: int,
                 transport: str | None = None,
                 partition_fn: Callable | None = None):
        super().__init__(parent.ctx, nparts)
        self.parent = parent
        self.transport = transport
        self.partition_fn = partition_fn


class Join(RDD):
    """``batch_schemas`` declares (key-schema, left-value-schema,
    right-value-schema) for the two side shuffles' columnar batches.
    ``how`` selects inner/left/right/outer semantics — unmatched rows of
    a preserved side pair with None."""

    def __init__(self, left: RDD, right: RDD, nparts: int,
                 transport: str | None = None,
                 batch_schemas: tuple | None = None,
                 how: str = "inner"):
        super().__init__(left.ctx, nparts)
        self.left = left
        self.right = right
        self.transport = transport
        self.batch_schemas = batch_schemas
        self.how = how


class Union(RDD):
    def __init__(self, a: RDD, b: RDD):
        super().__init__(a.ctx, a.nparts + b.nparts)
        self.a = a
        self.b = b
