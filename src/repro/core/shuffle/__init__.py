"""Pluggable shuffle-transport subsystem (docs/shuffle_transports.md).

The transport that moves intermediate data is a per-shuffle decision, not
an engine constant: ``ShuffleWrite.transport`` (the DAG-level hint, e.g.
``rdd.reduceByKey(fn, 8, transport="s3")``) names a backend here, falling
back to ``FlintConfig.shuffle_backend``. Backends conform to
``base.ShuffleTransport`` and share the columnar record-batch wire format
in ``batch``.
"""

from __future__ import annotations

import threading

from repro.core.retry import RetryPolicy
from repro.core.shuffle.base import (AbortedError, DrainHandle, DrainState,
                                     LostShuffleInput, ShuffleTransport)
from repro.core.shuffle.batch import (KVBatch, is_columnar, iter_records,
                                      pack_batch, pack_batch_columns,
                                      unpack_batch)
from repro.core.shuffle.s3 import S3ExchangeTransport
from repro.core.shuffle.sqs import SQSTransport, queue_name

_BACKENDS: dict[str, type] = {
    SQSTransport.name: SQSTransport,
    S3ExchangeTransport.name: S3ExchangeTransport,
}


def register_transport(name: str, cls: type):
    """Extension point: a new backend needs only a conforming class."""
    _BACKENDS[name] = cls


def transport_names() -> list[str]:
    return sorted(_BACKENDS)


class TransportSet:
    """Job-scoped transport instances sharing one (cfg, ledger, store, sqs)
    quartet, constructed lazily so a query that never touches a backend
    never pays its setup."""

    def __init__(self, cfg, ledger, store, sqs, *, budget=None):
        self.cfg = cfg
        self.ledger = ledger
        self.store = store
        self.sqs = sqs
        # one job-wide retry policy for every transport: the per-job retry
        # BUDGET is only meaningful if all backends draw from the same pool
        self.retry = RetryPolicy.from_config(cfg, budget=budget)
        self._instances: dict[str, ShuffleTransport] = {}
        self._lock = threading.Lock()

    def get(self, name: str) -> ShuffleTransport:
        with self._lock:
            tr = self._instances.get(name)
            if tr is None:
                cls = _BACKENDS.get(name)
                if cls is None:
                    raise ValueError(
                        f"unknown shuffle transport {name!r} "
                        f"(have: {', '.join(transport_names())})")
                tr = self._instances[name] = cls(self.cfg, self.ledger,
                                                 self.store, self.sqs)
                # attribute swap, not a constructor arg: third-party
                # backends registered via register_transport keep the
                # documented 4-arg signature
                tr.retry = self.retry
            return tr

    def active(self) -> list[ShuffleTransport]:
        with self._lock:
            return list(self._instances.values())


__all__ = ["AbortedError", "DrainHandle", "DrainState", "LostShuffleInput",
           "ShuffleTransport",
           "SQSTransport", "S3ExchangeTransport", "TransportSet",
           "KVBatch", "is_columnar", "iter_records", "pack_batch",
           "pack_batch_columns", "unpack_batch", "queue_name",
           "register_transport", "transport_names"]
