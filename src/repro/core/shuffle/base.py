"""The ShuffleTransport contract — intermediate data movement as a
first-class pluggable subsystem (docs/shuffle_transports.md).

The engine was hard-wired to SQS; Lambada showed a serverless exchange
operator over S3 objects scales better for analytical volumes, and Flock
that the transport should be a per-shuffle decision. Everything above this
interface (executors, scheduler, DAG planner) speaks only the contract:

  * ``open(sid, nparts, groups)``— scheduler-side channel setup, before any
                                   producer launches; ``groups`` is the
                                   plan-time CONSUMER-GROUP count (CSE fans
                                   one producer stage out to N read sites,
                                   each draining the full stream);
  * ``send(...)`` / ``emit_eos`` — producer-side: ship packed record-batch
                                   bodies, then close the stream with the
                                   per-partition sequence totals (EOS quorum
                                   is fixed at plan time);
  * ``open_drain(...)``          — consumer-side: an iterator of fresh
                                   ``(src, seq, body)`` batches that
                                   terminates on EOS quorum, plus ``ack()``
                                   invoked only once the task's output is
                                   durable (ack-after-fold);
  * ``release_partition``        — a completed consumer's channel is dead:
                                   losing speculative twins must abort fast;
  * ``destroy`` / ``gc``         — stage-end sweep and job-end garbage
                                   collection (zero leaked keys/queues);
  * ``service_cost``             — cost hook: the transport's share of the
                                   ledger, for per-transport cost A/Bs.

Delivery may be at-least-once and unordered; ``DrainState`` centralizes the
(src, seq) dedup + EOS-quorum bookkeeping every conforming backend shares.
A transport MUST tolerate byte-identical re-emission of the same (src, seq)
batches (retries and speculative twins re-send deterministically) and MUST
deliver each distinct batch exactly once per drain handle.
"""

from __future__ import annotations

import hashlib
from typing import Iterator

from repro.core.retry import RetryPolicy


class AbortedError(RuntimeError):
    """The shuffle channel disappeared under a live drain — the scheduler
    shut the transport down (fatal failure / re-plan), or a competing
    attempt already completed this partition. Unblock and exit quietly."""


class LostShuffleInput(RuntimeError):
    """The drain is CERTAIN its missing input will never arrive on its
    own: the producer quorum's EOS manifests are all in, yet advertised
    batches are absent past the drain deadline with no release tombstone
    to explain them — an acknowledged durable write was lost. Retrying
    the consumer cannot help; the scheduler answers with lineage-based
    resubmission of the producing stage (docs/fault_tolerance.md)."""


class DrainState:
    """Shared drain bookkeeping: (src, seq) dedup, per-producer counts, and
    the plan-time EOS quorum that terminates the drain."""

    __slots__ = ("quorum", "seen", "per_src", "eos_total", "stats")

    def __init__(self, quorum: int):
        self.quorum = quorum
        self.seen: set = set()
        self.per_src: dict[str, int] = {}
        self.eos_total: dict[str, int] = {}
        self.stats = {"messages": 0, "duplicates": 0}

    def register_eos(self, src: str, total: int) -> bool:
        """Record a producer's end-of-stream (total = its sequence count).
        Duplicate EOS (speculation, redelivery) is idempotent."""
        if src in self.eos_total:
            return False
        self.eos_total[src] = total
        return True

    def register_data(self, src: str, seq: int) -> bool:
        """True if (src, seq) is fresh; duplicates are counted and dropped."""
        if (src, seq) in self.seen:
            self.stats["duplicates"] += 1
            return False
        self.seen.add((src, seq))
        self.per_src[src] = self.per_src.get(src, 0) + 1
        self.stats["messages"] += 1
        return True

    def done(self) -> bool:
        """EOS from the full producer quorum AND every producer's advertised
        sequence count seen (EOS may outrun data — no ordering guarantee)."""
        return (len(self.eos_total) >= self.quorum
                and all(self.per_src.get(s, 0) >= t
                        for s, t in self.eos_total.items()))


class DrainHandle:
    """Iterator of fresh ``(src, seq, body)`` data batches for one
    (shuffle, partition). ``ack()`` is called by the executor only once the
    task's output is durable; ``stats`` mirrors DrainState.stats."""

    state: DrainState

    @property
    def stats(self) -> dict:
        return self.state.stats

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        raise NotImplementedError

    def ack(self):
        """Release the drained input for good. Must be idempotent; on
        transports with non-destructive reads this is a no-op."""


class ShuffleTransport:
    """Abstract transport. Concrete backends: shuffle.sqs.SQSTransport
    (queue semantics, the paper's choice) and shuffle.s3.S3ExchangeTransport
    (Lambada-style object exchange — no queues at all)."""

    name = "?"
    #: largest packed batch body this transport ships in one unit
    batch_limit = 0

    def __init__(self, cfg, ledger, store, sqs):
        self.cfg = cfg
        self.ledger = ledger
        self.store = store
        self.sqs = sqs  # SQSSim doubles as the job-wide abort signal
        # call-level retry around every service call this transport makes;
        # TransportSet replaces this with its shared, budget-backed policy
        self.retry = RetryPolicy.from_config(cfg)

    # ---------------------------------------------------- producer side
    def spill(self, blob: bytes) -> str:
        """Out-of-band home for a single record pickle too large for one
        batch body: content-addressed, so a retry or speculative twin
        re-spilling the same record overwrites idempotently."""
        key = f"_spill/{hashlib.sha1(blob).hexdigest()}"
        self.retry.call(self.store.put, key, blob)
        return key

    def send(self, shuffle_id: int, partition: int, src: str,
             first_seq: int, bodies: list[bytes]):
        raise NotImplementedError

    def emit_eos(self, shuffle_id: int, nparts: int, src: str,
                 totals: dict[int, int]):
        """Close ``src``'s stream on EVERY partition (total 0 where it wrote
        nothing), so consumers can count down a fixed producer quorum."""
        raise NotImplementedError

    # ---------------------------------------------------- consumer side
    def open_drain(self, shuffle_id: int, partition: int, quorum: int,
                   group: list | None = None,
                   consumer_group: int = 0) -> DrainHandle:
        """``group`` is the task-scoped claim group: a join task drains two
        shuffles and transports with leases (SQS visibility) must keep the
        first drain's claims alive while the second drains.
        ``consumer_group`` selects which fan-out copy of the stream this
        drain consumes — sibling groups are fully independent (their own
        dedup, their own claims/recovery, their own release)."""
        raise NotImplementedError

    # ------------------------------------------------- lifecycle + cost
    def open(self, shuffle_id: int, nparts: int, groups: int = 1):
        """Create channels before any producer of this shuffle launches.
        ``groups`` consumer groups will each drain the full stream."""

    def partition_drainable(self, shuffle_id: int, partition: int,
                            consumer_group: int = 0) -> bool:
        """True while a FRESH drain of this (partition, group) could still
        complete — i.e. the group has not released it. Lineage recovery
        consults this before resubmitting a mid-chain task: a released
        partition's channel aborts new drains (and its data may be
        reclaimed), so the upstream producers must be replayed through
        ``reopen`` first."""
        return True

    def release_partition(self, shuffle_id: int, partition: int,
                          consumer_group: int = 0):
        """A consumer completed this partition for its group: free that
        group's channel and make any competing drain OF THE SAME GROUP
        abort fast (idempotent). Sibling groups must stay drainable —
        the shuffle's data is only reclaimed once every group released."""

    def destroy(self, shuffle_id: int, nparts: int):
        """All-consumer-stages-done sweep (every group) of whatever
        ``release_partition`` didn't cover."""

    def reopen(self, shuffle_id: int, nparts: int, groups: int = 1):
        """Lineage recovery (docs/fault_tolerance.md): make a previously
        released/destroyed shuffle's channels writable and drainable
        again so the producing stage can be resubmitted. Must clear any
        per-partition release state for the shuffle; re-emitted batches
        are byte-identical, so consumers mid-drain dedup the overlap."""
        self.open(shuffle_id, nparts, groups)

    def gc(self) -> dict[str, int]:
        """Job-end cleanup; returns {resource: count} actually removed."""
        return {}

    def gc_sids(self, sids) -> dict[str, int]:
        """Targeted job-end sweep of ONLY the named shuffles' channels.
        Service mode (docs/multi_tenant.md) shares the backing store
        across concurrently-running jobs, so the blanket ``gc`` — which
        reaps the whole channel namespace — would destroy other jobs'
        live shuffles; each job sweeps just the shuffle ids it owns."""
        return {}

    def service_cost(self) -> float:
        """This transport's share of the ledger, in USD (cost hook)."""
        raise NotImplementedError
