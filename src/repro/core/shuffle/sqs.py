"""SQSTransport — the paper's queue shuffle behind the ShuffleTransport
contract, semantics preserved exactly: per-partition queues, batched sends
under the 256 KiB / 10-message caps, visibility-timeout receives with
ack-after-fold (docs/eos_shuffle.md), per-producer EOS control messages,
and QueueGone-based fast abort for losing speculative twins.

MULTI-CONSUMER fan-out (docs/dag_fanout.md): queues are destructive, so a
CSE-shared shuffle with N consumer groups materializes N per-partition
queue SETS (``shuffle{sid}-g{g}-p{p}``) and every producer send/EOS fans
out to all of them at emit time. Each group then keeps the full
single-consumer story independently: its own (src, seq) dedup, its own
visibility-claim recovery, its own byte-identical re-emission absorption,
and its own QueueGone release — one group's completion or death never
touches a sibling's stream.
"""

from __future__ import annotations

import time
from collections import deque

from repro.core.costs import SQS_BATCH_MESSAGES, SQS_MESSAGE_LIMIT
from repro.core.queues import Message, QueueGone, eos_message
from repro.core.shuffle.base import (AbortedError, DrainHandle, DrainState,
                                     ShuffleTransport)


def queue_name(shuffle_id: int, partition: int, group: int = 0) -> str:
    return f"shuffle{shuffle_id}-g{group}-p{partition}"


class SQSTransport(ShuffleTransport):
    name = "sqs"
    batch_limit = SQS_MESSAGE_LIMIT

    def __init__(self, cfg, ledger, store, sqs):
        super().__init__(cfg, ledger, store, sqs)
        self._live: set = set()      # queues created and not yet deleted
        self._released: set = set()  # deleted (each delete bills — once)
        self._groups: dict[int, int] = {}  # sid -> consumer-group count

    # ---------------------------------------------------- producer side
    def send(self, shuffle_id, partition, src, first_seq, bodies):
        names = [queue_name(shuffle_id, partition, g)
                 for g in range(self._groups.get(shuffle_id, 1))]
        batch: list[tuple] = []

        def flush(batch):
            # fan out to every consumer group's queue set; each send is a
            # real (billed) request — queues cannot be read twice. Every
            # queue gets its OWN Message objects: the sim enqueues caller
            # objects directly and Message.receipt is a mutable
            # per-receive slot, so sharing one object across queues would
            # let concurrent sibling-group receives clobber each other's
            # receipt handles
            for name in names:
                # transient send errors retry at the call layer: nothing
                # was enqueued, so the re-send cannot duplicate
                self.retry.call(self.sqs.send_batch, name,
                                [Message(body, seq, src)
                                 for body, seq in batch])

        for i, body in enumerate(bodies):
            batch.append((body, first_seq + i))
            if len(batch) == SQS_BATCH_MESSAGES:
                flush(batch)
                batch = []
        if batch:
            flush(batch)

    def emit_eos(self, shuffle_id, nparts, src, totals):
        for g in range(self._groups.get(shuffle_id, 1)):
            for p in range(nparts):
                self.retry.call(self.sqs.send_batch,
                                queue_name(shuffle_id, p, g),
                                [eos_message(src, totals.get(p, 0))])

    # ---------------------------------------------------- consumer side
    def open_drain(self, shuffle_id, partition, quorum, group=None,
                   consumer_group=0):
        return _SQSDrain(self,
                         queue_name(shuffle_id, partition, consumer_group),
                         quorum, group)

    # ------------------------------------------------- lifecycle + cost
    def open(self, shuffle_id, nparts, groups=1):
        self._groups[shuffle_id] = groups
        for g in range(groups):
            for p in range(nparts):
                name = queue_name(shuffle_id, p, g)
                self._live.add(name)
                self.sqs.create_queue(name)

    def partition_drainable(self, shuffle_id, partition, consumer_group=0):
        """False once this group's queue was deleted — its messages are
        gone with it, so a replayed consumer needs ``reopen`` + upstream
        re-production first."""
        return (queue_name(shuffle_id, partition, consumer_group)
                not in self._released)

    def release_partition(self, shuffle_id, partition, consumer_group=0):
        """Delete this GROUP's queue so a losing speculative duplicate (or
        a late retry of a task that already won) aborts on QueueGone
        immediately instead of blocking a pool thread until the drain
        timeout. Sibling groups' queues stay — their consumers may still
        be draining."""
        name = queue_name(shuffle_id, partition, consumer_group)
        if name not in self._released:
            self._released.add(name)
            self._live.discard(name)
            self.sqs.delete_queue(name)

    def destroy(self, shuffle_id, nparts):
        for g in range(self._groups.get(shuffle_id, 1)):
            for p in range(nparts):
                self.release_partition(shuffle_id, p, g)

    def reopen(self, shuffle_id, nparts, groups=1):
        """Lineage recovery: recreate this shuffle's queues (idempotent
        creates) and forget their released state so a resubmitted producer
        stage can re-fill them and a retried consumer can re-drain."""
        groups = max(groups, self._groups.get(shuffle_id, 1))
        self._groups[shuffle_id] = groups
        for g in range(groups):
            for p in range(nparts):
                name = queue_name(shuffle_id, p, g)
                self._released.discard(name)
                self._live.add(name)
                self.sqs.create_queue(name)

    def gc(self):
        """Queues normally die with their consuming stage; after an abort
        some survive — sweep them so nothing leaks past the job."""
        doomed = list(self._live)
        for name in doomed:
            self._released.add(name)
            self._live.discard(name)
            self.sqs.delete_queue(name)
        return {"queues": len(doomed)} if doomed else {}

    def gc_sids(self, sids):
        """Targeted sweep of only the named shuffles' surviving queues
        (service mode: the blanket ``gc`` would also count queues of
        shuffles this job never owned)."""
        want = {f"shuffle{sid}-" for sid in sids}
        doomed = [name for name in list(self._live)
                  if any(name.startswith(w) for w in want)]
        for name in doomed:
            self._released.add(name)
            self._live.discard(name)
            self.sqs.delete_queue(name)
        return {"queues": len(doomed)} if doomed else {}

    def service_cost(self):
        return self.ledger.sqs_usd


class _SQSDrain(DrainHandle):
    """Visibility-timeout drain of one queue: receives claim messages under
    receipt handles, heartbeats through long folds (never while idle — see
    docs/eos_shuffle.md on livelock-freedom), and defers the batched ack to
    task completion. Port of the pre-subsystem ``_drain_shuffle`` loop."""

    def __init__(self, tr: SQSTransport, name: str, quorum: int,
                 group: list | None):
        self.tr = tr
        self.name = name
        self.state = DrainState(quorum)
        self.held: dict = {}  # (src, seq, kind) -> latest receipt handle
        self._buf: deque = deque()
        self._timeout = tr.cfg.drain_timeout_s
        self._deadline = time.monotonic() + self._timeout
        vis = tr.cfg.visibility_timeout_s
        self._hb_deadline = time.monotonic() + vis / 2
        self._want = None  # None => query the backlog estimate
        # the task-scoped claim group: a join's second drain must keep the
        # first drain's claims alive through its own long folds
        self._group = group if group is not None else []
        self._group.append(self)

    def __next__(self):
        while True:
            if self._buf:
                if time.monotonic() > self._hb_deadline:
                    self._heartbeat()
                return self._buf.popleft()
            if self.state.done():
                raise StopIteration
            self._refill()

    def _refill(self):
        """One receive step, sized from the backlog estimate (the estimate
        is a billable GetQueueAttributes, re-queried only while receives
        keep coming back full)."""
        sqs = self.tr.sqs
        if self._want is None:
            self._want = min(1000, max(SQS_BATCH_MESSAGES,
                                       sqs.approx_len(self.name)))
        try:
            # transient receive errors (nothing claimed) retry at the
            # call layer; QueueGone passes through untouched
            msgs = self.tr.retry.call(sqs.receive_many, self.name,
                                      self._want)
        except QueueGone:
            raise AbortedError(
                f"queue {self.name} deleted — a competing attempt already "
                f"completed this partition") from None
        now = time.monotonic()
        if not msgs:
            self._want = SQS_BATCH_MESSAGES
            if sqs.closed:
                raise AbortedError(f"queue {self.name}: aborted")
            if now > self._deadline:
                raise TimeoutError(
                    f"queue {self.name} incomplete: "
                    f"{len(self.state.seen)} data msgs, eos "
                    f"{len(self.state.eos_total)}/{self.state.quorum}")
            # block on arrival instead of sleep-spinning. Held claims are
            # deliberately NOT heartbeated while idle: when a retry and a
            # speculative twin race on one queue, each needs the OTHER's
            # claims to lapse — idle heartbeats on both sides split the
            # queue permanently. An idle drain instead re-receives its
            # claimed backlog each visibility period (re-billed, deduped).
            sqs.wait_for_messages(self.name, 0.25)
            return
        self._want = None if len(msgs) == self._want else SQS_BATCH_MESSAGES
        progressed = False
        for m in msgs:
            self.held[(m.src, m.seq, m.kind)] = m.receipt
            if m.kind == "eos":
                progressed |= self.state.register_eos(m.src, m.seq)
            elif self.state.register_data(m.src, m.seq):
                progressed = True
                self._buf.append((m.src, m.seq, m.body))
        if progressed:
            self._deadline = now + self._timeout
        elif now > self._deadline:
            # a batch of pure duplicates (e.g. this drain's own lapsed
            # claims redelivering while a producer is stuck) is not
            # progress — without this the inactivity timeout could never
            # fire once the drain held a single claim
            raise TimeoutError(
                f"queue {self.name} stalled: {len(self.state.seen)} data "
                f"msgs, eos {len(self.state.eos_total)}/{self.state.quorum}")

    def _heartbeat(self):
        """Extend every claim the TASK holds — including sibling drains'
        (a join's left-side claims must survive its right-side fold)."""
        vis = self.tr.cfg.visibility_timeout_s
        for handle in self._group:
            receipts = list(handle.held.values())
            for i in range(0, len(receipts), SQS_BATCH_MESSAGES):
                self.tr.sqs.change_visibility(
                    handle.name, receipts[i:i + SQS_BATCH_MESSAGES], vis)
        self._hb_deadline = time.monotonic() + vis / 2

    def ack(self):
        """Batched ack-after-fold, deferred to task completion; stale or
        duplicate receipts are idempotent no-ops inside delete_batch."""
        receipts = list(self.held.values())
        for i in range(0, len(receipts), SQS_BATCH_MESSAGES):
            self.tr.sqs.delete_batch(self.name,
                                     receipts[i:i + SQS_BATCH_MESSAGES])
        self.held = {}
