"""Columnar record batches — the shuffle wire format shared by every
transport (docs/shuffle_transports.md).

Per-record pickling dominated shuffled bytes: a `((month, hour, payment),
count)` record costs ~60 pickle bytes where its data is ~25. When a batch's
key and value columns are homogeneous (same concrete type throughout —
ints, floats, bools, strings, or fixed-arity tuples of those), the batch is
framed as typed arrays instead (core.serde column codecs):

    b"C" | u32 n | (u16 schema-len | schema | u32 payload-len | payload) x2

Ragged data — mixed types, non-pair records, ints beyond int64, a single
record bigger than the body cap — falls back to the length-prefixed pickle
framing (queues.pack_records, which also handles the oversized-record
object-store spill), tagged:

    b"P" | pickle frames...

Both framings are deterministic functions of the record sequence, which the
fault-tolerance story requires: a retry or speculative twin re-packing the
same records must re-emit byte-identical bodies so (src, seq) dedup and
content-addressed exchange keys stay sound.
"""

from __future__ import annotations

import struct
from typing import Any, Callable, Iterable

from repro.core import serde
from repro.core.costs import SQS_MESSAGE_LIMIT
from repro.core.queues import ObjectStoreSim, pack_records, unpack_records

_TAG_COLUMNAR = 0x43  # "C"
_TAG_PICKLE = 0x50    # "P"
_N = struct.Struct("<I")
_SLEN = struct.Struct("<H")
# headroom for tag + count + two (schema, payload-length) headers and the
# nested tuple sub-column prefixes; schemas are tens of bytes, the caps are
# hundreds of KiB, so a flat reserve beats exact bookkeeping
_BODY_RESERVE = 512


def pack_batch(records: Iterable[Any], limit: int = SQS_MESSAGE_LIMIT,
               spill: Callable[[bytes], str] | None = None,
               columnar: bool = True,
               schema: tuple[str, str] | None = None) -> list[bytes]:
    """Pack records into tagged batch bodies, each under ``limit`` bytes.

    ``schema`` is an optional DECLARED (key-schema, value-schema) pair —
    the SQL layer knows its row types at plan time, so its shuffles skip
    the per-batch type sniffing entirely. Records that violate the
    declaration (e.g. a sum outgrowing int64) quietly fall back to the
    sniffing path, which itself falls back to pickle framing."""
    records = records if isinstance(records, list) else list(records)
    if columnar and records:
        if schema is not None:
            try:
                bodies = _pack_columnar(records, limit, declared=schema)
            except Exception:
                bodies = None  # declaration violated: sniff instead
            if bodies is not None:
                return bodies
        bodies = _pack_columnar(records, limit)
        if bodies is not None:
            return bodies
    return [bytes([_TAG_PICKLE]) + body
            for body in pack_records(records, limit - 1, spill)]


def unpack_batch(body: bytes, store: ObjectStoreSim | None = None
                 ) -> list[Any]:
    tag = body[0]
    if tag == _TAG_PICKLE:
        return unpack_records(body[1:], store)
    if tag == _TAG_COLUMNAR:
        return _unpack_columnar(body)
    raise ValueError(f"unknown batch tag {body[:1]!r}")


def is_columnar(body: bytes) -> bool:
    return bool(body) and body[0] == _TAG_COLUMNAR


# ------------------------------------------------------------- internals


def _pack_columnar(records: list, limit: int,
                   declared: tuple[str, str] | None = None
                   ) -> list[bytes] | None:
    """Columnar bodies, or None when the batch is ragged (caller falls back
    to pickle framing). With ``declared`` the schemas come from the plan
    instead of sniffing the batch; a mismatch surfaces as an exception the
    caller treats as a fallback signal."""
    if any(type(r) is not tuple or len(r) != 2 for r in records):
        return None
    keys = [r[0] for r in records]
    vals = [r[1] for r in records]
    if declared is not None:
        kschema, vschema = declared
        if kschema is None or vschema is None:
            return None
        # exact-type conformance, not just encodability: struct.pack
        # would silently coerce int -> float64 / bool -> int64, breaking
        # the round-trip-exactly invariant the sniffing path guarantees
        if not (serde.column_conforms(kschema, keys)
                and serde.column_conforms(vschema, vals)):
            return None
    else:
        kschema = serde.column_schema(keys)
        vschema = serde.column_schema(vals)
    if kschema is None or vschema is None:
        return None
    sizes = [a + b for a, b in zip(serde.column_value_sizes(kschema, keys),
                                   serde.column_value_sizes(vschema, vals))]
    cap = limit - _BODY_RESERVE
    if cap <= 0 or max(sizes) > cap:
        return None  # a single oversized record rides the spill path instead
    bodies: list[bytes] = []
    start, acc = 0, 0
    for i, s in enumerate(sizes):
        if acc + s > cap:
            bodies.append(_encode_chunk(kschema, vschema,
                                        keys[start:i], vals[start:i]))
            start, acc = i, 0
        acc += s
    bodies.append(_encode_chunk(kschema, vschema, keys[start:], vals[start:]))
    if any(len(b) > limit for b in bodies):
        return None  # reserve blown (pathological schema): play it safe
    return bodies


def _encode_chunk(kschema: str, vschema: str, keys: list, vals: list
                  ) -> bytes:
    parts = [bytes([_TAG_COLUMNAR]), _N.pack(len(keys))]
    for schema, col in ((kschema, keys), (vschema, vals)):
        sblob = schema.encode("ascii")
        payload = serde.encode_column(schema, col)
        parts += [_SLEN.pack(len(sblob)), sblob, _N.pack(len(payload)),
                  payload]
    return b"".join(parts)


def _unpack_columnar(body: bytes) -> list:
    (n,) = _N.unpack_from(body, 1)
    off = 1 + _N.size
    cols = []
    for _ in range(2):
        (slen,) = _SLEN.unpack_from(body, off)
        off += _SLEN.size
        schema = body[off:off + slen].decode("ascii")
        off += slen
        (plen,) = _N.unpack_from(body, off)
        off += _N.size
        cols.append(serde.decode_column(schema, body[off:off + plen], n))
        off += plen
    return list(zip(cols[0], cols[1]))
