"""Columnar record batches — the shuffle wire format shared by every
transport (docs/shuffle_transports.md).

Per-record pickling dominated shuffled bytes: a `((month, hour, payment),
count)` record costs ~60 pickle bytes where its data is ~25. When a batch's
key and value columns are homogeneous (same concrete type throughout —
ints, floats, bools, strings, or fixed-arity tuples of those), the batch is
framed as typed arrays instead (core.serde column codecs):

    b"C" | u32 n | (u16 schema-len | schema | u32 payload-len | payload) x2

Ragged data — mixed types, non-pair records, ints beyond int64, a single
record bigger than the body cap — falls back to the length-prefixed pickle
framing (queues.pack_records, which also handles the oversized-record
object-store spill), tagged:

    b"P" | pickle frames...

Both framings are deterministic functions of the record sequence, which the
fault-tolerance story requires: a retry or speculative twin re-packing the
same records must re-emit byte-identical bodies so (src, seq) dedup and
content-addressed exchange keys stay sound.

Two entry points feed this module. ``pack_batch`` takes row-major records
(the row engine's path). ``pack_batch_columns`` takes the key/value COLUMNS
directly — the vectorized engine (docs/vectorized_execution.md) keeps data
column-major from scan to shuffle via ``KVBatch`` carriers, and packing
straight from columns skips the rows→columns transpose and the per-batch
re-sniff while producing byte-identical bodies to the row path for the
same record sequence (asserted in tests/test_columnar_batches.py).
"""

from __future__ import annotations

import struct
from typing import Any, Callable, Iterable

from repro.core import serde
from repro.core.costs import SQS_MESSAGE_LIMIT
from repro.core.queues import ObjectStoreSim, pack_records, unpack_records

_TAG_COLUMNAR = 0x43  # "C"
_TAG_PICKLE = 0x50    # "P"
_N = struct.Struct("<I")
_SLEN = struct.Struct("<H")
# headroom for tag + count + two (schema, payload-length) headers and the
# nested tuple sub-column prefixes; schemas are tens of bytes, the caps are
# hundreds of KiB, so a flat reserve beats exact bookkeeping
_BODY_RESERVE = 512


def pack_batch(records: Iterable[Any], limit: int = SQS_MESSAGE_LIMIT,
               spill: Callable[[bytes], str] | None = None,
               columnar: bool = True,
               schema: tuple[str, str] | None = None) -> list[bytes]:
    """Pack records into tagged batch bodies, each under ``limit`` bytes.

    ``schema`` is an optional DECLARED (key-schema, value-schema) pair —
    the SQL layer knows its row types at plan time, so its shuffles skip
    the per-batch type sniffing entirely. Records that violate the
    declaration (e.g. a sum outgrowing int64) quietly fall back to the
    sniffing path, which itself falls back to pickle framing."""
    records = records if isinstance(records, list) else list(records)
    if columnar and records:
        if schema is not None:
            try:
                bodies = _pack_columnar(records, limit, declared=schema)
            except Exception:
                bodies = None  # declaration violated: sniff instead
            if bodies is not None:
                return bodies
            bodies = _pack_declared_runs(records, limit, spill, schema)
            if bodies is not None:
                return bodies
        bodies = _pack_columnar(records, limit)
        if bodies is not None:
            return bodies
    return [bytes([_TAG_PICKLE]) + body
            for body in pack_records(records, limit - 1, spill)]


def unpack_batch(body: bytes, store: ObjectStoreSim | None = None
                 ) -> list[Any]:
    tag = body[0]
    if tag == _TAG_PICKLE:
        return unpack_records(body[1:], store)
    if tag == _TAG_COLUMNAR:
        return _unpack_columnar(body)
    raise ValueError(f"unknown batch tag {body[:1]!r}")


def is_columnar(body: bytes) -> bool:
    return bool(body) and body[0] == _TAG_COLUMNAR


# --------------------------------------------------- column-major carrier


class KVBatch:
    """A run of (key, value) records held column-major between a fused
    vectorized operator and the shuffle writer.

    ``kcols``/``vcols`` are plain Python lists — one list per key/value
    tuple field, all the same length ``n`` — so core never needs numpy.
    Keys and values are always tuples on the wire for SQL shuffles, hence
    the per-field layout; ``kschema``/``vschema`` are the matching
    ``t(...)`` serde schemas (or None when the plan declared none)."""

    __slots__ = ("kcols", "vcols", "kschema", "vschema", "n")

    def __init__(self, kcols, vcols, kschema=None, vschema=None):
        if not kcols or not vcols:
            raise ValueError("KVBatch needs at least one key and value col")
        self.kcols = kcols
        self.vcols = vcols
        self.kschema = kschema
        self.vschema = vschema
        self.n = len(kcols[0])

    def key_tuples(self) -> list:
        return list(zip(*self.kcols))

    def iter_rows(self):
        """Expand back to the row representation: (key_tuple, val_tuple)."""
        return zip(zip(*self.kcols), zip(*self.vcols))

    def select(self, idxs) -> "KVBatch":
        """A new batch holding the rows at ``idxs`` (in that order)."""
        return KVBatch([[c[i] for i in idxs] for c in self.kcols],
                       [[c[i] for i in idxs] for c in self.vcols],
                       self.kschema, self.vschema)


def iter_records(it: Iterable[Any]):
    """Expand any KVBatch carriers in ``it`` back into plain records —
    the bridge for consumers that iterate row-at-a-time (result
    collection, the cluster backend's write loops, sorted re-emission)."""
    for rec in it:
        if isinstance(rec, KVBatch):
            yield from rec.iter_rows()
        else:
            yield rec


def pack_batch_columns(batch: KVBatch, limit: int = SQS_MESSAGE_LIMIT,
                       spill: Callable[[bytes], str] | None = None,
                       columnar: bool = True) -> list[bytes]:
    """Pack a column-major batch into wire bodies BYTE-IDENTICAL to
    ``pack_batch(list(batch.iter_rows()), ...)`` with the same declared
    schema — but without transposing to rows or re-sniffing types. Falls
    back to the row path (which run-splits / pickle-frames) whenever a
    column does not conform to its declared schema."""
    ks, vs = batch.kschema, batch.vschema
    if (columnar and ks is not None and vs is not None
            and ks.startswith("t(") and vs.startswith("t(")):
        ksubs = serde._split_tuple_schema(ks)
        vsubs = serde._split_tuple_schema(vs)
        if (len(ksubs) == len(batch.kcols) and len(vsubs) == len(batch.vcols)
                and all(serde.column_conforms(sub, col) for sub, col in
                        zip(ksubs + vsubs, batch.kcols + batch.vcols))):
            bodies = _pack_columnar_cols(batch, ksubs, vsubs, limit)
            if bodies is not None:
                return bodies
    return pack_batch(list(batch.iter_rows()), limit, spill, columnar,
                      schema=(ks, vs))


# ------------------------------------------------------------- internals


def _pack_columnar(records: list, limit: int,
                   declared: tuple[str, str] | None = None
                   ) -> list[bytes] | None:
    """Columnar bodies, or None when the batch is ragged (caller falls back
    to pickle framing). With ``declared`` the schemas come from the plan
    instead of sniffing the batch; a mismatch surfaces as an exception the
    caller treats as a fallback signal."""
    if any(type(r) is not tuple or len(r) != 2 for r in records):
        return None
    keys = [r[0] for r in records]
    vals = [r[1] for r in records]
    if declared is not None:
        kschema, vschema = declared
        if kschema is None or vschema is None:
            return None
        # exact-type conformance, not just encodability: struct.pack
        # would silently coerce int -> float64 / bool -> int64, breaking
        # the round-trip-exactly invariant the sniffing path guarantees
        if not (serde.column_conforms(kschema, keys)
                and serde.column_conforms(vschema, vals)):
            return None
    else:
        kschema = serde.column_schema(keys)
        vschema = serde.column_schema(vals)
    if kschema is None or vschema is None:
        return None
    sizes = [a + b for a, b in zip(serde.column_value_sizes(kschema, keys),
                                   serde.column_value_sizes(vschema, vals))]
    cap = limit - _BODY_RESERVE
    if cap <= 0 or max(sizes) > cap:
        return None  # a single oversized record rides the spill path instead
    bodies: list[bytes] = []
    start, acc = 0, 0
    for i, s in enumerate(sizes):
        if acc + s > cap:
            bodies.append(_encode_chunk(kschema, vschema,
                                        keys[start:i], vals[start:i]))
            start, acc = i, 0
        acc += s
    bodies.append(_encode_chunk(kschema, vschema, keys[start:], vals[start:]))
    if any(len(b) > limit for b in bodies):
        return None  # reserve blown (pathological schema): play it safe
    return bodies


def _encode_chunk(kschema: str, vschema: str, keys: list, vals: list
                  ) -> bytes:
    parts = [bytes([_TAG_COLUMNAR]), _N.pack(len(keys))]
    for schema, col in ((kschema, keys), (vschema, vals)):
        sblob = schema.encode("ascii")
        payload = serde.encode_column(schema, col)
        parts += [_SLEN.pack(len(sblob)), sblob, _N.pack(len(payload)),
                  payload]
    return b"".join(parts)


def _pack_declared_runs(records: list, limit: int,
                        spill: Callable[[bytes], str] | None,
                        schema: tuple[str, str]) -> list[bytes] | None:
    """Mid-stream fallback fix: when SOME records violate the declared
    schema, the old path dropped the whole call to sniffing (usually all
    the way to pickle framing), forcing downstream per-batch re-sniffing.
    Instead split the sequence into maximal runs — conforming runs keep
    the declared columnar framing, violating runs pickle-frame — so a
    single ragged record no longer degrades its neighbours. Still a
    deterministic function of the record sequence. Returns None when no
    record conforms (nothing to salvage: caller sniffs as before)."""
    kschema, vschema = schema
    if kschema is None or vschema is None:
        return None
    flags = [type(r) is tuple and len(r) == 2
             and serde.column_conforms(kschema, [r[0]])
             and serde.column_conforms(vschema, [r[1]])
             for r in records]
    if not any(flags):
        return None
    bodies: list[bytes] = []
    start = 0
    for i in range(1, len(records) + 1):
        if i < len(records) and flags[i] == flags[start]:
            continue
        run = records[start:i]
        packed = None
        if flags[start]:
            try:
                packed = _pack_columnar(run, limit, declared=schema)
            except Exception:
                packed = None
        if packed is None:  # violating run, or oversized record in a run
            packed = [bytes([_TAG_PICKLE]) + body
                      for body in pack_records(run, limit - 1, spill)]
        bodies.extend(packed)
        start = i
    return bodies


def _pack_columnar_cols(batch: KVBatch, ksubs: list[str], vsubs: list[str],
                        limit: int) -> list[bytes] | None:
    """Chunk + encode straight from columns. Mirrors ``_pack_columnar``
    exactly (same size model, same chunk boundaries, same encoding) so the
    bodies are byte-identical to the row path's for the same records."""
    sizes = [0] * batch.n
    for sub, col in zip(ksubs + vsubs, batch.kcols + batch.vcols):
        for i, s in enumerate(serde.column_value_sizes(sub, col)):
            sizes[i] += s
    cap = limit - _BODY_RESERVE
    if cap <= 0 or max(sizes) > cap:
        return None  # oversized record: row path spills it
    bodies: list[bytes] = []
    start, acc = 0, 0
    for i, s in enumerate(sizes):
        if acc + s > cap:
            bodies.append(_encode_chunk_cols(batch, ksubs, vsubs, start, i))
            start, acc = i, 0
        acc += s
    bodies.append(_encode_chunk_cols(batch, ksubs, vsubs, start, batch.n))
    if any(len(b) > limit for b in bodies):
        return None
    return bodies


def _encode_chunk_cols(batch: KVBatch, ksubs: list[str], vsubs: list[str],
                       lo: int, hi: int) -> bytes:
    parts = [bytes([_TAG_COLUMNAR]), _N.pack(hi - lo)]
    for schema, subs, cols in ((batch.kschema, ksubs, batch.kcols),
                               (batch.vschema, vsubs, batch.vcols)):
        sblob = schema.encode("ascii")
        # same layout encode_column emits for "t(...)": u32 length prefix
        # per sub-column blob, concatenated
        payload_parts = []
        for sub, col in zip(subs, cols):
            blob = serde.encode_column(sub, col[lo:hi])
            payload_parts.append(serde._U32.pack(len(blob)))
            payload_parts.append(blob)
        payload = b"".join(payload_parts)
        parts += [_SLEN.pack(len(sblob)), sblob, _N.pack(len(payload)),
                  payload]
    return b"".join(parts)


def _unpack_columnar(body: bytes) -> list:
    (n,) = _N.unpack_from(body, 1)
    off = 1 + _N.size
    cols = []
    for _ in range(2):
        (slen,) = _SLEN.unpack_from(body, off)
        off += _SLEN.size
        schema = body[off:off + slen].decode("ascii")
        off += slen
        (plen,) = _N.unpack_from(body, off)
        off += _N.size
        cols.append(serde.decode_column(schema, body[off:off + plen], n))
        off += plen
    return list(zip(cols[0], cols[1]))
