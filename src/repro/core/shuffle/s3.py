"""S3ExchangeTransport — a Lambada-style serverless exchange operator over
object storage: no queues at all.

Producers write one CONTENT-ADDRESSED object per packed output batch,

    _exchange/{sid}/p{partition}/{src}-{seq:08d}-{sha1(body)[:12]}

so a retry or speculative twin re-emitting the byte-identical batch
overwrites idempotently instead of duplicating. End-of-stream rides the
manifest object ``eos-{src}`` (one per partition, value = the producer's
total sequence count there), written by the final link of a chained task —
the consumer's EOS quorum comes from ``StagePlan.producer_counts`` exactly
as on the queue transport.

Consumers DISCOVER work by polling LIST (S3 has no arrival notification —
the recurring cost of an object-store shuffle, billed per LIST), GET fresh
batches as they appear, and terminate on the manifest quorum. Discovery is
BATCHED at the shuffle level: all of a shuffle's drains share one
``_SidIndex`` that LISTs ``_exchange/{sid}/`` once and buckets the result
per partition, so a 16-partition fan-in costs ~one LIST per poll interval
instead of sixteen. Reads are non-destructive, so ``ack`` is a no-op and a
consumer that dies mid-drain recovers by simply re-listing — no visibility
leases, no claim races.

MULTI-CONSUMER fan-out (docs/dag_fanout.md) is where an object exchange
shines: the batch objects are written ONCE and every consumer group reads
them non-destructively — no per-group copies, unlike the queue transport.
Only the release protocol is per group: ``release_partition`` drops a
``.released-g{g}`` tombstone (aborting that group's losing twins on their
next poll, the moral equivalent of QueueGone) and the partition's data
objects are deleted only once EVERY group has tombstoned it.

Unlike SQS's 256 KiB messages, one exchange object may be tens of MiB
(costs.S3_EXCHANGE_BATCH_LIMIT); objects past the multipart threshold bill
as Create + UploadParts + Complete. ``gc`` removes the whole
``_exchange/`` tree at job end, tombstones included.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import deque

from repro.core.costs import S3_EXCHANGE_BATCH_LIMIT
from repro.core.shuffle.base import (AbortedError, DrainHandle, DrainState,
                                     LostShuffleInput, ShuffleTransport)

EXCHANGE_PREFIX = "_exchange/"
_TOMBSTONE = ".released-g"


def _shuffle_prefix(shuffle_id: int) -> str:
    return f"{EXCHANGE_PREFIX}{shuffle_id}/"


def _partition_prefix(shuffle_id: int, partition: int) -> str:
    return f"{_shuffle_prefix(shuffle_id)}p{partition}/"


class _SidIndex:
    """Shared discovery state for one shuffle: a single LIST of
    ``_exchange/{sid}/`` feeds every partition's drain (and every consumer
    group — the keys are the same objects). The interval between LISTs
    backs off while nothing new appears and snaps back on fresh keys, so
    idle polling stays cheap without adding arrival latency."""

    def __init__(self):
        self.lock = threading.Lock()
        self.known: set[str] = set()
        self.by_partition: dict[int, list[str]] = {}
        self.last_list = float("-inf")
        self.interval = 0.0


class S3ExchangeTransport(ShuffleTransport):
    name = "s3"
    batch_limit = S3_EXCHANGE_BATCH_LIMIT

    def __init__(self, cfg, ledger, store, sqs):
        super().__init__(cfg, ledger, store, sqs)
        self._released: set = set()  # (sid, partition, group) tombstoned
        self._groups: dict[int, int] = {}  # sid -> consumer-group count
        self._index: dict[int, _SidIndex] = {}
        self._index_lock = threading.Lock()

    # ---------------------------------------------------- producer side
    def send(self, shuffle_id, partition, src, first_seq, bodies):
        prefix = _partition_prefix(shuffle_id, partition)
        for i, body in enumerate(bodies):
            digest = hashlib.sha1(body).hexdigest()[:12]
            # content-addressed: a PUT retried after a transient 503
            # overwrites itself idempotently
            self.retry.call(self.store.put,
                            f"{prefix}{src}-{first_seq + i:08d}-{digest}",
                            body)

    def emit_eos(self, shuffle_id, nparts, src, totals):
        for p in range(nparts):
            self.retry.call(
                self.store.put_obj,
                f"{_partition_prefix(shuffle_id, p)}eos-{src}",
                totals.get(p, 0))

    # ---------------------------------------------------- consumer side
    def open_drain(self, shuffle_id, partition, quorum, group=None,
                   consumer_group=0):
        return _S3Drain(self, shuffle_id, partition, quorum, consumer_group)

    def _sid_index(self, shuffle_id: int) -> _SidIndex:
        with self._index_lock:
            idx = self._index.get(shuffle_id)
            if idx is None:
                idx = self._index[shuffle_id] = _SidIndex()
            return idx

    def discover(self, shuffle_id: int):
        """One shared, rate-limited LIST of the whole shuffle prefix;
        fresh keys are bucketed per partition for every drain to consume.
        This is the batched-discovery path: N partitions' (and G groups')
        drains cost ONE LIST per poll interval, not N."""
        idx = self._sid_index(shuffle_id)
        with idx.lock:
            now = time.monotonic()
            if now - idx.last_list < idx.interval:
                return
            idx.last_list = now
            prefix = _shuffle_prefix(shuffle_id)
            fresh = [k for k in self.retry.call(self.store.list, prefix)
                     if k not in idx.known]
            if fresh:
                # snap back to the FLOOR, not zero: during active
                # production nearly every LIST finds something fresh, and
                # a zero interval would let every drain re-LIST on its own
                # poll — exactly the per-partition request storm batching
                # is meant to end
                idx.interval = 0.002
                for key in fresh:
                    idx.known.add(key)
                    tail = key[len(prefix):]  # "p{n}/..."
                    p = int(tail[1:tail.index("/")])
                    idx.by_partition.setdefault(p, []).append(key)
            else:
                idx.interval = min(max(idx.interval * 2, 0.002), 0.05)

    def partition_keys(self, shuffle_id: int, partition: int) -> list[str]:
        idx = self._sid_index(shuffle_id)
        with idx.lock:
            return list(idx.by_partition.get(partition, ()))

    # ------------------------------------------------- lifecycle + cost
    def open(self, shuffle_id, nparts, groups=1):
        self._groups[shuffle_id] = groups
        self._sid_index(shuffle_id)  # prefixes are implicit; index is not

    def add_group(self, shuffle_id, groups):
        """A consumer group joined AFTER ``open`` — a cross-job reader of
        a service-shared shuffle (docs/multi_tenant.md). Reads are
        non-destructive so the newcomer needs no channel setup; only the
        all-groups-released data reclaim in ``release_partition`` must
        learn to wait for it."""
        self._groups[shuffle_id] = max(self._groups.get(shuffle_id, 1),
                                       groups)

    def partition_drainable(self, shuffle_id, partition, consumer_group=0):
        """False once this group released the partition: the tombstone
        aborts any new drain and the data objects may already be deleted,
        so a replayed consumer needs ``reopen`` + upstream re-production
        first."""
        return (shuffle_id, partition, consumer_group) not in self._released

    def release_partition(self, shuffle_id, partition, consumer_group=0):
        key = (shuffle_id, partition, consumer_group)
        if key in self._released:
            return
        self._released.add(key)
        prefix = _partition_prefix(shuffle_id, partition)
        # abort marker for THIS group's competing drains first
        self.retry.call(self.store.put,
                        f"{prefix}{_TOMBSTONE}{consumer_group}", b"")
        groups = self._groups.get(shuffle_id, 1)
        if all((shuffle_id, partition, g) in self._released
               for g in range(groups)):
            # every consumer group drained this partition: the data is
            # dead (tombstones stay until gc so late losers still abort)
            for obj in self.retry.call(self.store.list, prefix):
                if _TOMBSTONE not in obj:
                    self.store.delete(obj)

    def destroy(self, shuffle_id, nparts):
        for p in range(nparts):
            for g in range(self._groups.get(shuffle_id, 1)):
                self.release_partition(shuffle_id, p, g)

    def reopen(self, shuffle_id, nparts, groups=1):
        """Lineage recovery: un-release this shuffle so a resubmitted
        producer stage can re-fill it. Deletes the partition tombstones
        (data objects are content-addressed — re-emission recreates them
        in place) and purges those tombstone keys from the shared
        discovery index, or a resumed drain would abort on the stale
        marker it discovered before the recovery."""
        self._groups.setdefault(shuffle_id, groups)
        self._released = {k for k in self._released
                          if k[0] != shuffle_id}
        prefix = _shuffle_prefix(shuffle_id)
        doomed = [k for k in self.retry.call(self.store.list, prefix)
                  if _TOMBSTONE in k]
        for k in doomed:
            self.store.delete(k)
        # purge only the authoritative ``known`` set: the per-partition
        # bucket lists keep their entries (live drains hold cursor
        # positions into them) and drains re-check a tombstone against
        # ``known`` before aborting on it
        idx = self._sid_index(shuffle_id)
        with idx.lock:
            idx.known = {k for k in idx.known if _TOMBSTONE not in k}

    def tombstone_active(self, shuffle_id: int, key: str) -> bool:
        """False once ``reopen`` retired this tombstone — a drain that
        discovered it before the recovery must not abort on it."""
        idx = self._sid_index(shuffle_id)
        with idx.lock:
            return key in idx.known

    def gc(self):
        n = self.store.delete_prefix(EXCHANGE_PREFIX)
        self._released.clear()
        with self._index_lock:
            self._index.clear()
        return {EXCHANGE_PREFIX: n} if n else {}

    def gc_sids(self, sids):
        """Targeted sweep of only the named shuffles (service mode: the
        blanket ``gc`` reaps ``_exchange/`` wholesale and would delete
        shuffles other live jobs are still draining). ``delete_prefix``
        bypasses fault injection, so this sweep cannot flake under a
        service-wide chaos plan."""
        n = 0
        for sid in sids:
            n += self.store.delete_prefix(_shuffle_prefix(sid))
            self._released = {k for k in self._released if k[0] != sid}
            with self._index_lock:
                self._index.pop(sid, None)
        return {EXCHANGE_PREFIX: n} if n else {}

    def service_cost(self):
        return self.ledger.s3_usd


class _S3Drain(DrainHandle):
    """Shared-LIST discovery with per-drain exponential backoff (an early
    pipelined consumer must not spin while its producers compute), GET per
    fresh batch, manifest-quorum termination. The drain keeps a cursor
    into its partition's shared key bucket, so work discovered by ANY
    drain of this shuffle is visible to all of them."""

    def __init__(self, tr: S3ExchangeTransport, shuffle_id: int,
                 partition: int, quorum: int, consumer_group: int):
        self.tr = tr
        self.sid = shuffle_id
        self.partition = partition
        self.consumer_group = consumer_group
        self.prefix = _partition_prefix(shuffle_id, partition)
        self.state = DrainState(quorum)
        self._pending: deque = deque()  # (src, seq, key) discovered, un-GET
        self._deferred: list = []  # discovered keys whose GET found nothing
        self._eos_pending: list = []  # eos manifests awaiting a readable GET
        self._cursor = 0  # position in the shared partition bucket
        self._timeout = tr.cfg.drain_timeout_s
        self._deadline = time.monotonic() + self._timeout
        self._backoff = 0.002

    def __next__(self):
        while True:
            if self._pending:
                src, seq, key = self._pending.popleft()
                try:
                    body = self.tr.retry.call(self.tr.store.get, key)
                except KeyError:
                    # the advertised object is GONE. Either a release
                    # deleted it (a tombstone explains that — the next
                    # poll aborts on it) or an acknowledged write was
                    # LOST. Defer instead of deciding: a concurrent
                    # stage resubmission may rewrite the byte-identical
                    # key; the drain deadline arbitrates.
                    self._deferred.append((src, seq, key))
                    continue
                return (src, seq, body)
            if self.state.done() and not self._deferred:
                raise StopIteration
            self._poll()

    def _poll(self):
        if self.tr.sqs.closed:
            raise AbortedError(f"s3 exchange {self.prefix}: aborted")
        self.tr.discover(self.sid)
        bucket = self.tr.partition_keys(self.sid, self.partition)
        progressed = False
        for key in bucket[self._cursor:]:
            tail = key[len(self.prefix):]
            if tail.startswith(_TOMBSTONE):
                if (int(tail[len(_TOMBSTONE):]) == self.consumer_group
                        and self.tr.tombstone_active(self.sid, key)):
                    raise AbortedError(
                        f"s3 exchange {self.prefix} released for group "
                        f"{self.consumer_group} — a competing attempt "
                        f"already completed this partition")
                continue  # a sibling group's (or a retired) release
            if tail.startswith("eos-"):
                self._eos_pending.append(key)
            else:
                src, seq, _digest = tail.split("-")
                if self.state.register_data(src, int(seq)):
                    self._pending.append((src, int(seq), key))
                    progressed = True
        self._cursor = len(bucket)
        if self._eos_pending:
            # a discovered EOS manifest that GETs to nothing is either a
            # released partition (the tombstone branch above handles that
            # on a later poll) or a LOST object — keep trying until the
            # manifest reappears (stage resubmission rewrites it) or the
            # deadline arbitrates
            still = []
            for key in self._eos_pending:
                try:
                    total = self.tr.retry.call(self.tr.store.get_obj, key)
                except KeyError:
                    still.append(key)
                    continue
                progressed |= self.state.register_eos(
                    key[len(self.prefix) + 4:], total)
            self._eos_pending = still
        # vanished-object re-check: a resubmitted producer rewrites the
        # byte-identical key in place — promote it back to pending the
        # moment it reappears (HEAD, unbilled metadata)
        if self._deferred:
            still_gone = []
            for src, seq, key in self._deferred:
                if self.tr.store.exists(key):
                    self._pending.append((src, seq, key))
                    progressed = True
                else:
                    still_gone.append((src, seq, key))
            self._deferred = still_gone
        now = time.monotonic()
        if progressed:
            self._deadline = now + self._timeout
            self._backoff = 0.002
            return
        if self._pending or (self.state.done() and not self._deferred):
            return
        if now > self._deadline:
            if len(self.state.eos_total) >= self.state.quorum > 0:
                # every producer finished and closed its stream, yet
                # advertised batches never materialized: an acknowledged
                # durable write was lost. Only producing-stage
                # resubmission can recreate it.
                # name the producers whose output vanished so the
                # scheduler can resubmit exactly those tasks instead of
                # the whole stage (src encodes stage/index): a producer is
                # short when its EOS-advertised count exceeds what was
                # received — whether the object vanished AFTER discovery
                # (deferred) or was lost before any LIST ever saw it
                short = {src for src, total in self.state.eos_total.items()
                         if self.state.per_src.get(src, 0) < total}
                short |= {src for src, _, _ in self._deferred}
                missing = sum(
                    total - self.state.per_src.get(src, 0)
                    for src, total in self.state.eos_total.items()
                ) + len(self._deferred)
                err = LostShuffleInput(
                    f"s3 exchange {self.prefix}: producer quorum complete "
                    f"but {missing} advertised batch(es) from "
                    f"{sorted(short)} missing past the drain deadline — "
                    f"exchange object(s) lost after write")
                err.detail = {"srcs": sorted(short)}
                raise err
            # quorum incomplete: name the producers whose EOS manifest DID
            # arrive so the scheduler — once it knows every producing
            # stage finished — can resubmit exactly the absent ones (a
            # lost eos-{src} manifest is indistinguishable from a slow
            # producer down here; the scheduler has the stage ledger)
            err2 = TimeoutError(
                f"s3 exchange {self.prefix} incomplete: "
                f"{len(self.state.seen)} batches, eos "
                f"{len(self.state.eos_total)}/{self.state.quorum}")
            err2.detail = {"sid": self.sid,
                           "have_eos": sorted(self.state.eos_total)}
            raise err2
        time.sleep(self._backoff)
        self._backoff = min(self._backoff * 2, 0.1)

    def ack(self):
        pass  # reads are non-destructive; a retry recovers by re-listing
