"""S3ExchangeTransport — a Lambada-style serverless exchange operator over
object storage: no queues at all.

Producers write one CONTENT-ADDRESSED object per packed output batch,

    _exchange/{sid}/p{partition}/{src}-{seq:08d}-{sha1(body)[:12]}

so a retry or speculative twin re-emitting the byte-identical batch
overwrites idempotently instead of duplicating. End-of-stream rides the
manifest object ``eos-{src}`` (one per partition, value = the producer's
total sequence count there), written by the final link of a chained task —
the consumer's EOS quorum comes from ``StagePlan.producer_counts`` exactly
as on the queue transport.

Consumers DISCOVER work by polling LIST on their partition prefix (S3 has
no arrival notification — the recurring cost of an object-store shuffle,
billed per LIST), GET fresh batches as they appear, and terminate on the
manifest quorum. Reads are non-destructive, so ``ack`` is a no-op and a
consumer that dies mid-drain recovers by simply re-listing — no visibility
leases, no claim races.

Unlike SQS's 256 KiB messages, one exchange object may be tens of MiB
(costs.S3_EXCHANGE_BATCH_LIMIT); objects past the multipart threshold bill
as Create + UploadParts + Complete.

Fast abort for losing speculative twins: when a consumer completes,
``release_partition`` drops a ``.released`` tombstone and deletes the
partition's objects — a competing drain hits the tombstone on its next
LIST (or a KeyError on an already-deleted GET) and aborts, the moral
equivalent of QueueGone. ``gc`` removes the whole ``_exchange/`` tree at
job end, tombstones included.
"""

from __future__ import annotations

import hashlib
import time
from collections import deque

from repro.core.costs import S3_EXCHANGE_BATCH_LIMIT
from repro.core.shuffle.base import (AbortedError, DrainHandle, DrainState,
                                     ShuffleTransport)

EXCHANGE_PREFIX = "_exchange/"
_TOMBSTONE = ".released"


def _partition_prefix(shuffle_id: int, partition: int) -> str:
    return f"{EXCHANGE_PREFIX}{shuffle_id}/p{partition}/"


class S3ExchangeTransport(ShuffleTransport):
    name = "s3"
    batch_limit = S3_EXCHANGE_BATCH_LIMIT

    def __init__(self, cfg, ledger, store, sqs):
        super().__init__(cfg, ledger, store, sqs)
        self._released: set = set()

    # ---------------------------------------------------- producer side
    def send(self, shuffle_id, partition, src, first_seq, bodies):
        prefix = _partition_prefix(shuffle_id, partition)
        for i, body in enumerate(bodies):
            digest = hashlib.sha1(body).hexdigest()[:12]
            self.store.put(f"{prefix}{src}-{first_seq + i:08d}-{digest}",
                           body)

    def emit_eos(self, shuffle_id, nparts, src, totals):
        for p in range(nparts):
            self.store.put_obj(
                f"{_partition_prefix(shuffle_id, p)}eos-{src}",
                totals.get(p, 0))

    # ---------------------------------------------------- consumer side
    def open_drain(self, shuffle_id, partition, quorum, group=None):
        return _S3Drain(self, _partition_prefix(shuffle_id, partition),
                        quorum)

    # ------------------------------------------------- lifecycle + cost
    def open(self, shuffle_id, nparts):
        pass  # prefixes are implicit — nothing to create, nothing billed

    def release_partition(self, shuffle_id, partition):
        prefix = _partition_prefix(shuffle_id, partition)
        if prefix in self._released:
            return
        self._released.add(prefix)
        tomb = prefix + _TOMBSTONE
        self.store.put(tomb, b"")  # abort marker FIRST, then free the data
        for key in self.store.list(prefix):
            if key != tomb:
                self.store.delete(key)

    def destroy(self, shuffle_id, nparts):
        # tombstones stay until gc: a loser twin that starts its LIST after
        # the stage ended must still abort fast instead of waiting out the
        # drain timeout
        for p in range(nparts):
            self.release_partition(shuffle_id, p)

    def gc(self):
        n = self.store.delete_prefix(EXCHANGE_PREFIX)
        self._released.clear()
        return {EXCHANGE_PREFIX: n} if n else {}

    def service_cost(self):
        return self.ledger.s3_usd


class _S3Drain(DrainHandle):
    """Polling-LIST discovery with exponential backoff (an early pipelined
    consumer must not spin while its producers compute), GET per fresh
    batch, manifest-quorum termination."""

    def __init__(self, tr: S3ExchangeTransport, prefix: str, quorum: int):
        self.tr = tr
        self.prefix = prefix
        self.state = DrainState(quorum)
        self._pending: deque = deque()  # (src, seq, key) discovered, un-GET
        self._listed: set = set()
        self._timeout = tr.cfg.drain_timeout_s
        self._deadline = time.monotonic() + self._timeout
        self._backoff = 0.002

    def __next__(self):
        while True:
            if self._pending:
                src, seq, key = self._pending.popleft()
                try:
                    body = self.tr.store.get(key)
                except KeyError:
                    raise AbortedError(
                        f"{key} vanished mid-drain — partition released by "
                        f"a competing attempt") from None
                return (src, seq, body)
            if self.state.done():
                raise StopIteration
            self._poll()

    def _poll(self):
        if self.tr.sqs.closed:
            raise AbortedError(f"s3 exchange {self.prefix}: aborted")
        progressed = False
        for key in self.tr.store.list(self.prefix):
            if key in self._listed:
                continue
            tail = key[len(self.prefix):]
            if tail == _TOMBSTONE:
                raise AbortedError(
                    f"s3 exchange {self.prefix} released — a competing "
                    f"attempt already completed this partition")
            self._listed.add(key)
            if tail.startswith("eos-"):
                try:
                    total = self.tr.store.get_obj(key)
                except KeyError:
                    raise AbortedError(
                        f"{key} vanished mid-drain — partition released"
                    ) from None
                progressed |= self.state.register_eos(tail[4:], total)
            else:
                src, seq, _digest = tail.split("-")
                if self.state.register_data(src, int(seq)):
                    self._pending.append((src, int(seq), key))
                    progressed = True
        now = time.monotonic()
        if progressed:
            self._deadline = now + self._timeout
            self._backoff = 0.002
            return
        if self._pending or self.state.done():
            return
        if now > self._deadline:
            raise TimeoutError(
                f"s3 exchange {self.prefix} incomplete: "
                f"{len(self.state.seen)} batches, eos "
                f"{len(self.state.eos_total)}/{self.state.quorum}")
        time.sleep(self._backoff)
        self._backoff = min(self._backoff * 2, 0.1)

    def ack(self):
        pass  # reads are non-destructive; a retry recovers by re-listing
