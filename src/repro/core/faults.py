"""Chaos fault-injection subsystem (docs/fault_tolerance.md).

``FaultPlan`` is THE fault-schedule schema — the legacy per-task dict the
scheduler used to take (``{(stage, index): {"fail_attempts": n}}``) folds
into its ``tasks`` field via ``FaultPlan.coerce``. ``FaultInjector`` turns
a plan into reproducible decisions that the simulated services consult at
every data-plane call: the SQS sim on send/receive, the object store on
PUT/GET/LIST, and ``LambdaSim`` at invocation admission.

Schema (all probabilities per call, in [0, 1]):

  seed                  base for every pseudo-random decision
  tasks                 {(stage, index): {task fault}} — targeted task
                        faults, unchanged from the legacy format:
                          fail_attempts: n          fail the first n attempts
                          straggle_s: s             sleep s on attempt 0
                          fail_after_records: n     die mid-task (attempt 0)
                          fail_on_link: k           die on chained link k
                          timeout_after_records: n  invocation lease expires
                                                    mid-task (attempt 0) —
                                                    partial flushes LAND
  s3_error_prob         transient 503/SlowDown on S3 PUT/GET/LIST
  sqs_error_prob        transient error on SQS send/receive
  sqs_delay_prob        a sent batch is delivered late ...
  sqs_delay_s           ... by this many seconds
  invoke_throttle_prob  Lambda 429 at invocation admission
  invoke_timeout_prob   probabilistic invocation timeout (attempt 0)
  account_concurrency   429 every invocation above this in-flight cap
                        (0 = uncapped)
  lose_object_prob      an ACKNOWLEDGED durable write silently vanishes
  lose_object_prefixes  ... restricted to these key prefixes (default:
                        exchange batches, cache materializations and
                        broadcast objects — the lost-durable-object
                        faults lineage recovery heals)
  lose_keys             targeted loss: first write whose key contains each
                        fragment vanishes (fires once per fragment)
  lose_keys_every       like lose_keys but EVERY matching write vanishes —
                        a permanent black hole, for exhaustion tests

Decisions are pure functions of (seed, call signature, per-signature call
count), not of global call order — so a fixed seed yields the same
schedule for the same call sequence even across thread interleavings.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading

from repro.core.retry import TransientServiceError

#: recognized targeted task-fault keys (the legacy scheduler format)
TASK_FAULT_KEYS = frozenset({
    "fail_attempts", "straggle_s", "fail_after_records", "fail_on_link",
    "timeout_after_records",
})

_PROB_FIELDS = ("s3_error_prob", "sqs_error_prob", "sqs_delay_prob",
                "invoke_throttle_prob", "invoke_timeout_prob",
                "lose_object_prob")


@dataclasses.dataclass
class FaultPlan:
    seed: int = 0
    tasks: dict = dataclasses.field(default_factory=dict)
    s3_error_prob: float = 0.0
    sqs_error_prob: float = 0.0
    sqs_delay_prob: float = 0.0
    sqs_delay_s: float = 0.02
    invoke_throttle_prob: float = 0.0
    invoke_timeout_prob: float = 0.0
    account_concurrency: int = 0
    lose_object_prob: float = 0.0
    lose_object_prefixes: tuple = ("_exchange/", "_cache/",
                                   "_broadcast/")
    lose_keys: tuple = ()
    lose_keys_every: tuple = ()

    def __post_init__(self):
        for f in _PROB_FIELDS:
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultPlan.{f} must be in [0, 1], got {v}")
        if self.account_concurrency < 0:
            raise ValueError("FaultPlan.account_concurrency must be >= 0")
        if self.sqs_delay_s < 0:
            raise ValueError("FaultPlan.sqs_delay_s must be >= 0")
        for key, fault in self.tasks.items():
            if (not isinstance(key, tuple) or len(key) != 2
                    or not all(isinstance(k, int) for k in key)):
                raise ValueError(
                    f"FaultPlan.tasks keys are (stage, index) int pairs, "
                    f"got {key!r}")
            unknown = set(fault) - TASK_FAULT_KEYS
            if unknown:
                raise ValueError(
                    f"unknown task fault keys {sorted(unknown)} for task "
                    f"{key} (known: {sorted(TASK_FAULT_KEYS)})")

    @classmethod
    def coerce(cls, plan) -> "FaultPlan":
        """Accept a FaultPlan, the legacy ``{(stage, index): {...}}`` dict
        (compatibility shim), or None (no faults)."""
        if plan is None:
            return cls()
        if isinstance(plan, FaultPlan):
            return plan
        if isinstance(plan, dict):
            return cls(tasks=dict(plan))
        raise TypeError(
            f"fault_plan must be a FaultPlan or a legacy task-fault dict, "
            f"got {type(plan).__name__}")

    @property
    def has_service_faults(self) -> bool:
        """True when the SERVICE sims need an injector installed (targeted
        task faults alone ride the task payload, as they always did)."""
        return bool(any(getattr(self, f) for f in _PROB_FIELDS)
                    or self.account_concurrency
                    or self.lose_keys or self.lose_keys_every)

    @property
    def empty(self) -> bool:
        return not (self.tasks or self.has_service_faults)


class ConcurrencyGauge:
    """Account-level in-flight invocation counter. Each ``LambdaSim``
    owns one by default; the multi-tenant service (repro.svc) shares a
    single gauge across every session's LambdaSim so that
    ``FaultPlan.account_concurrency`` caps the ACCOUNT — the paper's
    per-account Lambda limit — rather than each job independently
    (docs/multi_tenant.md). ``peak`` is observability for tests and
    benchmarks asserting the shared cap was actually exercised."""

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0
        self.peak = 0

    def enter(self) -> int:
        """Count an invocation in; returns the in-flight total including
        it (the admission check compares this against the cap)."""
        with self._lock:
            self.value += 1
            if self.value > self.peak:
                self.peak = self.value
            return self.value

    def exit(self):
        with self._lock:
            self.value -= 1


class FaultInjector:
    """Seeded, reproducible fault decisions over one FaultPlan. Installed
    on the sims as a ``.faults`` attribute for the duration of one
    scheduler run; the sims consult it at every data-plane call."""

    def __init__(self, plan: FaultPlan, ledger=None):
        self.plan = plan
        self.ledger = ledger
        self._lock = threading.Lock()
        self._counts: dict = {}     # call signature -> times seen
        self._fired: set = set()    # one-shot faults already delivered
        self.stats = {"s3_errors": 0, "sqs_errors": 0, "sqs_delays": 0,
                      "lost_objects": 0, "throttles": 0, "timeouts": 0}

    def _bump(self, key: str):
        with self._lock:
            self.stats[key] += 1
        if self.ledger is not None and key.endswith("_errors"):
            self.ledger.add_service_fault()

    def _decide(self, prob: float, *sig) -> bool:
        """One seeded coin flip for this (signature, occurrence) pair."""
        if prob <= 0.0:
            return False
        with self._lock:
            n = self._counts.get(sig, 0)
            self._counts[sig] = n + 1
        h = hashlib.sha1(
            repr((self.plan.seed,) + sig + (n,)).encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64 < prob

    # ------------------------------------------------------ service hooks
    def s3_call(self, op: str, key: str):
        """Raises a transient 5xx BEFORE the operation takes effect (AWS
        does not bill server errors; the ledger counts them separately)."""
        if self._decide(self.plan.s3_error_prob, "s3", op, key):
            self._bump("s3_errors")
            raise TransientServiceError(
                f"S3 {op} {key}: 503 SlowDown (injected)",
                service="s3", op=op)

    def sqs_call(self, op: str, queue: str):
        if self._decide(self.plan.sqs_error_prob, "sqs", op, queue):
            self._bump("sqs_errors")
            raise TransientServiceError(
                f"SQS {op} {queue}: internal error (injected)",
                service="sqs", op=op)

    def delivery_delay(self, queue: str) -> float:
        """Seconds a successfully-sent batch sits invisible before
        delivery (SQS makes no latency promise)."""
        if self._decide(self.plan.sqs_delay_prob, "sqsdelay", queue):
            self._bump("sqs_delays")
            return self.plan.sqs_delay_s
        return 0.0

    def object_written(self, key: str) -> bool:
        """Consulted AFTER a durable write is acknowledged; True means the
        object silently vanishes — the writer saw success. Tombstones are
        exempt (they are release markers, not data)."""
        if ".released" in key:
            return False
        for frag in self.plan.lose_keys_every:
            if frag in key:
                self._bump("lost_objects")
                return True
        for frag in self.plan.lose_keys:
            if frag in key:
                with self._lock:
                    if ("lose_keys", frag) in self._fired:
                        continue
                    self._fired.add(("lose_keys", frag))
                self._bump("lost_objects")
                return True
        if (self.plan.lose_object_prob
                and any(key.startswith(p)
                        for p in self.plan.lose_object_prefixes)
                and self._decide(self.plan.lose_object_prob, "lost", key)):
            self._bump("lost_objects")
            return True
        return False

    # --------------------------------------------------- invocation hooks
    def invoke_fault(self, stage: int, index: int, attempt: int,
                     inflight: int) -> str | None:
        """Admission decision for one invocation: "throttle" (429) or
        None. The concurrency cap throttles deterministically; the
        probabilistic throttle is a fresh coin per (task, occurrence)."""
        cap = self.plan.account_concurrency
        if cap and inflight > cap:
            self._bump("throttles")
            return "throttle"
        if self._decide(self.plan.invoke_throttle_prob,
                        "throttle", stage, index):
            self._bump("throttles")
            return "throttle"
        return None

    def timeout_after(self, stage: int, index: int, attempt: int
                      ) -> int | None:
        """Record count after which this invocation's lease expires
        mid-task (killed WITHOUT a final flush — whatever full batches
        already flushed stay durable, exercising re-emission dedup).
        Attempt 0 only: the retry must be able to finish."""
        if attempt != 0:
            return None
        t = self.plan.tasks.get((stage, index), {}).get(
            "timeout_after_records")
        if t:
            self._bump("timeouts")
            return t
        if self.plan.invoke_timeout_prob and self._decide(
                self.plan.invoke_timeout_prob, "timeout", stage, index):
            self._bump("timeouts")
            h = hashlib.sha1(
                repr((self.plan.seed, "tcount", stage, index)).encode()
            ).digest()
            return 20 + int.from_bytes(h[:4], "big") % 180
        return None

    def task_fault(self, stage: int, index: int) -> dict:
        """Targeted task faults for the scheduler's payload builder."""
        return self.plan.tasks.get((stage, index), {})
