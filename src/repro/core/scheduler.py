"""FlintScheduler — the serverless SchedulerBackend (paper §III).

Lives on the client and drives the physical plan in one of two modes:

PIPELINED (default, ``cfg.pipeline_stages``): every stage's tasks enter a
single launch frontier ordered by stage id and bounded by the concurrency
cap. Consumer tasks are invoked WHILE their producers are still running;
they drain their queues as messages arrive and terminate on per-producer
EOS control messages (the producer quorum is known at plan time), so queue
transport and consumer-side folding overlap producer compute — no stage
barrier. Producer-stage work (retries, chained continuations) always
outranks consumer launches in the frontier, which keeps the window
deadlock-free: a slot freed by a producer completion is re-offered to
producer work before any consumer takes it.

BARRIER (``pipeline_stages=False``, the paper's original design kept for
A/B measurement): one stage at a time. Termination is the SAME EOS
protocol as pipelined mode — producers close their streams with
per-partition sequence totals and consumers count down the plan-time
producer quorum. (The original post-hoc expectation-table handover died
with the pluggable-transport refactor; both modes now share one
termination path, barrier mode simply delays consumer launch.)

Intermediate data moves over a pluggable ShuffleTransport
(core.shuffle): per-partition SQS queues or a Lambada-style S3 object
exchange, chosen per shuffle via the DAG-level ``transport`` hint with
``cfg.shuffle_backend`` as the default. A CSE-shared shuffle (one
producer stage, N consumer groups — docs/dag_fanout.md) is released per
(shuffle, consumer-stage): each completed consumer frees only its own
group's channels, and the shuffle is destroyed once EVERY consuming
stage has drained. Queue/prefix lifecycle (open/release/destroy) and the
job-end garbage collection of transient object-store keys (``_spill/``,
``_payload/``, ``_result/``, ``_exchange/``, stale ``_cache/``) are
driven from here.

Both modes share task semantics: CONTINUATIONS re-invoked on warm
containers (executor chaining — a chained producer only emits EOS from its
final link), failures retried with the same task identity (idempotent via
stable partitioning + seq-id dedup), stragglers get a speculative
duplicate (first completion wins; duplicate messages AND duplicate EOS are
dropped by the same dedup). Consumer (shuffle-reading) tasks are as
retryable and speculatable as producers: SQS receives are visibility-
timeout claims, acked only at task completion, so a dead consumer's
messages redeliver to its retry and two competing drains merely race on
acks. In pipelined mode a consumer is only speculated once its producers
are all done (a blocked consumer is waiting, not straggling). When a
consumer completes, its queues are deleted immediately so a losing
duplicate aborts on QueueGone instead of waiting out the drain timeout.
Straggler thresholds compare scheduler-observed latency and allow for one
cold start.
"""

from __future__ import annotations

import concurrent.futures as cf
import heapq
import itertools
import pickle
import threading
import time
from typing import Any

from repro.core.costs import CostLedger
from repro.core.dag import ShuffleRead, StagePlan, TaskDef
from repro.core.executors import FlintConfig, LambdaSim, serialize_task
from repro.core.queues import ObjectStoreSim, SQSSim
from repro.core.shuffle import TransportSet

#: transient object-store prefixes swept by the job-end GC (the S3
#: exchange's _exchange/ prefix is swept by its transport's gc())
GC_PREFIXES = ("_spill/", "_payload/", "_result/")


class StageFailure(RuntimeError):
    def __init__(self, msg, error_type=""):
        super().__init__(msg)
        self.error_type = error_type


def _consumed_shuffles(stage: StagePlan) -> set[int]:
    sids: set[int] = set()
    for task in stage.tasks:
        if isinstance(task.input, ShuffleRead):
            sids.update(sid for sid, _ in task.input.parts)
    return sids


class FlintScheduler:
    def __init__(self, cfg: FlintConfig, ledger: CostLedger | None = None,
                 store: ObjectStoreSim | None = None, *,
                 fault_plan: dict | None = None, verbose: bool = False,
                 cache_index: dict | None = None):
        if (cfg.shuffle_backend in ("sqs", "auto")
                and cfg.visibility_timeout_s >= cfg.drain_timeout_s):
            # otherwise a retried consumer times out waiting for its dead
            # predecessor's claims to expire — and fails with a confusing
            # "queue incomplete" instead of this
            raise ValueError(
                f"visibility_timeout_s ({cfg.visibility_timeout_s}) must be "
                f"< drain_timeout_s ({cfg.drain_timeout_s}) or consumer "
                f"retries cannot outwait redelivery")
        self.cfg = cfg
        self.ledger = ledger or CostLedger()
        self.store = store or ObjectStoreSim(self.ledger)
        self.sqs = SQSSim(self.ledger, duplicate_prob=cfg.duplicate_prob,
                          visibility_timeout=cfg.visibility_timeout_s)
        self.transports = TransportSet(cfg, self.ledger, self.store,
                                       self.sqs)
        self.lam = LambdaSim(cfg, self.ledger, self.store, self.sqs,
                             self.transports)
        self.pool = cf.ThreadPoolExecutor(max_workers=cfg.concurrency)
        # fault_plan: {(stage, index): {"fail_attempts": n} | {"straggle_s": s}
        #             | {"fail_after_records": n} | {"fail_on_link": k}}
        self.fault_plan = fault_plan or {}
        self.verbose = verbose
        self.stage_stats: list[dict] = []
        self._lock = threading.Lock()
        # shuffle_id -> (producer nparts, transport name); set per run()
        self._sid_meta: dict[int, tuple[int, str]] = {}
        # shuffle_id -> {consuming stage indices} / {finished consumers}:
        # a CSE-shared shuffle is only destroyed once EVERY consuming
        # stage has drained its group (per-(shuffle, consumer-stage) GC)
        self._sid_consumers: dict[int, set] = {}
        self._sid_drained: dict[int, set] = {}
        # context-owned RDD.cache() registry: tokens listed here survive
        # the job-scoped GC (they feed later actions); anything else
        # under _cache/ is stale and swept
        self._cache_index = cache_index
        self.gc_report: dict[str, int] = {}
        self._gc_done = False

    # ------------------------------------------------------------------
    def run(self, stages: list[StagePlan]):
        self._sid_meta = {
            s.write.shuffle_id:
                (s.write.nparts,
                 s.write.transport or self.cfg.fallback_backend)
            for s in stages if s.write is not None}
        self._sid_consumers = {}
        for si, stage in enumerate(stages):
            for sid in _consumed_shuffles(stage):
                self._sid_consumers.setdefault(sid, set()).add(si)
        self._sid_drained = {sid: set() for sid in self._sid_consumers}
        if (self.cfg.visibility_timeout_s >= self.cfg.drain_timeout_s
                and any(t == "sqs" for _, t in self._sid_meta.values())):
            # the constructor guard only sees the engine default; a
            # per-shuffle transport="sqs" hint must not sneak past it into
            # the same unrecoverable-retry failure
            raise ValueError(
                f"visibility_timeout_s ({self.cfg.visibility_timeout_s}) "
                f"must be < drain_timeout_s ({self.cfg.drain_timeout_s}) "
                f"for shuffles routed over sqs, or consumer retries cannot "
                f"outwait redelivery")
        if self.cfg.pipeline_stages:
            return self._run_pipelined(stages)
        return self._run_barrier(stages)

    def _transport_of(self, sid: int):
        return self.transports.get(self._sid_meta[sid][1])

    def _open_shuffle(self, write):
        """Create the shuffle's channels before any producer launches."""
        name = write.transport or self.cfg.fallback_backend
        self.transports.get(name).open(write.shuffle_id, write.nparts,
                                       groups=write.consumer_groups)

    def _destroy_shuffles(self, sids):
        """All-consumers-done sweep — the transport skips partitions
        already released per-task (each release is billed; re-issuing
        deletes for channels the scheduler knows are gone would skew the
        benchmarks' request counts)."""
        for sid in sids:
            nparts, _ = self._sid_meta[sid]
            self._transport_of(sid).destroy(sid, nparts)

    def _consumer_stage_done(self, si: int, stage: StagePlan):
        """Per-(shuffle, consumer-stage) GC: record that stage ``si``
        drained its groups; destroy only the shuffles whose EVERY
        consuming stage has now finished — a CSE-shared shuffle must stay
        alive for its remaining consumer groups."""
        dead = []
        for sid in _consumed_shuffles(stage):
            drained = self._sid_drained[sid]
            drained.add(si)
            if drained >= self._sid_consumers[sid]:
                dead.append(sid)
        self._destroy_shuffles(dead)

    def _release_task_partitions(self, task: TaskDef):
        """A completed consumer's shuffle partitions are dead FOR ITS
        GROUP: release them now so a losing speculative duplicate (or a
        late retry of a task that already won) aborts immediately
        (QueueGone / exchange tombstone) instead of blocking a pool thread
        until the drain timeout. Sibling consumer groups keep draining."""
        if isinstance(task.input, ShuffleRead):
            groups = task.input.groups or [0] * len(task.input.parts)
            for (sid, _), g in zip(task.input.parts, groups):
                self._transport_of(sid).release_partition(
                    sid, task.input.partition, consumer_group=g)

    # ----------------------------------------------------- barrier mode
    def _run_barrier(self, stages: list[StagePlan]):
        result = None
        try:
            for si, stage in enumerate(stages):
                if stage.write is not None:
                    self._open_shuffle(stage.write)
                result = self._run_stage(stage)
                # channels whose last consumer just finished are dead
                self._consumer_stage_done(si, stage)
        except BaseException:
            # same teardown as the pipelined path: a consumer blocked on a
            # queue that will never fill must not linger in the thread
            # pool until drain_timeout_s
            self.sqs.close()
            raise
        return result

    # ------------------------------------------------------------------
    def _payload_for(self, task: TaskDef, stage: StagePlan, attempt: int,
                     extra: dict | None = None) -> dict:
        extra = dict(extra or {})
        fault = self.fault_plan.get((task.stage_id, task.index), {})
        if fault.get("fail_attempts", 0) > attempt:
            extra["inject_failure"] = True
        if fault.get("straggle_s") and attempt == 0 \
                and not extra.get("_speculative"):
            extra["straggle_s"] = fault["straggle_s"]
        if fault.get("fail_after_records") and attempt == 0:
            extra["fail_after_records"] = fault["fail_after_records"]
        if fault.get("fail_on_link") and attempt == 0 \
                and extra.get("_link") == fault["fail_on_link"]:
            # kill a specific link of a CHAINED task — exercises the
            # resume-from-cursor retry path deterministically
            extra["inject_failure"] = True
        extra.pop("_link", None)
        extra.pop("_speculative", None)
        if isinstance(task.input, ShuffleRead):
            # EOS termination quorum, known at plan time — both modes
            extra["n_producers"] = {
                str(sid): stage.producer_counts[sid]
                for sid, _ in task.input.parts}
        if stage.action == "save" or stage.save_prefix:
            extra["save_prefix"] = stage.save_prefix
        return serialize_task(task, attempt, extra)

    def _run_stage(self, stage: StagePlan) -> Any:
        t0 = time.monotonic()
        n = len(stage.tasks)
        results: dict[int, Any] = {}
        partials: dict[int, list] = {}
        attempts: dict[int, int] = {i: 0 for i in range(n)}
        durations: list[float] = []
        speculated: set[int] = set()
        inflight: dict[cf.Future, tuple[int, bool, float]] = {}
        dup_dropped = 0
        chained = 0
        # last continuation cursor per chained task: a retry resumes from
        # here instead of replaying from scratch — the already-emitted
        # links' (src, seq) messages stay untouched and only the failed
        # link replays (its flush boundaries are count-based, so the
        # replay is byte-identical)
        cursors: dict[int, dict] = {}
        links: dict[int, int] = {}

        def launch(task: TaskDef, extra=None, speculative=False):
            payload = self._payload_for(
                task, stage, attempts[task.index],
                dict(extra or {}, _speculative=speculative))
            fut = self.pool.submit(self.lam.invoke, payload)
            inflight[fut] = (task.index, speculative, time.monotonic())

        for task in stage.tasks:
            launch(task)

        def spec_armed() -> bool:
            # consumers included: visibility-timeout receives make two
            # drains of one queue race on acks, not split messages. Only
            # FIRST attempts are speculated — a retry's latency baseline
            # is meaningless (a consumer retry is waiting out its dead
            # predecessor's visibility deadline), and a twin racing it
            # would hold claims the retry needs. Tasks that already
            # CHAINED are excluded too: a twin restarting from scratch
            # could cut its links at different wall-clock positions and
            # emit conflicting framings under the same sequence ids
            return (len(durations) >= self.cfg.speculation_min_done
                    and len(inflight) < self.cfg.concurrency
                    and any(not spec and idx not in speculated
                            and idx not in results and attempts[idx] == 0
                            and idx not in cursors
                            for idx, spec, _ in inflight.values()))

        # straggler thresholds compare scheduler-observed latency, so allow
        # for a cold start before calling anything a straggler
        start_allowance = self.cfg.cold_start_s * self.cfg.start_latency_scale

        while inflight:
            # event-driven: block on completions; wake periodically only
            # while a straggler check could actually fire
            done, _ = cf.wait(list(inflight),
                              timeout=0.05 if spec_armed() else 5.0,
                              return_when=cf.FIRST_COMPLETED)
            now = time.monotonic()
            # straggler speculation
            if (len(durations) >= self.cfg.speculation_min_done
                    and len(inflight) < self.cfg.concurrency):
                med = sorted(durations)[len(durations) // 2]
                for fut, (idx, spec, started) in list(inflight.items()):
                    if (not spec and idx not in speculated
                            and idx not in results and attempts[idx] == 0
                            and idx not in cursors
                            and now - started > self.cfg.speculation_factor
                            * max(med, 0.05) + start_allowance):
                        speculated.add(idx)
                        launch(stage.tasks[idx], speculative=True)
            for fut in done:
                idx, speculative, started = inflight.pop(fut)
                resp = fut.result()
                if "spilled" in resp:
                    resp = pickle.loads(self.store.get(resp["spilled"]))
                if idx in results:
                    dup_dropped += 1  # speculative duplicate lost the race
                    continue
                if resp.get("status") != "ok":
                    if resp.get("error_type") == "MemoryCapExceeded":
                        raise StageFailure(resp.get("error", ""),
                                           error_type="MemoryCapExceeded")
                    # a dead consumer's unacked messages redeliver after
                    # the visibility timeout, so its retry sees them all
                    attempts[idx] += 1
                    if attempts[idx] > self.cfg.max_task_retries:
                        raise StageFailure(
                            f"task {stage.id}/{idx} failed after "
                            f"{attempts[idx]} attempts: {resp.get('error')}",
                            error_type=resp.get("error_type", ""))
                    launch(stage.tasks[idx], extra=cursors.get(idx))
                    continue
                if "continuation" in resp:
                    # executor chaining: merge partial output, re-invoke warm
                    chained += 1
                    self._merge_partial(resp, idx, partials)
                    cursors[idx] = resp["continuation"]
                    links[idx] = links.get(idx, 1) + 1
                    launch(stage.tasks[idx],
                           extra=dict(resp["continuation"],
                                      _link=links[idx]))
                    continue
                durations.append(now - started)
                self._merge_partial(resp, idx, partials)
                results[idx] = True
                self._release_task_partitions(stage.tasks[idx])

        self.stage_stats.append({
            "stage": stage.id, "tasks": n,
            "wall_s": round(time.monotonic() - t0, 4),
            "attempts": sum(attempts.values()) + n,
            "chained": chained,
            "speculated": len(speculated),
            "spec_dropped": dup_dropped,
        })
        if self.verbose:
            print(f"[flint] stage {stage.id}: {self.stage_stats[-1]}")

        return self._stage_result(stage, partials)

    # --------------------------------------------------- pipelined mode
    def _run_pipelined(self, stages: list[StagePlan]):
        cfg = self.cfg
        for stage in stages:
            if stage.write is not None:
                self._open_shuffle(stage.write)

        producer_stage_of = {s.write.shuffle_id: si
                             for si, s in enumerate(stages)
                             if s.write is not None}
        deps = [sorted(producer_stage_of[sid]
                       for sid in _consumed_shuffles(stage))
                for stage in stages]

        n_stages = len(stages)
        results: list[dict] = [{} for _ in stages]
        partials: list[dict] = [{} for _ in stages]
        attempts = [{i: 0 for i in range(len(s.tasks))} for s in stages]
        durations: list[list[float]] = [[] for _ in stages]
        speculated: list[set] = [set() for _ in stages]
        chained = [0] * n_stages
        dup_dropped = [0] * n_stages
        # last continuation cursor per chained task (see _run_stage)
        cursors: list[dict] = [{} for _ in stages]
        links: list[dict] = [{} for _ in stages]
        stage_done = [False] * n_stages
        stage_t0: list[float | None] = [None] * n_stages
        stats_rows: list[dict | None] = [None] * n_stages
        final_result: list[Any] = [None]

        # launch frontier: a min-heap keyed (stage, arrival) so producer
        # work — including late retries and chained continuations — always
        # outranks consumer launches for a freed window slot
        ticket = itertools.count()
        pending: list = []
        inflight: dict[cf.Future, tuple[int, int, bool, float]] = {}

        def push(si, task, extra=None, speculative=False):
            heapq.heappush(pending,
                           (si, next(ticket), task, extra, speculative))

        for si, stage in enumerate(stages):
            for task in stage.tasks:
                push(si, task)

        def launch_ready():
            while pending and len(inflight) < cfg.concurrency:
                si, _, task, extra, speculative = heapq.heappop(pending)
                if task.index in results[si]:
                    continue  # stale: original already won
                if stage_t0[si] is None:
                    stage_t0[si] = time.monotonic()
                payload = self._payload_for(
                    task, stages[si], attempts[si][task.index],
                    dict(extra or {}, _speculative=speculative))
                fut = self.pool.submit(self.lam.invoke, payload)
                inflight[fut] = (si, task.index, speculative,
                                 time.monotonic())

        def deps_done(si) -> bool:
            return all(stage_done[d] for d in deps[si])

        start_allowance = cfg.cold_start_s * cfg.start_latency_scale

        def spec_armed() -> bool:
            # consumers included (once their producers are done):
            # visibility-timeout receives make two drains of one queue
            # race on acks, not split messages. Only FIRST attempts are
            # speculated — a retry's latency baseline is meaningless (a
            # consumer retry is waiting out its dead predecessor's
            # visibility deadline), and a twin racing it would hold
            # claims the retry needs
            if len(inflight) >= cfg.concurrency:
                return False
            for fsi, idx, spec, _ in inflight.values():
                if (not spec and deps_done(fsi)
                        and len(durations[fsi]) >= cfg.speculation_min_done
                        and idx not in speculated[fsi]
                        and idx not in results[fsi]
                        and attempts[fsi][idx] == 0
                        and idx not in cursors[fsi]):
                    return True
            return False

        def finish_stage(si, stage):
            stage_done[si] = True
            stats_rows[si] = {
                "stage": stage.id, "tasks": len(stage.tasks),
                "wall_s": round(time.monotonic()
                                - (stage_t0[si] or time.monotonic()), 4),
                "attempts": sum(attempts[si].values()) + len(stage.tasks),
                "chained": chained[si],
                "speculated": len(speculated[si]),
                "spec_dropped": dup_dropped[si],
            }
            if self.verbose:
                print(f"[flint] stage {stage.id}: {stats_rows[si]}")
            self._consumer_stage_done(si, stage)
            if stage.action is not None or stage.write is None:
                final_result[0] = self._stage_result(stage, partials[si])

        launch_ready()
        try:
            while inflight:
                done, _ = cf.wait(list(inflight),
                                  timeout=0.05 if spec_armed() else 5.0,
                                  return_when=cf.FIRST_COMPLETED)
                now = time.monotonic()
                # straggler speculation — only for stages whose producers
                # are all done (a blocked consumer is not a straggler)
                if len(inflight) < cfg.concurrency or pending:
                    for fut, (fsi, idx, spec, started) in list(
                            inflight.items()):
                        if (spec or not deps_done(fsi)
                                or idx in speculated[fsi]
                                or idx in results[fsi]
                                or attempts[fsi][idx] > 0
                                or idx in cursors[fsi]):
                            continue
                        durs = durations[fsi]
                        if len(durs) < cfg.speculation_min_done:
                            continue
                        med = sorted(durs)[len(durs) // 2]
                        if now - started > (cfg.speculation_factor
                                            * max(med, 0.05)
                                            + start_allowance):
                            speculated[fsi].add(idx)
                            push(fsi, stages[fsi].tasks[idx],
                                 speculative=True)
                for fut in done:
                    si, idx, speculative, started = inflight.pop(fut)
                    resp = fut.result()
                    if "spilled" in resp:
                        resp = pickle.loads(self.store.get(resp["spilled"]))
                    if idx in results[si]:
                        dup_dropped[si] += 1  # speculative dup lost the race
                        continue
                    if resp.get("status") != "ok":
                        if resp.get("error_type") == "MemoryCapExceeded":
                            raise StageFailure(
                                resp.get("error", ""),
                                error_type="MemoryCapExceeded")
                        # a dead consumer's unacked messages redeliver
                        # after the visibility timeout — retry like any task
                        attempts[si][idx] += 1
                        if attempts[si][idx] > cfg.max_task_retries:
                            raise StageFailure(
                                f"task {stages[si].id}/{idx} failed after "
                                f"{attempts[si][idx]} attempts: "
                                f"{resp.get('error')}",
                                error_type=resp.get("error_type", ""))
                        push(si, stages[si].tasks[idx],
                             extra=cursors[si].get(idx))
                        continue
                    if "continuation" in resp:
                        # chaining: the producer has NOT emitted EOS yet —
                        # the re-invoked link (or its last successor) will
                        chained[si] += 1
                        self._merge_partial(resp, idx, partials[si])
                        cursors[si][idx] = resp["continuation"]
                        links[si][idx] = links[si].get(idx, 1) + 1
                        push(si, stages[si].tasks[idx],
                             extra=dict(resp["continuation"],
                                        _link=links[si][idx]))
                        continue
                    durations[si].append(now - started)
                    self._merge_partial(resp, idx, partials[si])
                    results[si][idx] = True
                    self._release_task_partitions(stages[si].tasks[idx])
                    if len(results[si]) == len(stages[si].tasks):
                        finish_stage(si, stages[si])
                launch_ready()
        except BaseException:
            # unblock any consumer still waiting on queues we now know
            # will never complete (fatal failure / elastic re-plan)
            self.sqs.close()
            raise

        # completion order is event order; report in plan order
        self.stage_stats.extend(r for r in stats_rows if r is not None)
        return final_result[0]

    # ------------------------------------------------------------------
    @staticmethod
    def _stage_result(stage: StagePlan, partials: dict) -> Any:
        n = len(stage.tasks)
        if stage.action in ("collect", "sum"):
            out = []
            for i in range(n):
                out.extend(partials.get(i, []))
                if stage.limit is not None and len(out) >= stage.limit:
                    # take(n): the merge short-circuits — later
                    # partitions' results are never consumed
                    return out[:stage.limit]
            return sum(out) if stage.action == "sum" else out
        if stage.action == "save":
            return [f"{stage.save_prefix}/part-{i:05d}" for i in range(n)]
        return None

    @staticmethod
    def _merge_partial(resp, idx, partials):
        if "result" in resp:
            partials.setdefault(idx, []).extend(resp["result"])

    def gc_job(self) -> dict[str, int]:
        """Job-scoped garbage collection (idempotent): every transport
        sweeps its channels (stray queues, the whole ``_exchange/`` tree)
        and the transient object-store prefixes are deleted — content-
        addressed spill keys were never reclaimed before this. Runs inside
        ``shutdown``, i.e. on every query completion or failure; the
        removal counts land in ``gc_report`` so benchmarks/tests can both
        assert zero leaks and see that the GC actually had work to do."""
        if self._gc_done:
            return self.gc_report
        self._gc_done = True
        report: dict[str, int] = {}
        for transport in self.transports.active():
            for resource, n in transport.gc().items():
                report[resource] = report.get(resource, 0) + n
        for prefix in GC_PREFIXES:
            n = self.store.delete_prefix(prefix)
            if n:
                report[prefix] = n
        # RDD.cache() materializations outlive the job on purpose (they
        # feed later actions) — but only while their token is registered;
        # stale content (cleared caches, elastic re-plans that changed the
        # partition count) is swept here like any other transient key
        live = {f"_cache/{t}/{e['nparts']}/"
                for t, e in (self._cache_index or {}).items()}
        stale = [k for k in self.store.list("_cache/")
                 if not any(k.startswith(p) for p in live)]
        for k in stale:
            self.store.delete(k)
        if stale:
            report["_cache/"] = len(stale)
        self.gc_report = report
        return report

    def shutdown(self):
        self.sqs.close()  # release any consumer blocked on arrival
        self.gc_job()
        self.pool.shutdown(wait=False)
