"""FlintScheduler — the serverless SchedulerBackend (paper §III).

Lives on the client and drives the physical plan in one of two modes:

PIPELINED (default, ``cfg.pipeline_stages``): every stage's tasks enter a
single launch frontier ordered by stage id and bounded by the concurrency
cap. Consumer tasks are invoked WHILE their producers are still running;
they drain their queues as messages arrive and terminate on per-producer
EOS control messages (the producer quorum is known at plan time), so queue
transport and consumer-side folding overlap producer compute — no stage
barrier. Producer-stage work (retries, chained continuations) always
outranks consumer launches in the frontier, which keeps the window
deadlock-free: a slot freed by a producer completion is re-offered to
producer work before any consumer takes it.

BARRIER (``pipeline_stages=False``, the paper's original design kept for
A/B measurement): one stage at a time. Termination is the SAME EOS
protocol as pipelined mode — producers close their streams with
per-partition sequence totals and consumers count down the plan-time
producer quorum. (The original post-hoc expectation-table handover died
with the pluggable-transport refactor; both modes now share one
termination path, barrier mode simply delays consumer launch.)

Intermediate data moves over a pluggable ShuffleTransport
(core.shuffle): per-partition SQS queues or a Lambada-style S3 object
exchange, chosen per shuffle via the DAG-level ``transport`` hint with
``cfg.shuffle_backend`` as the default. A CSE-shared shuffle (one
producer stage, N consumer groups — docs/dag_fanout.md) is released per
(shuffle, consumer-stage): each completed consumer frees only its own
group's channels, and the shuffle is destroyed once EVERY consuming
stage has drained. Queue/prefix lifecycle (open/release/destroy) and the
job-end garbage collection of transient object-store keys (``_spill/``,
``_payload/``, ``_result/``, ``_exchange/``, stale ``_cache/``) are
driven from here.

Both modes share task semantics: CONTINUATIONS re-invoked on warm
containers (executor chaining — a chained producer only emits EOS from its
final link), failures retried with the same task identity (idempotent via
stable partitioning + seq-id dedup), stragglers get a speculative
duplicate (first completion wins; duplicate messages AND duplicate EOS are
dropped by the same dedup). Consumer (shuffle-reading) tasks are as
retryable and speculatable as producers: SQS receives are visibility-
timeout claims, acked only at task completion, so a dead consumer's
messages redeliver to its retry and two competing drains merely race on
acks. In pipelined mode a consumer is only speculated once its producers
are all done (a blocked consumer is waiting, not straggling). When a
consumer completes, its queues are deleted immediately so a losing
duplicate aborts on QueueGone instead of waiting out the drain timeout.
Straggler thresholds compare scheduler-observed latency and allow for one
cold start.
"""

from __future__ import annotations

import concurrent.futures as cf
import heapq
import itertools
import pickle
import random
import re
import threading
import time
from typing import Any

from repro.core.costs import (S3_EXCHANGE_BATCH_LIMIT, CostLedger,
                              pick_join_strategy, pick_shuffle_transport)
from repro.core.dag import ShuffleRead, StagePlan, TaskDef
from repro.core.executors import (FlintConfig, LambdaSim, _stable_order,
                                  serialize_task)
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.queues import ObjectStoreSim, SQSSim
from repro.core.retry import RetryBudget, TransientServiceError
from repro.core.shuffle import TransportSet, pack_batch, unpack_batch

#: transient object-store prefixes swept by the job-end GC (the S3
#: exchange's _exchange/ prefix is swept by its transport's gc();
#: _broadcast/ holds adaptive broadcast-join build sides — job-scoped,
#: never outliving the query)
GC_PREFIXES = ("_spill/", "_payload/", "_result/", "_broadcast/")

#: streaming checkpoints (offsets + window state, repro.streaming) live
#: under this prefix. Deliberately NOT in GC_PREFIXES: a streaming query
#: runs MANY jobs (one per micro-batch) and its checkpoints must outlive
#: each of them — the query's own cleanup()/retention sweeps the prefix,
#: and the service close()/leak_report() treat anything left as a leak
STREAM_PREFIX = "_stream/"

#: attempt number used for lineage-recovery replays: far past any real
#: retry count, so targeted first-attempt faults (straggle_s,
#: fail_after_records, probabilistic invocation timeouts) don't re-fire —
#: while the task's shuffle identity (src = stage/index) stays unchanged,
#: keeping the replay's re-emission byte-identical for downstream dedup
_REPLAY_ATTEMPT = 1_000_000


class StageFailure(RuntimeError):
    """A stage cannot make progress. Structured so callers branch on the
    ROOT CAUSE instead of parsing message text: ``error_type`` carries the
    executor-side exception class name, ``retryable`` whether a coarser
    recovery above the scheduler (elastic re-plan, cache
    re-materialization) could still succeed."""

    def __init__(self, msg, error_type="", *, stage_id=None,
                 task_index=None, attempts=0, retryable=False, detail=None):
        super().__init__(msg)
        self.error_type = error_type
        self.stage_id = stage_id
        self.task_index = task_index
        self.attempts = attempts
        self.retryable = retryable
        self.detail = detail or {}


class _NullSlots:
    """Solo-mode slot source: the in-process pool (``cfg.concurrency``)
    is the only launch bound, so every slot request succeeds instantly.
    The multi-tenant service replaces this with a ``JobSlots`` lease on
    its weighted fair-share pool (repro.svc.fairshare) — same protocol,
    but ``try_acquire`` can say no and ``wait`` can block."""

    def try_acquire(self) -> bool:
        return True

    def acquire(self):
        pass

    def release(self):
        pass

    def set_demand(self, n: int):
        pass

    def contended(self) -> bool:
        return False

    def wait(self, timeout: float):
        pass

    def detach(self):
        pass


def _consumed_shuffles(stage: StagePlan) -> set[int]:
    sids: set[int] = set()
    for task in stage.tasks:
        if isinstance(task.input, ShuffleRead):
            sids.update(sid for sid, _ in task.input.parts)
    return sids


class FlintScheduler:
    def __init__(self, cfg: FlintConfig, ledger: CostLedger | None = None,
                 store: ObjectStoreSim | None = None, *,
                 fault_plan: dict | None = None, verbose: bool = False,
                 cache_index: dict | None = None, binding=None):
        cfg.validate()
        if (cfg.shuffle_backend in ("sqs", "auto")
                and cfg.visibility_timeout_s >= cfg.drain_timeout_s):
            # otherwise a retried consumer times out waiting for its dead
            # predecessor's claims to expire — and fails with a confusing
            # "queue incomplete" instead of this
            raise ValueError(
                f"visibility_timeout_s ({cfg.visibility_timeout_s}) must be "
                f"< drain_timeout_s ({cfg.drain_timeout_s}) or consumer "
                f"retries cannot outwait redelivery")
        self.cfg = cfg
        self.ledger = ledger or CostLedger()
        self.store = store or ObjectStoreSim(self.ledger)
        self.sqs = SQSSim(self.ledger, duplicate_prob=cfg.duplicate_prob,
                          visibility_timeout=cfg.visibility_timeout_s)
        # service-mode binding (repro.svc): per-job slice of the shared
        # pool — slot lease, shuffle-share registry, account concurrency
        # gauge, tenant quota guard, per-job key scope. Solo mode runs
        # with inert defaults and behaves exactly as before.
        self._binding = binding
        self._slots = binding.slots if binding is not None else _NullSlots()
        self._share = binding.share if binding is not None else None
        self._job_id = binding.job_id if binding is not None else 0
        self._scope = binding.scope if binding is not None else ""
        self._cost_guard = (binding.cost_guard
                            if binding is not None else None)
        # the chaos layer: one seeded injector consulted by every service
        # sim, one job-wide retry budget every retry layer draws from
        plan = FaultPlan.coerce(fault_plan)
        self.faults = FaultInjector(plan, self.ledger)
        if binding is not None and binding.retry_budget is not None:
            # per-tenant budget: every job the tenant runs draws from it
            self.retry_budget = binding.retry_budget
        else:
            self.retry_budget = RetryBudget(cfg.retry_budget)
        if plan.has_service_faults:
            # the per-scheduler SQS sim is always ours to chaos; the
            # object store is ours ONLY solo — in service mode it is
            # shared across live jobs and carries ONE service-wide
            # injector, installed (and detached) by the service itself
            self.sqs.faults = self.faults
            if binding is None:
                self.store.faults = self.faults
        self.transports = TransportSet(cfg, self.ledger, self.store,
                                       self.sqs, budget=self.retry_budget)
        self.lam = LambdaSim(cfg, self.ledger, self.store, self.sqs,
                             self.transports,
                             faults=None if plan.empty else self.faults,
                             budget=self.retry_budget,
                             gauge=(binding.gauge
                                    if binding is not None else None))
        self.lam.scope = self._scope
        self.pool = cf.ThreadPoolExecutor(max_workers=cfg.concurrency)
        self.verbose = verbose
        self.stage_stats: list[dict] = []
        # recovery bookkeeping: 429 re-dispatches, lost-input detections,
        # and lineage resubmissions (docs/fault_tolerance.md)
        self.recovery_stats = {"throttled": 0, "lost_inputs": 0,
                               "stage_resubmits": 0, "replayed_tasks": 0}
        self._dispatch_sleep = 0.0  # decorrelated-jitter state, 0 = idle
        self._backoff_rng = random.Random(plan.seed ^ 0x5DEECE66D)
        self._stage_retries: dict[int, int] = {}  # stage idx -> resubmits
        self._stages: list[StagePlan] = []
        self._producer_stage_of: dict[int, int] = {}
        self._stage_done: list[bool] = []
        self._lock = threading.Lock()
        # shuffle_id -> (producer nparts, transport name); set per run()
        self._sid_meta: dict[int, tuple[int, str]] = {}
        # shuffle_id -> {consuming stage indices} / {finished consumers}:
        # a CSE-shared shuffle is only destroyed once EVERY consuming
        # stage has drained its group (per-(shuffle, consumer-stage) GC)
        self._sid_consumers: dict[int, set] = {}
        self._sid_drained: dict[int, set] = {}
        # context-owned RDD.cache() registry: tokens listed here survive
        # the job-scoped GC (they feed later actions); anything else
        # under _cache/ is stale and swept
        self._cache_index = cache_index
        self.gc_report: dict[str, int] = {}
        self._gc_done = False
        # ---- adaptive execution state (docs/adaptive_execution.md) ----
        # measured shuffle output: shuffle_id -> {partition: [bytes,
        # records]}, folded from executor shuffle_out deltas on successful
        # responses. Advisory — a link that failed after a partial flush
        # counts its retry's re-emission too — so it only steers replan
        # CHOICES, never correctness-bearing quorums
        self.shuffle_stats: dict[int, dict[int, list]] = {}
        self.adaptive_stats = {"broadcast_joins": 0, "coalesced_stages": 0,
                               "transport_rechoices": 0,
                               "broadcast_rebuilds": 0}
        # broadcast prefix -> rebuild recipe (small-side stage index +
        # consumer group), for lineage recovery of a lost _broadcast/ key
        self._broadcasts: dict[str, dict] = {}
        self._absorbed: dict[int, int] = {}  # large-producer si -> join si

    # ------------------------------------------------------------------
    def run(self, stages: list[StagePlan]):
        self._stages = stages
        self._stage_done = [False] * len(stages)
        self._stage_retries = {}
        self.shuffle_stats = {}
        self._broadcasts = {}
        self._absorbed = {}
        self._producer_stage_of = {
            s.write.shuffle_id: si for si, s in enumerate(stages)
            if s.write is not None}
        self._sid_meta = {
            s.write.shuffle_id:
                (s.write.nparts,
                 s.write.transport or self.cfg.fallback_backend)
            for s in stages if s.write is not None}
        for stage in stages:
            for sid_tr in (t.input.transports or {} for t in stage.tasks
                           if isinstance(t.input, ShuffleRead)):
                for sid, tname in sid_tr.items():
                    if sid not in self._sid_meta:
                        # FOREIGN shuffle: produced by another job's
                        # scheduler, joined through the service share
                        # registry (docs/multi_tenant.md) — drainable
                        # here, never produced, released, or destroyed
                        # here (nparts 0 keeps destroy a no-op)
                        self._sid_meta[sid] = (
                            0, tname or self.cfg.fallback_backend)
        self._sid_consumers = {}
        for si, stage in enumerate(stages):
            for sid in _consumed_shuffles(stage):
                self._sid_consumers.setdefault(sid, set()).add(si)
        self._sid_drained = {sid: set() for sid in self._sid_consumers}
        if (self.cfg.visibility_timeout_s >= self.cfg.drain_timeout_s
                and any(t == "sqs" for _, t in self._sid_meta.values())):
            # the constructor guard only sees the engine default; a
            # per-shuffle transport="sqs" hint must not sneak past it into
            # the same unrecoverable-retry failure
            raise ValueError(
                f"visibility_timeout_s ({self.cfg.visibility_timeout_s}) "
                f"must be < drain_timeout_s ({self.cfg.drain_timeout_s}) "
                f"for shuffles routed over sqs, or consumer retries cannot "
                f"outwait redelivery")
        if self.cfg.pipeline_stages:
            return self._run_pipelined(stages)
        return self._run_barrier(stages)

    def _transport_of(self, sid: int):
        return self.transports.get(self._sid_meta[sid][1])

    def _open_shuffle(self, write):
        """Create the shuffle's channels before any producer launches."""
        name = write.transport or self.cfg.fallback_backend
        tr = self.transports.get(name)
        tr.open(write.shuffle_id, write.nparts,
                groups=write.consumer_groups)
        if self._share is not None:
            # a service-shared shuffle: record the owning transport so a
            # consumer group joining from ANOTHER job's plan after this
            # point can raise the all-groups-released reclaim threshold
            # (transport.add_group) through the registry
            self._share.notify_open(write.shuffle_id, tr, write)

    def _destroy_shuffles(self, sids):
        """All-consumers-done sweep — the transport skips partitions
        already released per-task (each release is billed; re-issuing
        deletes for channels the scheduler knows are gone would skew the
        benchmarks' request counts)."""
        for sid in sids:
            nparts, _ = self._sid_meta[sid]
            self._transport_of(sid).destroy(sid, nparts)

    def _consumer_stage_done(self, si: int, stage: StagePlan):
        """Per-(shuffle, consumer-stage) GC: record that stage ``si``
        drained its groups; destroy only the shuffles whose EVERY
        consuming stage has now finished — a CSE-shared shuffle must stay
        alive for its remaining consumer groups."""
        dead = []
        for sid in _consumed_shuffles(stage):
            drained = self._sid_drained[sid]
            drained.add(si)
            if drained >= self._sid_consumers[sid]:
                if self._share is not None and self._share.manages(sid):
                    # service-shared: other jobs may still be draining —
                    # the registry destroys once every participant is done
                    self._share.job_drained(sid, self._job_id)
                else:
                    dead.append(sid)
        self._destroy_shuffles(dead)

    def _release_task_partitions(self, task: TaskDef):
        """A completed consumer's shuffle partitions are dead FOR ITS
        GROUP: release them now so a losing speculative duplicate (or a
        late retry of a task that already won) aborts immediately
        (QueueGone / exchange tombstone) instead of blocking a pool thread
        until the drain timeout. Sibling consumer groups keep draining."""
        if isinstance(task.input, ShuffleRead):
            groups = task.input.groups or [0] * len(task.input.parts)
            parts = task.input.partitions or [task.input.partition]
            for (sid, _), g in zip(task.input.parts, groups):
                for p in parts:
                    self._transport_of(sid).release_partition(
                        sid, p, consumer_group=g)

    # ----------------------------------------- adaptive replanning (AQE)
    def _adaptive_on(self) -> bool:
        """Runtime replanning runs SOLO only: in service mode the plan
        shape was published to the cross-job CSE registry, and rewriting
        a shuffle another tenant may join would break that contract."""
        return self.cfg.adaptive and self._binding is None

    def _note_shuffle_stats(self, stage: StagePlan, resp: dict):
        """Fold one successful response's per-partition shuffle-output
        deltas (wire bytes, records) into the running measurement for the
        stage's shuffle — the feedback signal every replan decision reads."""
        out = (resp.get("stats") or {}).get("shuffle_out")
        if not out or stage.write is None:
            return
        agg = self.shuffle_stats.setdefault(stage.write.shuffle_id, {})
        for p, (nbytes, nrecs) in out.items():
            st = agg.setdefault(int(p), [0, 0])
            st[0] += nbytes
            st[1] += nrecs

    def _measured_sid_bytes(self, sid: int) -> float | None:
        stats = self.shuffle_stats.get(sid)
        if stats is None:
            return None
        return float(sum(b for b, _ in stats.values()))

    def _find_join_gates(self, stages) -> list[tuple[int, int, int]]:
        """Two-sided shuffle joins eligible for runtime broadcast
        conversion: returns ``(small_si, large_si, join_si)`` triples,
        where ``small`` is the producer stage whose measured output will
        decide the conversion once it completes. Eligible means: both
        sides produced by this job, each consumed ONLY by the join stage
        (a CSE-shared side must stay a shuffle), the join semantics leave
        the broadcast side non-preserved (inner: either side; left: only
        the right side may broadcast; right: only the left; outer:
        nothing), and the join's ops carry no per-task cache
        materialization (its spec is keyed to the planned task count)."""
        gates: list[tuple[int, int, int]] = []
        used: set[int] = set()
        for jsi, stage in enumerate(stages):
            if not stage.tasks:
                continue
            inp = stage.tasks[0].input
            if not (isinstance(inp, ShuffleRead) and len(inp.parts) == 2
                    and not inp.self_join
                    and all(m == "join" for _, m in inp.parts)):
                continue
            if any(kind == "cache" for kind, _ in stage.tasks[0].ops):
                continue
            sid_l, sid_r = inp.parts[0][0], inp.parts[1][0]
            psl = self._producer_stage_of.get(sid_l)
            psr = self._producer_stage_of.get(sid_r)
            if psl is None or psr is None or psl == psr:
                continue
            if (self._sid_consumers.get(sid_l) != {jsi}
                    or self._sid_consumers.get(sid_r) != {jsi}):
                continue
            wl, wr = stages[psl].write, stages[psr].write
            if wl.consumer_groups != 1 or wr.consumer_groups != 1:
                continue
            if self._share is not None and (self._share.manages(sid_l)
                                            or self._share.manages(sid_r)):
                continue
            how = inp.join_how
            if how == "outer":
                continue  # both sides preserved: no broadcastable side
            if how == "left":
                small, large = psr, psl  # only the right side may ship
            elif how == "right":
                small, large = psl, psr
            elif wl.est_bytes <= wr.est_bytes:
                small, large = psl, psr
            else:
                small, large = psr, psl
            if not stages[small].tasks or not stages[large].tasks:
                continue
            if {small, large, jsi} & used:
                continue  # overlapping gates: keep the first, skip the rest
            used |= {small, large, jsi}
            gates.append((small, large, jsi))
        return gates

    def _publish_broadcast(self, prefix: str, small_si: int,
                           group: int = 0):
        """Drain the completed small join side ON THE DRIVER (billed
        receives/GETs through its transport, exactly what a consumer
        stage would have paid) and re-publish it as content-addressed
        ``_broadcast/`` objects plus a batch-count manifest. The records
        are sorted before packing so the published bytes are a pure
        function of the record multiset — a rebuild after loss publishes
        identical objects and mid-flight readers stay consistent."""
        stage = self._stages[small_si]
        sid = stage.write.shuffle_id
        nparts, tname = self._sid_meta[sid]
        tr = self.transports.get(tname)
        quorum = len(stage.tasks)
        records: list = []
        handles = []
        claim: list = []
        for p in range(nparts):
            handle = tr.open_drain(sid, p, quorum, group=claim,
                                   consumer_group=group)
            for _src, _seq, body in handle:
                records.extend(unpack_batch(body, self.lam.rstore))
            handles.append(handle)
        for handle in handles:
            handle.ack()
        records.sort(key=_stable_order)
        bodies = pack_batch(records, limit=S3_EXCHANGE_BATCH_LIMIT)
        for seq, body in enumerate(bodies):
            self.lam.rstore.put(f"{prefix}{seq:06d}", body)
        self.lam.rstore.put_obj(f"{prefix}manifest", len(bodies))
        tr.destroy(sid, nparts)

    def _try_broadcast_convert(self, small_si: int, large_si: int,
                               join_si: int) -> bool:
        """The tentpole rewrite: once the small side's MEASURED output is
        known (its producer stage completed), decide shuffle-vs-broadcast
        from actual volume. On broadcast: the driver re-publishes the
        small side under ``_broadcast/``, the large producer stage keeps
        its own input and ops but gains a ``bcjoin`` probe op plus the
        join stage's pipeline, write, and action — and the join stage is
        absorbed (its large-side shuffle never opens, shipping zero
        bytes). Downstream EOS quorums follow the large stage's task
        count via the live ``producer_counts`` reads. Returns True when
        converted; False leaves the planned shuffle join untouched."""
        stages = self._stages
        small, large, join = stages[small_si], stages[large_si], \
            stages[join_si]
        sid_s = small.write.shuffle_id
        measured = self._measured_sid_bytes(sid_s)
        if measured is None:
            return False
        jt = join.tasks[0]
        choice = pick_join_strategy(
            measured, max(large.write.est_bytes, measured),
            len(large.tasks), large.write.nparts, len(large.tasks),
            self.cfg.broadcast_threshold_bytes)
        if choice != "broadcast":
            return False
        k = jt.input.parts.index((sid_s, "join"))
        group = jt.input.groups[k] if jt.input.groups else 0
        prefix = f"_broadcast/{self._scope}sid{sid_s}/"
        self._publish_broadcast(prefix, small_si, group)
        self._broadcasts[prefix] = {"stage": small_si, "group": group}
        spec = {"prefix": prefix, "side": small.write.key_side or "left",
                "how": jt.input.join_how}
        extra_ops = [("bcjoin", spec)] + list(jt.ops)
        for t in large.tasks:
            t.ops = list(t.ops) + extra_ops
            t.write = join.write
        large.write = join.write
        large.action = join.action
        large.save_prefix = join.save_prefix
        large.limit = join.limit
        if join.write is not None:
            sid_j = join.write.shuffle_id
            self._producer_stage_of[sid_j] = large_si
            for ci in self._sid_consumers.get(sid_j, ()):
                stages[ci].producer_counts[sid_j] = len(large.tasks)
        join.tasks = []
        join.write = None
        join.action = None
        join.save_prefix = None
        self._absorbed[large_si] = join_si
        self.adaptive_stats["broadcast_joins"] += 1
        if self.verbose:
            print(f"[flint] adaptive: join stage {join.id} -> broadcast "
                  f"({measured:.0f}B build side from shuffle {sid_s})")
        return True

    def _broadcast_intact(self, prefix: str) -> bool:
        """The same manifest check ``broadcast_read`` performs: does the
        store hold exactly the advertised batch count under prefix?"""
        expected, data = None, 0
        for key in self.lam.rstore.list(prefix):
            if key.endswith("manifest"):
                expected = self.lam.rstore.get_obj(key)
            else:
                data += 1
        return expected is not None and expected == data

    def _rebuild_broadcast(self, prefix: str) -> bool:
        """Lineage recovery for a lost ``_broadcast/`` object: reopen the
        small side's channels, replay its producer stage (byte-identical
        re-emission), re-drain on the driver and re-publish — the sorted
        content-addressed pack writes the same bytes, so probe tasks that
        already read the old copy agree with ones reading the new.
        Charged against the per-stage resubmission budget."""
        info = self._broadcasts.get(prefix)
        if info is None:
            return False
        if self._broadcast_intact(prefix):
            # a peer task's failure already triggered the rebuild (many
            # probe tasks trip over the same lost object concurrently) —
            # the store is whole again, just rerun without charging
            return True
        key = ("broadcast", prefix)
        n = self._stage_retries.get(key, 0) + 1
        if n > self.cfg.max_stage_retries:
            return False
        self._stage_retries[key] = n
        small_si, group = info["stage"], info["group"]
        write = self._stages[small_si].write
        sid = write.shuffle_id
        self._transport_of(sid).reopen(sid, write.nparts,
                                       groups=write.consumer_groups)
        self._replay_stage(small_si)
        self._publish_broadcast(prefix, small_si, group)
        self.adaptive_stats["broadcast_rebuilds"] += 1
        self.recovery_stats["stage_resubmits"] += 1
        return True

    def _coalesce_stage(self, stage: StagePlan):
        """Barrier-mode partition coalescing: with every input shuffle
        fully produced and measured, fold runs of CONTIGUOUS tiny
        partitions (under ``cfg.coalesce_min_bytes`` together) into single
        consumer tasks — each drains its whole partition list in order, so
        index-ordered merges (collect, range-sorted output) are
        unchanged. Downstream EOS quorums follow the new task count via
        the live ``producer_counts`` reads."""
        floor = float(self.cfg.coalesce_min_bytes)
        if not floor or len(stage.tasks) <= 1:
            return
        if any(not isinstance(t.input, ShuffleRead) or t.input.partitions
               or t.input.partition != i
               for i, t in enumerate(stage.tasks)):
            return
        sids = [sid for sid, _ in stage.tasks[0].input.parts]
        per_part: list[float] = []
        for p in range(len(stage.tasks)):
            tot = 0.0
            for sid in sids:
                st = self.shuffle_stats.get(sid)
                if st is None:
                    return  # unmeasured input (e.g. foreign): keep plan
                tot += st.get(p, (0, 0))[0]
            per_part.append(tot)
        groups: list[list[int]] = []
        cur: list[int] = []
        cur_bytes = 0.0
        for p, b in enumerate(per_part):
            cur.append(p)
            cur_bytes += b
            if cur_bytes >= floor:
                groups.append(cur)
                cur, cur_bytes = [], 0.0
        if cur:
            if groups:
                groups[-1].extend(cur)
            else:
                groups.append(cur)
        if len(groups) >= len(stage.tasks):
            return
        new_tasks = []
        for i, grp in enumerate(groups):
            t = stage.tasks[grp[0]]
            t.index = i
            t.input.partition = grp[0]
            t.input.partitions = list(grp) if len(grp) > 1 else None
            new_tasks.append(t)
        stage.tasks = new_tasks
        if stage.write is not None:
            sid_w = stage.write.shuffle_id
            for ci in self._sid_consumers.get(sid_w, ()):
                self._stages[ci].producer_counts[sid_w] = len(new_tasks)
        self.adaptive_stats["coalesced_stages"] += 1
        if self.verbose:
            print(f"[flint] adaptive: stage {stage.id} coalesced to "
                  f"{len(new_tasks)} task(s)")

    def _rechoose_transport(self, stage: StagePlan):
        """Re-run the SQS-vs-S3 cost choice for a not-yet-opened shuffle
        from MEASURED input volume, scaled by the planner's own
        output/input ratio. Only cost-model ("auto") choices move —
        explicit per-shuffle hints and engine defaults stay pinned — and
        a move to SQS is refused when the run-wide visibility guard
        would reject it."""
        write = stage.write
        if write is None or not write.auto_transport:
            return
        sids = _consumed_shuffles(stage)
        if not sids:
            return
        measured = 0.0
        for sid in sids:
            m = self._measured_sid_bytes(sid)
            if m is None:
                return
            measured += m
        est_in = sum(
            self._stages[self._producer_stage_of[sid]].write.est_bytes
            for sid in sids if sid in self._producer_stage_of)
        new_est = (write.est_bytes * measured / est_in) if est_in > 0 \
            else measured
        choice = pick_shuffle_transport(new_est, len(stage.tasks),
                                        write.nparts)
        cur = write.transport or self.cfg.fallback_backend
        if choice == cur:
            return
        if (choice == "sqs" and self.cfg.visibility_timeout_s
                >= self.cfg.drain_timeout_s):
            return
        write.transport = choice
        sid_w = write.shuffle_id
        self._sid_meta[sid_w] = (write.nparts, choice)
        for ci in self._sid_consumers.get(sid_w, ()):
            for t in self._stages[ci].tasks:
                tmap = (t.input.transports
                        if isinstance(t.input, ShuffleRead) else None)
                if tmap and sid_w in tmap:
                    tmap[sid_w] = choice
        self.adaptive_stats["transport_rechoices"] += 1
        if self.verbose:
            print(f"[flint] adaptive: shuffle {sid_w} transport "
                  f"{cur} -> {choice} ({new_est:.0f}B measured est)")

    # ----------------------------------------------------- barrier mode
    def _run_barrier(self, stages: list[StagePlan]):
        result = None
        adaptive = self._adaptive_on()
        # large-side producer stage -> its join gate (broadcast candidate)
        gate_by_large = {large: (small, large, jsi) for small, large, jsi
                         in (self._find_join_gates(stages)
                             if adaptive else ())}
        try:
            for si, stage in enumerate(stages):
                if si in self._absorbed.values():
                    # join stage absorbed into its large-side producer by
                    # an earlier broadcast conversion: nothing left to run
                    self._stage_done[si] = True
                    continue
                if adaptive:
                    # the stage boundary: every input of stage ``si`` is
                    # complete and measured — re-optimize what remains
                    gate = gate_by_large.get(si)
                    if gate is not None:
                        self._try_broadcast_convert(*gate)
                    self._coalesce_stage(stage)
                    self._rechoose_transport(stage)
                if stage.write is not None:
                    self._open_shuffle(stage.write)
                result = self._run_stage(stage)
                self._stage_done[si] = True
                # channels whose last consumer just finished are dead
                self._consumer_stage_done(si, stage)
        except BaseException:
            # same teardown as the pipelined path: a consumer blocked on a
            # queue that will never fill must not linger in the thread
            # pool until drain_timeout_s
            self.sqs.close()
            raise
        return result

    # ------------------------------------------------------------------
    def _payload_for(self, task: TaskDef, stage: StagePlan, attempt: int,
                     extra: dict | None = None) -> dict:
        extra = dict(extra or {})
        fault = self.faults.task_fault(task.stage_id, task.index)
        if fault.get("fail_attempts", 0) > attempt:
            extra["inject_failure"] = True
        if fault.get("straggle_s") and attempt == 0 \
                and not extra.get("_speculative"):
            extra["straggle_s"] = fault["straggle_s"]
        if fault.get("fail_after_records") and attempt == 0:
            extra["fail_after_records"] = fault["fail_after_records"]
        if fault.get("fail_on_link") and attempt == 0 \
                and extra.get("_link") == fault["fail_on_link"]:
            # kill a specific link of a CHAINED task — exercises the
            # resume-from-cursor retry path deterministically
            extra["inject_failure"] = True
        extra.pop("_link", None)
        extra.pop("_speculative", None)
        if isinstance(task.input, ShuffleRead):
            # EOS termination quorum, known at plan time — both modes
            extra["n_producers"] = {
                str(sid): stage.producer_counts[sid]
                for sid, _ in task.input.parts}
        if stage.action == "save" or stage.save_prefix:
            extra["save_prefix"] = stage.save_prefix
        return serialize_task(task, attempt, extra)

    # -------------------------------------------- failure triage + recovery
    def _task_failure(self, stage, idx, n_attempts, resp, *,
                      retryable=False) -> StageFailure:
        return StageFailure(
            f"task {stage.id}/{idx} failed after {n_attempts} attempt(s): "
            f"{resp.get('error')}",
            error_type=resp.get("error_type", ""),
            stage_id=stage.id, task_index=idx, attempts=n_attempts,
            retryable=retryable, detail=resp.get("detail"))

    def _on_task_error(self, stage, task, resp, attempts_map):
        """Shared failure triage for both scheduler modes and the replay
        path. Returns after deciding the task should run again (charging a
        retry attempt unless the failure was a recovered lost input —
        those are the INPUT's fault, bounded by the stage-resubmission
        budget instead); raises a structured StageFailure when the cause
        is terminal at this layer."""
        err = resp.get("error_type", "")
        idx = task.index
        if err == "MemoryCapExceeded":
            # retryable=True: the context's answer is elasticity — raise
            # the partition count and re-plan (message kept verbatim)
            raise StageFailure(resp.get("error", ""),
                               error_type="MemoryCapExceeded",
                               stage_id=stage.id, task_index=idx,
                               attempts=attempts_map[idx] + 1,
                               retryable=True)
        if err == "RetryBudgetExhausted":
            # the job-wide budget is gone; any further attempt would just
            # trip it again on its first service call
            raise self._task_failure(stage, idx, attempts_map[idx] + 1, resp)
        if err == "LostCacheInput":
            # durable cache data is gone — only the context can replan the
            # cached lineage and re-materialize (detail carries the token)
            raise self._task_failure(stage, idx, attempts_map[idx] + 1,
                                     resp, retryable=True)
        if err == "LostBroadcastInput":
            # an adaptive broadcast build side vanished: replay the small
            # side's lineage and re-publish identical bytes, then rerun
            # the probe task without charging it — the loss was the
            # input's fault, bounded by the stage-resubmission budget
            self.recovery_stats["lost_inputs"] += 1
            prefix = (resp.get("detail") or {}).get("broadcast_prefix", "")
            if self._rebuild_broadcast(prefix):
                return
            raise self._task_failure(stage, idx, attempts_map[idx] + 1,
                                     resp)
        if self._is_lost_input(task, err):
            self.recovery_stats["lost_inputs"] += 1
            if self._recover_lost_input(task, resp.get("detail")):
                return  # input re-created — rerun without charging the task
            raise self._task_failure(
                stage, idx, attempts_map[idx] + 1,
                dict(resp, error=f"{resp.get('error')} [stage-resubmission "
                     f"budget exhausted: max_stage_retries="
                     f"{self.cfg.max_stage_retries}]"))
        attempts_map[idx] += 1
        if attempts_map[idx] > self.cfg.max_task_retries:
            raise self._task_failure(stage, idx, attempts_map[idx], resp)

    def _is_lost_input(self, task: TaskDef, err_type: str) -> bool:
        """LostShuffleInput is conclusive on its own — the drain proved the
        producer quorum complete with advertised data absent. A bare drain
        TimeoutError only means lost input once every producing stage
        finished; before that it is an ordinary slow/failed producer and
        task retry is the right tool."""
        if not isinstance(task.input, ShuffleRead):
            return False
        if err_type == "LostShuffleInput":
            return True
        if err_type != "TimeoutError":
            return False
        return all(self._stage_done[self._producer_stage_of[sid]]
                   for sid, _ in task.input.parts
                   if sid in self._producer_stage_of)

    def _next_dispatch_backoff(self) -> float:
        """Decorrelated-jitter pause before re-dispatching a 429-throttled
        invocation; grows while throttles keep coming, resets to idle on
        the next successful completion."""
        base = self.cfg.dispatch_backoff_base_s
        prev = self._dispatch_sleep or base
        self._dispatch_sleep = min(self.cfg.dispatch_backoff_cap_s,
                                   self._backoff_rng.uniform(base, prev * 3))
        return self._dispatch_sleep

    def _recover_lost_input(self, task: TaskDef, detail=None) -> bool:
        """Lineage-based recovery (docs/fault_tolerance.md): the consumer
        proved its shuffle input permanently gone, so re-execute producing
        tasks from lineage, exactly as the paper's driver would.

        TARGETED path: when the drain names the producers whose advertised
        output vanished (detail["srcs"], ``s{stage}t{index}``), only those
        tasks are resubmitted — their re-emission is byte-identical
        (stable partitioning, sorted re-emission, fixed flush boundaries)
        and rewrites the content-addressed keys in place, so the retried
        consumer's deferred GETs pick them up without reopening the
        channel. This keeps recovery cost proportional to what was lost,
        not to the stage width. A quorum-incomplete drain timeout with
        every producing stage finished (a LOST EOS MANIFEST) is targeted
        too: the drain reports which producers' manifests DID arrive
        (detail["have_eos"]) and the absent ones are the targets. And when
        a target sits MID-CHAIN — its own shuffle input was already
        released, tombstoned, and reclaimed by its first successful run —
        the replay expands deepest-first: the upstream producing stage is
        resubmitted in full (every producer feeds every partition) behind
        a channel ``reopen``, or the replayed task would abort on its own
        stale tombstone.

        FULL path (no producer names at all): reopen and replay the whole
        upstream lineage deepest-first; consumers still mid-drain dedup
        the byte-identical overlap instead of double-counting.

        Both paths charge the per-stage resubmission budget; returns
        False when max_stage_retries is exhausted."""
        if any(sid not in self._producer_stage_of
               for sid, _ in task.input.parts):
            # a service-shared input produced by ANOTHER job's scheduler:
            # no lineage here to replay it with. Fail structured — the
            # service answers with one solo re-plan (sharing disabled)
            return False
        detail = detail or {}
        targets: dict[int, set[int]] = {}
        stage_by_id = {s.id: i for i, s in enumerate(self._stages)}
        srcs = detail.get("srcs") or ()
        if not srcs and "have_eos" in detail:
            # every producing stage is done (the caller checked), yet the
            # EOS quorum never completed: the missing manifests' writers
            # are exactly the producers not named in have_eos
            psi = self._producer_stage_of.get(detail.get("sid"))
            if psi is not None:
                have = set(detail["have_eos"])
                pstage = self._stages[psi]
                srcs = [s for s in (f"s{pstage.id}t{t.index}"
                                    for t in pstage.tasks) if s not in have]
        for src in srcs:
            m = re.fullmatch(r"s(\d+)t(\d+)", src)
            psi = stage_by_id.get(int(m.group(1))) if m else None
            if psi is None:
                targets.clear()  # unparseable producer: fall back to full
                break
            targets.setdefault(psi, set()).add(int(m.group(2)))
        if targets:
            replay_order: list[int] = []
            only: dict[int, set[int] | None] = {}  # None = full stage
            reopen_sids: list[int] = []
            scanned: set[tuple[int, int]] = set()

            def require(psi: int, indices: set[int] | None):
                stage = self._stages[psi]
                for t in stage.tasks:
                    if indices is not None and t.index not in indices:
                        continue
                    if (psi, t.index) in scanned:
                        continue
                    scanned.add((psi, t.index))
                    inp = t.input
                    if not isinstance(inp, ShuffleRead):
                        continue
                    for k, (sid, _mode) in enumerate(inp.parts):
                        up = self._producer_stage_of.get(sid)
                        if up is None:
                            continue
                        g = inp.groups[k] if inp.groups else 0
                        if not self._transport_of(sid).partition_drainable(
                                sid, inp.partition, g):
                            if sid not in reopen_sids:
                                reopen_sids.append(sid)
                            require(up, None)
                if psi not in only:
                    only[psi] = set() if indices is not None else None
                    replay_order.append(psi)
                if indices is None:
                    only[psi] = None
                elif only[psi] is not None:
                    only[psi] |= indices
            for psi, indices in sorted(targets.items()):
                require(psi, indices)
            # only the NAMED target stages are charged: an upstream stage
            # replayed solely to re-produce a reclaimed input rides its
            # target's charge (every recovery still charges >= 1 stage,
            # so a black-hole loss loop stays bounded), or deep chains
            # would bill the innermost stage for every downstream incident.
            # The charge is keyed per (stage, task set): a permanently
            # black-holed object re-targets the SAME tasks every time and
            # exhausts at max_stage_retries, while independent losses on
            # different producers of a wide stage don't share one counter
            for psi, indices in targets.items():
                key = (psi, tuple(sorted(indices)))
                n = self._stage_retries.get(key, 0) + 1
                if n > self.cfg.max_stage_retries:
                    return False
                self._stage_retries[key] = n
            for sid in reopen_sids:
                write = self._stages[self._producer_stage_of[sid]].write
                self._transport_of(sid).reopen(
                    sid, write.nparts, groups=write.consumer_groups)
            for psi in replay_order:
                self._replay_stage(psi, only=only[psi])
            self.recovery_stats["stage_resubmits"] += len(replay_order)
            return True
        order: list[int] = []
        seen: set[int] = set()

        def visit(sid: int):
            psi = self._producer_stage_of.get(sid)
            if psi is None or psi in seen:
                return
            seen.add(psi)
            for up in sorted(_consumed_shuffles(self._stages[psi])):
                visit(up)
            order.append(psi)

        for sid, _ in task.input.parts:
            visit(sid)
        if not order:
            return False
        for psi in order:
            n = self._stage_retries.get(psi, 0) + 1
            if n > self.cfg.max_stage_retries:
                return False
            self._stage_retries[psi] = n
        for psi in order:
            write = self._stages[psi].write
            self._transport_of(write.shuffle_id).reopen(
                write.shuffle_id, write.nparts,
                groups=write.consumer_groups)
            self._replay_stage(psi)
        self.recovery_stats["stage_resubmits"] += len(order)
        return True

    def _replay_stage(self, psi: int, only: set[int] | None = None):
        """Synchronously re-execute one producing stage (or, with
        ``only``, just the named task indices) for lineage recovery — on
        a PRIVATE pool, because the main pool's threads may all be
        consumers blocked in drains waiting for exactly this data.
        Replay invocations carry a large attempt number so targeted
        first-attempt faults don't re-fire, while the tasks' shuffle
        identity (src = stage/index) is unchanged. Completed partitions
        are NOT released here: the retried consumer re-drains the
        channels, and the job-end GC sweeps whatever remains."""
        stage = self._stages[psi]
        cfg = self.cfg
        tasks = [t for t in stage.tasks
                 if only is None or t.index in only]
        by_idx = {t.index: t for t in tasks}
        attempts = {t.index: 0 for t in tasks}
        cursors: dict[int, dict] = {}
        delayed: list = []  # (due, task, extra) — 429 backoff
        inflight: dict = {}
        pool = cf.ThreadPoolExecutor(
            max_workers=max(1, cfg.concurrency // 2))
        try:
            def launch(task, extra=None):
                payload = self._payload_for(
                    task, stage, _REPLAY_ATTEMPT + attempts[task.index],
                    dict(extra or {}))
                inflight[pool.submit(self.lam.invoke, payload)] = task.index

            for t in tasks:
                launch(t)
            while inflight or delayed:
                now = time.monotonic()
                due = [e for e in delayed if e[0] <= now]
                if due:
                    delayed = [e for e in delayed if e[0] > now]
                    for _, t, extra in due:
                        launch(t, extra)
                if not inflight:
                    time.sleep(max(0.001, min(
                        0.25, min(e[0] for e in delayed) - now)))
                    continue
                done, _ = cf.wait(list(inflight), timeout=0.25,
                                  return_when=cf.FIRST_COMPLETED)
                for fut in done:
                    idx = inflight.pop(fut)
                    resp = fut.result()
                    if "spilled" in resp:
                        resp = pickle.loads(
                            self.lam.rstore.get(resp["spilled"]))
                    if resp.get("status") == "throttled":
                        self.recovery_stats["throttled"] += 1
                        delayed.append(
                            (time.monotonic() + self._next_dispatch_backoff(),
                             by_idx[idx], cursors.get(idx)))
                        continue
                    if resp.get("status") != "ok":
                        # re-entrant on purpose: a lost input DURING replay
                        # cascades one level deeper, bounded by the shared
                        # per-stage resubmission counters
                        self._on_task_error(stage, by_idx[idx], resp,
                                            attempts)
                        launch(by_idx[idx], cursors.get(idx))
                        continue
                    if "continuation" in resp:
                        cursors[idx] = resp["continuation"]
                        launch(by_idx[idx], resp["continuation"])
                        continue
                    self.recovery_stats["replayed_tasks"] += 1
        finally:
            pool.shutdown(wait=False)

    def _invoke_slotted(self, payload):
        """Barrier-mode fair-share gate, applied INSIDE the worker thread
        (safe to block there: a barrier stage's inputs are complete, so a
        task holding a slot never waits on another that wants one).
        Pipelined mode gates at the launch frontier instead — its
        consumers block mid-drain on producers that may be slot-starved,
        so blocking a worker thread on a slot could deadlock."""
        self._slots.acquire()
        try:
            return self.lam.invoke(payload)
        finally:
            self._slots.release()

    def _run_stage(self, stage: StagePlan) -> Any:
        t0 = time.monotonic()
        n = len(stage.tasks)
        results: dict[int, Any] = {}
        partials: dict[int, list] = {}
        attempts: dict[int, int] = {i: 0 for i in range(n)}
        durations: list[float] = []
        speculated: set[int] = set()
        inflight: dict[cf.Future, tuple[int, bool, float]] = {}
        dup_dropped = 0
        chained = 0
        # last continuation cursor per chained task: a retry resumes from
        # here instead of replaying from scratch — the already-emitted
        # links' (src, seq) messages stay untouched and only the failed
        # link replays (its flush boundaries are count-based, so the
        # replay is byte-identical)
        cursors: dict[int, dict] = {}
        links: dict[int, int] = {}
        delayed: list = []  # (due, task, extra) — 429 dispatch backoff

        def launch(task: TaskDef, extra=None, speculative=False):
            payload = self._payload_for(
                task, stage, attempts[task.index],
                dict(extra or {}, _speculative=speculative))
            fut = self.pool.submit(self._invoke_slotted, payload)
            inflight[fut] = (task.index, speculative, time.monotonic())

        for task in stage.tasks:
            launch(task)

        def spec_armed() -> bool:
            # consumers included: visibility-timeout receives make two
            # drains of one queue race on acks, not split messages. Only
            # FIRST attempts are speculated — a retry's latency baseline
            # is meaningless (a consumer retry is waiting out its dead
            # predecessor's visibility deadline), and a twin racing it
            # would hold claims the retry needs. Tasks that already
            # CHAINED are excluded too: a twin restarting from scratch
            # could cut its links at different wall-clock positions and
            # emit conflicting framings under the same sequence ids
            return (len(durations) >= self.cfg.speculation_min_done
                    and len(inflight) < self.cfg.concurrency
                    and any(not spec and idx not in speculated
                            and idx not in results and attempts[idx] == 0
                            and idx not in cursors
                            for idx, spec, _ in inflight.values()))

        # straggler thresholds compare scheduler-observed latency, so allow
        # for a cold start before calling anything a straggler
        start_allowance = self.cfg.cold_start_s * self.cfg.start_latency_scale

        while inflight or delayed:
            if self._cost_guard is not None:
                self._cost_guard()
            now = time.monotonic()
            due = [e for e in delayed if e[0] <= now]
            if due:
                delayed = [e for e in delayed if e[0] > now]
                for _, dtask, dextra in due:
                    launch(dtask, extra=dextra)
            if not inflight:
                # every runnable task is backing off a 429
                time.sleep(max(0.001, min(
                    0.25, min(e[0] for e in delayed) - time.monotonic())))
                continue
            # event-driven: block on completions; wake periodically only
            # while a straggler check or a delayed re-dispatch could fire
            done, _ = cf.wait(list(inflight),
                              timeout=0.05 if (spec_armed() or delayed)
                              else 5.0,
                              return_when=cf.FIRST_COMPLETED)
            now = time.monotonic()
            # straggler speculation
            if (len(durations) >= self.cfg.speculation_min_done
                    and len(inflight) < self.cfg.concurrency):
                med = sorted(durations)[len(durations) // 2]
                for fut, (idx, spec, started) in list(inflight.items()):
                    if (not spec and idx not in speculated
                            and idx not in results and attempts[idx] == 0
                            and idx not in cursors
                            and now - started > self.cfg.speculation_factor
                            * max(med, 0.05) + start_allowance):
                        speculated.add(idx)
                        launch(stage.tasks[idx], speculative=True)
            for fut in done:
                idx, speculative, started = inflight.pop(fut)
                resp = fut.result()
                if "spilled" in resp:
                    resp = pickle.loads(self.lam.rstore.get(resp["spilled"]))
                if idx in results:
                    dup_dropped += 1  # speculative duplicate lost the race
                    continue
                if resp.get("status") == "throttled":
                    # 429: never ran, never billed — re-dispatch after a
                    # decorrelated-jitter pause, no retry attempt charged
                    self.recovery_stats["throttled"] += 1
                    delayed.append(
                        (time.monotonic() + self._next_dispatch_backoff(),
                         stage.tasks[idx], cursors.get(idx)))
                    continue
                if resp.get("status") != "ok":
                    # a dead consumer's unacked messages redeliver after
                    # the visibility timeout, so its retry sees them all;
                    # lost durable input triggers lineage resubmission
                    # instead (triage raises when terminal)
                    self._on_task_error(stage, stage.tasks[idx], resp,
                                        attempts)
                    launch(stage.tasks[idx], extra=cursors.get(idx))
                    continue
                self._dispatch_sleep = 0.0  # concurrency is healthy again
                self._note_shuffle_stats(stage, resp)
                if "continuation" in resp:
                    # executor chaining: merge partial output, re-invoke warm
                    chained += 1
                    self._merge_partial(resp, idx, partials)
                    cursors[idx] = resp["continuation"]
                    links[idx] = links.get(idx, 1) + 1
                    launch(stage.tasks[idx],
                           extra=dict(resp["continuation"],
                                      _link=links[idx]))
                    continue
                durations.append(now - started)
                self._merge_partial(resp, idx, partials)
                results[idx] = True
                self._release_task_partitions(stage.tasks[idx])

        self.stage_stats.append({
            "stage": stage.id, "tasks": n,
            "wall_s": round(time.monotonic() - t0, 4),
            "attempts": sum(attempts.values()) + n,
            "chained": chained,
            "speculated": len(speculated),
            "spec_dropped": dup_dropped,
        })
        if self.verbose:
            print(f"[flint] stage {stage.id}: {self.stage_stats[-1]}")

        return self._stage_result(stage, partials)

    # --------------------------------------------------- pipelined mode
    def _run_pipelined(self, stages: list[StagePlan]):
        cfg = self.cfg
        # Adaptive join gating: for each eligible two-sided join, HOLD the
        # larger-estimated side's producer stage and the join stage (and
        # the join output's direct consumers, whose EOS quorum payloads
        # must see the post-decision producer count) until the small side
        # completes and its measured size decides shuffle vs broadcast.
        # The large side's shuffle channels are not opened until then —
        # on conversion they are never opened at all. Everything else
        # pipelines exactly as before; with adaptive off the gate set is
        # empty and this is the old code path.
        gates = (self._find_join_gates(stages)
                 if self._adaptive_on() else [])
        gate_by_small: dict[int, list] = {}
        # stage index -> number of unresolved gates holding it back (a
        # stage consuming TWO gated joins' outputs waits for both)
        gate_holds: dict[int, int] = {}
        deferred_opens: set[int] = set()
        for small, large, jsi in gates:
            held = {large, jsi}
            deferred_opens.add(large)
            jw = stages[jsi].write
            if jw is not None:
                held |= self._sid_consumers.get(jw.shuffle_id, set())
            gate_by_small.setdefault(small, []).append(
                (small, large, jsi, held))
            for h in held:
                gate_holds[h] = gate_holds.get(h, 0) + 1
        gated = set(gate_holds)
        for si, stage in enumerate(stages):
            if stage.write is not None and si not in deferred_opens:
                self._open_shuffle(stage.write)

        deps = [sorted(self._producer_stage_of[sid]
                       for sid in _consumed_shuffles(stage)
                       if sid in self._producer_stage_of)
                for stage in stages]

        n_stages = len(stages)
        results: list[dict] = [{} for _ in stages]
        partials: list[dict] = [{} for _ in stages]
        attempts = [{i: 0 for i in range(len(s.tasks))} for s in stages]
        durations: list[list[float]] = [[] for _ in stages]
        speculated: list[set] = [set() for _ in stages]
        chained = [0] * n_stages
        dup_dropped = [0] * n_stages
        # last continuation cursor per chained task (see _run_stage)
        cursors: list[dict] = [{} for _ in stages]
        links: list[dict] = [{} for _ in stages]
        stage_done = self._stage_done  # shared: failure triage reads it
        stage_t0: list[float | None] = [None] * n_stages
        stats_rows: list[dict | None] = [None] * n_stages
        final_result: list[Any] = [None]

        # launch frontier: a min-heap keyed (stage, arrival) so producer
        # work — including late retries and chained continuations — always
        # outranks consumer launches for a freed window slot
        ticket = itertools.count()
        pending: list = []
        delayed: list = []  # (due, si, task, extra) — 429 dispatch backoff
        inflight: dict[cf.Future, tuple[int, int, bool, float]] = {}

        def push(si, task, extra=None, speculative=False):
            heapq.heappush(pending,
                           (si, next(ticket), task, extra, speculative))

        for si, stage in enumerate(stages):
            if si in gated:
                continue  # released (and pushed) at gate resolution
            for task in stage.tasks:
                push(si, task)

        # fair-share slot accounting (service mode; _NullSlots solo). One
        # slot is held per inflight invocation. Retries and chained
        # continuations CARRY their predecessor's slot instead of
        # re-queueing for one — a continuation re-entering the general
        # scramble could starve behind other tenants' consumers that are
        # blocked mid-drain on exactly this producer's output. Carried
        # slots not consumed by launch_ready are returned at the end of
        # the event-loop iteration (invariant: held == inflight + carry).
        slots = self._slots
        carry = [0]

        def launch_ready():
            while pending and len(inflight) < cfg.concurrency:
                if carry[0] > 0:
                    carry[0] -= 1
                elif not slots.try_acquire():
                    break
                si, _, task, extra, speculative = heapq.heappop(pending)
                if task.index in results[si]:
                    carry[0] += 1
                    continue  # stale: original already won
                if stage_t0[si] is None:
                    stage_t0[si] = time.monotonic()
                payload = self._payload_for(
                    task, stages[si], attempts[si][task.index],
                    dict(extra or {}, _speculative=speculative))
                fut = self.pool.submit(self.lam.invoke, payload)
                inflight[fut] = (si, task.index, speculative,
                                 time.monotonic())
            # advertise EFFECTIVE demand — what could launch right now.
            # A job whose local pool is saturated must not hold the
            # fair-share pool idle against other tenants
            slots.set_demand(min(len(pending),
                                 max(0, cfg.concurrency - len(inflight))))

        def deps_done(si) -> bool:
            return all(stage_done[d] for d in deps[si])

        start_allowance = cfg.cold_start_s * cfg.start_latency_scale

        def spec_armed() -> bool:
            # consumers included (once their producers are done):
            # visibility-timeout receives make two drains of one queue
            # race on acks, not split messages. Only FIRST attempts are
            # speculated — a retry's latency baseline is meaningless (a
            # consumer retry is waiting out its dead predecessor's
            # visibility deadline), and a twin racing it would hold
            # claims the retry needs
            if len(inflight) >= cfg.concurrency:
                return False
            for fsi, idx, spec, _ in inflight.values():
                if (not spec and deps_done(fsi)
                        and len(durations[fsi]) >= cfg.speculation_min_done
                        and idx not in speculated[fsi]
                        and idx not in results[fsi]
                        and attempts[fsi][idx] == 0
                        and idx not in cursors[fsi]):
                    return True
            return False

        def release_gate(small_si, large_si, jsi, held):
            """The small join side completed: decide broadcast-vs-shuffle
            from its measured bytes, open the large side's channels if the
            shuffle survives, and un-hold every stage this gate held
            (stages held by several gates wait for all of them)."""
            converted = self._try_broadcast_convert(small_si, large_si,
                                                    jsi)
            if not converted:
                large = stages[large_si]
                if deps_done(large_si):
                    # every input measured: revisit the cost-model
                    # transport choice before the channels open
                    self._rechoose_transport(large)
                self._open_shuffle(large.write)
            for gsi in sorted(held):
                gate_holds[gsi] -= 1
                if gate_holds[gsi] == 0:
                    gated.discard(gsi)
                    for task in stages[gsi].tasks:
                        push(gsi, task)

        def finish_stage(si, stage):
            stage_done[si] = True
            stats_rows[si] = {
                "stage": stage.id, "tasks": len(stage.tasks),
                "wall_s": round(time.monotonic()
                                - (stage_t0[si] or time.monotonic()), 4),
                "attempts": sum(attempts[si].values()) + len(stage.tasks),
                "chained": chained[si],
                "speculated": len(speculated[si]),
                "spec_dropped": dup_dropped[si],
            }
            if self.verbose:
                print(f"[flint] stage {stage.id}: {stats_rows[si]}")
            self._consumer_stage_done(si, stage)
            if stage.action is not None or stage.write is None:
                final_result[0] = self._stage_result(stage, partials[si])
            for gate in gate_by_small.pop(si, ()):
                release_gate(*gate)
            jsi = self._absorbed.get(si)
            if jsi is not None:
                # the absorbed join stage finished WITH its large-side
                # producer — its work ran fused into that stage's tasks
                stage_done[jsi] = True
                stats_rows[jsi] = {
                    "stage": stages[jsi].id, "tasks": 0, "wall_s": 0.0,
                    "attempts": 0, "chained": 0, "speculated": 0,
                    "spec_dropped": 0, "absorbed": True,
                }

        launch_ready()
        try:
            while inflight or pending or delayed:
                if self._cost_guard is not None:
                    self._cost_guard()
                now = time.monotonic()
                due = [e for e in delayed if e[0] <= now]
                if due:
                    delayed = [e for e in delayed if e[0] > now]
                    for _, dsi, dtask, dextra in due:
                        push(dsi, dtask, extra=dextra)
                launch_ready()
                if not inflight:
                    if delayed:
                        # every runnable task is backing off a 429
                        time.sleep(max(0.001, min(
                            0.25,
                            min(e[0] for e in delayed) - time.monotonic())))
                    elif pending:
                        # slot-starved: every runnable task is waiting on
                        # the fair-share pool — block until a slot frees
                        slots.wait(0.05)
                    continue
                done, _ = cf.wait(list(inflight),
                                  timeout=0.05 if (spec_armed() or delayed
                                                   or slots.contended())
                                  else 5.0,
                                  return_when=cf.FIRST_COMPLETED)
                now = time.monotonic()
                # straggler speculation — only for stages whose producers
                # are all done (a blocked consumer is not a straggler)
                if len(inflight) < cfg.concurrency or pending:
                    for fut, (fsi, idx, spec, started) in list(
                            inflight.items()):
                        if (spec or not deps_done(fsi)
                                or idx in speculated[fsi]
                                or idx in results[fsi]
                                or attempts[fsi][idx] > 0
                                or idx in cursors[fsi]):
                            continue
                        durs = durations[fsi]
                        if len(durs) < cfg.speculation_min_done:
                            continue
                        med = sorted(durs)[len(durs) // 2]
                        if now - started > (cfg.speculation_factor
                                            * max(med, 0.05)
                                            + start_allowance):
                            speculated[fsi].add(idx)
                            push(fsi, stages[fsi].tasks[idx],
                                 speculative=True)
                for fut in done:
                    si, idx, speculative, started = inflight.pop(fut)
                    resp = fut.result()
                    if "spilled" in resp:
                        resp = pickle.loads(
                            self.lam.rstore.get(resp["spilled"]))
                    if idx in results[si]:
                        dup_dropped[si] += 1  # speculative dup lost the race
                        slots.release()
                        continue
                    if resp.get("status") == "throttled":
                        # 429: never ran, never billed — re-dispatch after
                        # a decorrelated-jitter pause, no attempt charged.
                        # The slot goes back to the pool for the duration
                        # of the pause: a throttled tenant holding slots
                        # it cannot use would starve the others
                        slots.release()
                        self.recovery_stats["throttled"] += 1
                        delayed.append(
                            (time.monotonic()
                             + self._next_dispatch_backoff(),
                             si, stages[si].tasks[idx],
                             cursors[si].get(idx)))
                        continue
                    if resp.get("status") != "ok":
                        # a dead consumer's unacked messages redeliver
                        # after the visibility timeout — retry like any
                        # task; lost durable input triggers lineage
                        # resubmission instead (triage raises if terminal).
                        # The retry carries the failed attempt's slot
                        carry[0] += 1
                        self._on_task_error(stages[si], stages[si].tasks[idx],
                                            resp, attempts[si])
                        push(si, stages[si].tasks[idx],
                             extra=cursors[si].get(idx))
                        continue
                    self._dispatch_sleep = 0.0  # concurrency healthy again
                    self._note_shuffle_stats(stages[si], resp)
                    if "continuation" in resp:
                        # chaining: the producer has NOT emitted EOS yet —
                        # the re-invoked link (or its last successor) will.
                        # The next link carries this one's slot
                        carry[0] += 1
                        chained[si] += 1
                        self._merge_partial(resp, idx, partials[si])
                        cursors[si][idx] = resp["continuation"]
                        links[si][idx] = links[si].get(idx, 1) + 1
                        push(si, stages[si].tasks[idx],
                             extra=dict(resp["continuation"],
                                        _link=links[si][idx]))
                        continue
                    slots.release()
                    durations[si].append(now - started)
                    self._merge_partial(resp, idx, partials[si])
                    results[si][idx] = True
                    self._release_task_partitions(stages[si].tasks[idx])
                    if len(results[si]) == len(stages[si].tasks):
                        finish_stage(si, stages[si])
                launch_ready()
                # carried slots launch_ready could not use this iteration
                # (frontier empty / local pool full) go back to the pool
                while carry[0] > 0:
                    carry[0] -= 1
                    slots.release()
        except BaseException:
            # unblock any consumer still waiting on queues we now know
            # will never complete (fatal failure / elastic re-plan)
            self.sqs.close()
            raise

        # completion order is event order; report in plan order
        self.stage_stats.extend(r for r in stats_rows if r is not None)
        return final_result[0]

    # ------------------------------------------------------------------
    @staticmethod
    def _stage_result(stage: StagePlan, partials: dict) -> Any:
        n = len(stage.tasks)
        if stage.action in ("collect", "sum"):
            out = []
            for i in range(n):
                out.extend(partials.get(i, []))
                if stage.limit is not None and len(out) >= stage.limit:
                    # take(n): the merge short-circuits — later
                    # partitions' results are never consumed
                    return out[:stage.limit]
            return sum(out) if stage.action == "sum" else out
        if stage.action == "save":
            return [f"{stage.save_prefix}/part-{i:05d}" for i in range(n)]
        return None

    @staticmethod
    def _merge_partial(resp, idx, partials):
        if "result" in resp:
            partials.setdefault(idx, []).extend(resp["result"])

    def gc_job(self) -> dict[str, int]:
        """Job-scoped garbage collection (idempotent): every transport
        sweeps its channels (stray queues, the whole ``_exchange/`` tree)
        and the transient object-store prefixes are deleted — content-
        addressed spill keys were never reclaimed before this. Runs inside
        ``shutdown``, i.e. on every query completion or failure; the
        removal counts land in ``gc_report`` so benchmarks/tests can both
        assert zero leaks and see that the GC actually had work to do."""
        with self._lock:
            if self._gc_done:
                return self.gc_report
            self._gc_done = True
        report: dict[str, int] = {}
        if self._binding is None:
            for transport in self.transports.active():
                for resource, n in transport.gc().items():
                    report[resource] = report.get(resource, 0) + n
            for prefix in GC_PREFIXES:
                n = self.store.delete_prefix(prefix)
                if n:
                    report[prefix] = n
        else:
            # SERVICE mode: the store is shared with concurrently-running
            # jobs, so the blanket sweeps above would destroy their live
            # state. Sweep only what this job owns: its own (non-shared)
            # shuffle ids per transport, and its job-scoped payload/result
            # spill prefixes. ``_spill/`` keys are content-addressed and
            # cross-job shareable — the service sweeps them at close
            by_tr: dict[str, list[int]] = {}
            for sid, psi in self._producer_stage_of.items():
                if self._share is not None and self._share.manages(sid):
                    continue  # the share registry owns its lifecycle
                by_tr.setdefault(self._sid_meta[sid][1], []).append(sid)
            for tname, sids in by_tr.items():
                for resource, n in self.transports.get(
                        tname).gc_sids(sids).items():
                    report[resource] = report.get(resource, 0) + n
            for prefix in (f"_payload/{self._scope}",
                           f"_result/{self._scope}"):
                n = self.store.delete_prefix(prefix)
                if n:
                    report[prefix] = n
        # RDD.cache() materializations outlive the job on purpose (they
        # feed later actions) — but only while their token is registered;
        # stale content (cleared caches, elastic re-plans that changed the
        # partition count) is swept here like any other transient key.
        # Keys are listed BEFORE the live set is computed: a concurrent
        # job registers a token at plan time, before its first cache
        # write, so any key this listing sees belongs to a token that is
        # either already registered (kept live) or genuinely dead
        keys = self._retry_transient(self.store.list, "_cache/",
                                     default=())
        live = {f"_cache/{t}/{e['nparts']}/"
                for t, e in self._cache_items()}
        stale = [k for k in keys
                 if not any(k.startswith(p) for p in live)]
        for k in stale:
            self.store.delete(k)
        if stale:
            report["_cache/"] = len(stale)
        self.gc_report = report
        return report

    def _cache_items(self):
        """Snapshot of the cache registry — the service's shared index
        takes its lock for a consistent copy; a plain dict is iterated
        over a list copy for the same reason."""
        index = self._cache_index or {}
        items = getattr(index, "items", None)
        return list(items()) if items else []

    def _retry_transient(self, fn, *args, default=None):
        """GC-time store calls must survive a still-attached chaos
        injector: solo mode detaches its own in ``shutdown`` before GC,
        but the service-wide injector stays attached while other jobs
        are mid-flight. Deletes bypass injection by design; only LIST
        needs this shield. Gives up with ``default`` (a soft leak, swept
        again at service close) rather than failing the job."""
        for i in range(8):
            try:
                return fn(*args)
            except TransientServiceError:
                time.sleep(min(0.25, 0.002 * (2 ** i)))
        return default

    def shutdown(self):
        # detach the chaos layer FIRST: job-end GC must not be failed by
        # injected faults (a real driver retries cleanup indefinitely;
        # modeling it fault-free keeps the zero-leak asserts meaningful),
        # and the service sims may be shared with the next scheduler
        if self.store.faults is self.faults:
            self.store.faults = None
        if self.sqs.faults is self.faults:
            self.sqs.faults = None
        self.lam.faults = None
        self.sqs.close()  # release any consumer blocked on arrival
        if self._share is not None:
            # retire this job's published shuffles and mark its
            # cross-job participations done; the registry destroys each
            # shared shuffle once its owner retired AND every
            # participating job is done with it
            self._share.run_closed(self._job_id,
                                   set(self._producer_stage_of))
        self.gc_job()
        self._slots.detach()
        self.pool.shutdown(wait=False)
