"""FlintScheduler — the serverless SchedulerBackend (paper §III).

Lives on the client, drives one stage at a time:
  * creates the stage's output queues, serializes tasks, launches executors
    asynchronously up to the concurrency cap;
  * processes responses: CONTINUATIONS are re-invoked on warm containers
    (executor chaining), failures retried with the same task identity
    (idempotent via seq-id dedup), STRAGGLERS get a speculative duplicate
    (first completion wins — duplicates are dropped by the same dedup);
  * once all tasks of a stage complete, aggregates per-queue message counts
    and launches the next stage with those expectations; deletes queues
    once consumed.
"""

from __future__ import annotations

import concurrent.futures as cf
import pickle
import threading
import time
from typing import Any

from repro.core.costs import CostLedger
from repro.core.dag import ShuffleRead, StagePlan, TaskDef
from repro.core.executors import (FlintConfig, LambdaSim, queue_name,
                                  serialize_task)
from repro.core.queues import ObjectStoreSim, SQSSim


class StageFailure(RuntimeError):
    def __init__(self, msg, error_type=""):
        super().__init__(msg)
        self.error_type = error_type


class FlintScheduler:
    def __init__(self, cfg: FlintConfig, ledger: CostLedger | None = None,
                 store: ObjectStoreSim | None = None, *,
                 fault_plan: dict | None = None, verbose: bool = False):
        self.cfg = cfg
        self.ledger = ledger or CostLedger()
        self.store = store or ObjectStoreSim(self.ledger)
        self.sqs = SQSSim(self.ledger, duplicate_prob=cfg.duplicate_prob)
        self.lam = LambdaSim(cfg, self.ledger, self.store, self.sqs)
        self.pool = cf.ThreadPoolExecutor(max_workers=cfg.concurrency)
        # fault_plan: {(stage, index): {"fail_attempts": n} | {"straggle_s": s}
        #             | {"fail_after_records": n}}
        self.fault_plan = fault_plan or {}
        self.verbose = verbose
        self.stage_stats: list[dict] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def run(self, stages: list[StagePlan]):
        # expected message counts: shuffle_id -> partition -> src -> count
        expectations: dict[int, dict[int, dict[str, int]]] = {}
        result = None
        for stage in stages:
            if stage.write is not None:
                for p in range(stage.write.nparts):
                    self.sqs.create_queue(queue_name(stage.write.shuffle_id, p))
            result = self._run_stage(stage, expectations)
            # queues consumed by this stage are dead — scheduler cleanup
            for task in stage.tasks[:1]:
                if isinstance(task.input, ShuffleRead):
                    for sid, _ in task.input.parts:
                        for p in range(len(stage.tasks)):
                            self.sqs.delete_queue(queue_name(sid, p))
        return result

    # ------------------------------------------------------------------
    def _payload_for(self, task: TaskDef, stage: StagePlan, attempt: int,
                     expectations, extra: dict | None = None) -> dict:
        extra = dict(extra or {})
        fault = self.fault_plan.get((task.stage_id, task.index), {})
        if fault.get("fail_attempts", 0) > attempt:
            extra["inject_failure"] = True
        if fault.get("straggle_s") and attempt == 0 \
                and not extra.get("_speculative"):
            extra["straggle_s"] = fault["straggle_s"]
        if fault.get("fail_after_records") and attempt == 0:
            extra["fail_after_records"] = fault["fail_after_records"]
        extra.pop("_speculative", None)
        if isinstance(task.input, ShuffleRead):
            exp = {}
            for sid, _ in task.input.parts:
                exp[str(sid)] = expectations.get(sid, {}).get(task.input.partition, {})
            extra["expected"] = exp
        if stage.action == "save" or stage.save_prefix:
            extra["save_prefix"] = stage.save_prefix
        return serialize_task(task, attempt, extra)

    def _run_stage(self, stage: StagePlan, expectations) -> Any:
        t0 = time.monotonic()
        n = len(stage.tasks)
        results: dict[int, Any] = {}
        partials: dict[int, list] = {}
        counts: dict[int, dict[str, int]] = {}
        attempts: dict[int, int] = {i: 0 for i in range(n)}
        durations: list[float] = []
        speculated: set[int] = set()
        inflight: dict[cf.Future, tuple[int, bool, float]] = {}
        dup_dropped = 0
        chained = 0

        def launch(task: TaskDef, extra=None, speculative=False):
            payload = self._payload_for(
                task, stage, attempts[task.index], expectations,
                dict(extra or {}, _speculative=speculative))
            fut = self.pool.submit(self.lam.invoke, payload)
            inflight[fut] = (task.index, speculative, time.monotonic())

        for task in stage.tasks:
            launch(task)

        while inflight:
            done, _ = cf.wait(list(inflight), timeout=0.05,
                              return_when=cf.FIRST_COMPLETED)
            now = time.monotonic()
            # straggler speculation
            if (len(durations) >= self.cfg.speculation_min_done
                    and len(inflight) < self.cfg.concurrency):
                med = sorted(durations)[len(durations) // 2]
                for fut, (idx, spec, started) in list(inflight.items()):
                    if (not spec and idx not in speculated
                            and idx not in results
                            and now - started > self.cfg.speculation_factor
                            * max(med, 0.05)):
                        speculated.add(idx)
                        launch(stage.tasks[idx], speculative=True)
            for fut in done:
                idx, speculative, started = inflight.pop(fut)
                resp = fut.result()
                if "spilled" in resp:
                    resp = pickle.loads(self.store.get(resp["spilled"]))
                if idx in results:
                    dup_dropped += 1  # speculative duplicate lost the race
                    continue
                if resp.get("status") != "ok":
                    if resp.get("error_type") == "MemoryCapExceeded":
                        raise StageFailure(resp.get("error", ""),
                                           error_type="MemoryCapExceeded")
                    attempts[idx] += 1
                    if attempts[idx] > self.cfg.max_task_retries:
                        raise StageFailure(
                            f"task {stage.id}/{idx} failed after "
                            f"{attempts[idx]} attempts: {resp.get('error')}",
                            error_type=resp.get("error_type", ""))
                    launch(stage.tasks[idx])
                    continue
                if "continuation" in resp:
                    # executor chaining: merge partial output, re-invoke warm
                    chained += 1
                    self._merge_partial(resp, idx, partials, counts)
                    launch(stage.tasks[idx], extra=resp["continuation"])
                    continue
                durations.append(resp.get("duration_s", 0.0))
                self._merge_partial(resp, idx, partials, counts)
                results[idx] = True

        # stage complete: fold message counts into expectations
        if stage.write is not None:
            exp = expectations.setdefault(stage.write.shuffle_id, {})
            for idx, per_part in counts.items():
                src = f"s{stage.id}t{idx}"
                for p, c in per_part.items():
                    exp.setdefault(int(p), {})[src] = c

        self.stage_stats.append({
            "stage": stage.id, "tasks": n,
            "wall_s": round(time.monotonic() - t0, 4),
            "attempts": sum(attempts.values()) + n,
            "chained": chained,
            "speculated": len(speculated),
            "spec_dropped": dup_dropped,
        })
        if self.verbose:
            print(f"[flint] stage {stage.id}: {self.stage_stats[-1]}")

        if stage.action in ("collect", "sum"):
            out = []
            for i in range(n):
                out.extend(partials.get(i, []))
            return sum(out) if stage.action == "sum" else out
        if stage.action == "save":
            return [f"{stage.save_prefix}/part-{i:05d}" for i in range(n)]
        return None

    @staticmethod
    def _merge_partial(resp, idx, partials, counts):
        if "result" in resp:
            partials.setdefault(idx, []).extend(resp["result"])
        if "message_counts" in resp:
            cur = counts.setdefault(idx, {})
            for p, c in resp["message_counts"].items():
                cur[p] = cur.get(p, 0) + c

    def shutdown(self):
        self.pool.shutdown(wait=False)
