"""Pay-as-you-go cost model (paper §II, Table I).

2018 AWS price sheet constants (us-east-1), the ones Flint's evaluation
used: Lambda GB-seconds + per-request, SQS per-request (each 64 KiB chunk
of a batch send/receive bills as one request), S3 GET/PUT, and the
m4.2xlarge hourly rate for the cluster baseline (11 instances = driver +
10 workers, 80 vCores).

Everything that moves in the simulated services reports here, so the
benchmark can print Table I's cost columns from actual usage — zero idle
cost by construction.
"""

from __future__ import annotations

import dataclasses
import math
import threading

LAMBDA_GB_SECOND = 0.00001667
LAMBDA_PER_REQUEST = 0.20 / 1e6
LAMBDA_MAX_MEMORY_MB = 3008
LAMBDA_TIME_LIMIT_S = 300.0
LAMBDA_PAYLOAD_LIMIT = 6 * 2**20  # 6 MB request payload cap

SQS_PER_REQUEST = 0.40 / 1e6
SQS_BILLING_CHUNK = 64 * 2**10  # every 64 KiB of a request bills separately
SQS_MESSAGE_LIMIT = 256 * 2**10
SQS_BATCH_MESSAGES = 10

S3_PER_GET = 0.0004 / 1e3
S3_PER_PUT = 0.005 / 1e3
# LIST bills at the PUT tier (it is a "LIST request" on the 2018 sheet);
# DELETE is free but counted, because a job-scoped GC that issued millions
# of them would still matter operationally.
S3_PER_LIST = 0.005 / 1e3
# Objects above the threshold upload as multipart: one CreateMultipartUpload
# + ceil(size/part) UploadPart + one CompleteMultipartUpload, each billed at
# the PUT tier. The S3 exchange shuffle is the only writer big enough.
S3_MULTIPART_THRESHOLD = 8 * 2**20
S3_MULTIPART_PART_SIZE = 8 * 2**20
# One S3-exchange batch object may be far larger than an SQS message — the
# whole point of an object-store shuffle (Lambada §4: few large objects
# instead of many tiny requests).
S3_EXCHANGE_BATCH_LIMIT = 64 * 2**20

M4_2XLARGE_HOURLY = 0.40
CLUSTER_INSTANCES = 11  # 1 driver + 10 workers (paper's Databricks cluster)

# ---------------------------------------------- adaptive transport choice
#
# Plan-time defaults for estimating how many bytes a shuffle will move
# (the planner has no statistics beyond source object sizes, so these are
# the textbook selectivity constants):
EST_FILTER_SELECTIVITY = 0.5   # each filter() halves the stream
EST_AGG_OUTPUT_FACTOR = 0.3    # aggregation output vs its input


def shuffle_transport_costs(est_bytes: float, n_producers: int,
                            nparts: int) -> dict:
    """Modeled USD for moving ``est_bytes`` of shuffle data through each
    transport, from the same price constants the ledger bills with.

    SQS bills every 64 KiB chunk on BOTH sides (send + receive) plus one
    send/receive pair per (producer, partition) channel for EOS control
    messages. The S3 exchange writes roughly one object per channel (plus
    one manifest per producer), reads each object once, and pays a few
    LISTs per partition for discovery — so its cost is per-REQUEST, not
    per-byte, which is exactly why large shuffles want it (Lambada §4)
    and tiny ones do not."""
    channels = max(1, n_producers * nparts)
    sqs_chunks = est_bytes / SQS_BILLING_CHUNK + channels  # data + EOS
    sqs = 2 * sqs_chunks * SQS_PER_REQUEST  # send + receive
    s3 = ((channels + n_producers) * S3_PER_PUT
          + channels * S3_PER_GET
          + 2 * nparts * S3_PER_LIST)
    return {"sqs": sqs, "s3": s3}


def pick_shuffle_transport(est_bytes: float, n_producers: int,
                           nparts: int) -> str:
    """The planner's per-shuffle choice when no hint or engine override
    pins one (FlintConfig.shuffle_backend == "auto")."""
    costs = shuffle_transport_costs(est_bytes, n_producers, nparts)
    return "s3" if costs["s3"] < costs["sqs"] else "sqs"


def broadcast_join_cost(small_bytes: float, n_readers: int) -> float:
    """Modeled USD for shipping a measured small join side as a
    content-addressed broadcast object: the driver drains it once (the
    GETs are already paid by the shuffle it replaces), PUTs ~one object
    (+ manifest), and every map task of the large side LISTs + GETs it
    back. Per-reader cost is a couple of requests — no per-byte shuffle
    chunking on either side."""
    n_objects = max(1, math.ceil(small_bytes / S3_EXCHANGE_BATCH_LIMIT))
    return ((n_objects + 1) * S3_PER_PUT
            + n_readers * (S3_PER_LIST + n_objects * S3_PER_GET))


def pick_join_strategy(small_bytes: float, large_bytes: float,
                       n_producers: int, nparts: int, n_readers: int,
                       threshold_bytes: int) -> str:
    """The adaptive scheduler's runtime join choice, from MEASURED sizes:
    "broadcast" when the small side fits the configured threshold AND the
    modeled broadcast cost undercuts shuffling BOTH sides; else
    "shuffle". The threshold is the memory guard (every map task holds
    the whole build side); the cost comparison is what keeps a small
    side with thousands of readers on the shuffle path."""
    if small_bytes > threshold_bytes:
        return "shuffle"
    shuffle_cost = min(shuffle_transport_costs(
        small_bytes + large_bytes, n_producers, nparts).values())
    return ("broadcast"
            if broadcast_join_cost(small_bytes, n_readers) < shuffle_cost
            else "shuffle")


def cluster_cost(wall_seconds: float, instances: int = CLUSTER_INSTANCES) -> float:
    """Per-second billing of a provisioned cluster — accrues while idle,
    which is exactly what the paper's pay-as-you-go goal removes."""
    return wall_seconds * instances * M4_2XLARGE_HOURLY / 3600.0


def sqs_request_units(payload_bytes: int) -> int:
    return max(1, math.ceil(payload_bytes / SQS_BILLING_CHUNK))


@dataclasses.dataclass
class CostLedger:
    """Thread-safe usage accumulator shared by the simulated services.

    ``child()`` creates a TENANT-SCOPED sub-ledger: everything billed to
    the child is billed to this (parent) ledger too, so a multi-tenant
    service can show each tenant its own bill while the root ledger stays
    the account-wide total (docs/multi_tenant.md). Chaining is one level
    deep in practice but composes to any depth."""

    lambda_gb_seconds: float = 0.0
    lambda_requests: int = 0
    sqs_requests: int = 0
    s3_gets: int = 0
    s3_puts: int = 0
    s3_lists: int = 0
    s3_upload_parts: int = 0
    s3_deletes: int = 0
    bytes_to_sqs: int = 0
    bytes_from_sqs: int = 0
    bytes_from_s3: int = 0
    bytes_to_s3: int = 0
    # chaos bookkeeping: injected 5xx are NOT billed (AWS doesn't bill
    # server errors) but the retries they force are — each retried call
    # re-bills above. 429s never reach a container, so no GB-seconds.
    service_faults: int = 0
    lambda_throttles: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()
        self._parent: "CostLedger | None" = None

    def child(self) -> "CostLedger":
        """A sub-ledger whose every charge also lands here. The service
        layer hands one to each tenant so per-tenant cost reports and
        dollar quotas come from real metered usage, not attribution
        heuristics."""
        c = CostLedger()
        c._parent = self
        return c

    def add_lambda(self, duration_s: float, memory_mb: int):
        with self._lock:
            self.lambda_requests += 1
            # AWS billed per 100ms slices in 2018
            slices = math.ceil(duration_s / 0.1)
            self.lambda_gb_seconds += slices * 0.1 * (memory_mb / 1024.0)
        if self._parent is not None:
            self._parent.add_lambda(duration_s, memory_mb)

    def add_sqs(self, payload_bytes: int, receive: bool = False):
        with self._lock:
            self.sqs_requests += sqs_request_units(payload_bytes)
            if receive:
                self.bytes_from_sqs += payload_bytes
            else:
                self.bytes_to_sqs += payload_bytes
        if self._parent is not None:
            self._parent.add_sqs(payload_bytes, receive)

    def add_sqs_control(self):
        """Queue create/delete/empty-receive — one billable request."""
        with self._lock:
            self.sqs_requests += 1
        if self._parent is not None:
            self._parent.add_sqs_control()

    def add_s3(self, nbytes: int, put: bool = False):
        if put:
            self.add_s3_put(nbytes)
        else:
            with self._lock:
                self.s3_gets += 1
                self.bytes_from_s3 += nbytes
            if self._parent is not None:
                self._parent.add_s3(nbytes)

    def add_s3_put(self, nbytes: int):
        """A PUT; above the multipart threshold it bills as a multipart
        upload instead: Create + per-part UploadPart + Complete, each a
        PUT-tier request."""
        with self._lock:
            self.bytes_to_s3 += nbytes
            if nbytes > S3_MULTIPART_THRESHOLD:
                self.s3_puts += 2  # CreateMultipartUpload + Complete
                self.s3_upload_parts += math.ceil(
                    nbytes / S3_MULTIPART_PART_SIZE)
            else:
                self.s3_puts += 1
        if self._parent is not None:
            self._parent.add_s3_put(nbytes)

    def add_s3_list(self):
        with self._lock:
            self.s3_lists += 1
        if self._parent is not None:
            self._parent.add_s3_list()

    def add_s3_delete(self):
        """DELETE requests are free on the price sheet; counted anyway."""
        with self._lock:
            self.s3_deletes += 1
        if self._parent is not None:
            self._parent.add_s3_delete()

    def add_service_fault(self):
        """An injected transient service error (unbilled, counted)."""
        with self._lock:
            self.service_faults += 1
        if self._parent is not None:
            self._parent.add_service_fault()

    def add_lambda_throttle(self):
        """A 429-rejected invocation: no container, no GB-seconds."""
        with self._lock:
            self.lambda_throttles += 1
        if self._parent is not None:
            self._parent.add_lambda_throttle()

    # ------------------------------------------------------------- report
    @property
    def lambda_usd(self) -> float:
        return (self.lambda_gb_seconds * LAMBDA_GB_SECOND
                + self.lambda_requests * LAMBDA_PER_REQUEST)

    @property
    def sqs_usd(self) -> float:
        return self.sqs_requests * SQS_PER_REQUEST

    @property
    def s3_usd(self) -> float:
        return (self.s3_gets * S3_PER_GET
                + (self.s3_puts + self.s3_upload_parts) * S3_PER_PUT
                + self.s3_lists * S3_PER_LIST)

    @property
    def total_usd(self) -> float:
        return self.lambda_usd + self.sqs_usd + self.s3_usd

    def service_subtotals(self) -> dict:
        """Per-service / per-operation USD — the Table-I-style breakdown the
        shuffle benchmark prints per transport."""
        return {
            "lambda": round(self.lambda_usd, 6),
            "sqs": round(self.sqs_usd, 6),
            "s3.GET": round(self.s3_gets * S3_PER_GET, 6),
            "s3.PUT": round(self.s3_puts * S3_PER_PUT, 6),
            "s3.UploadPart": round(self.s3_upload_parts * S3_PER_PUT, 6),
            "s3.LIST": round(self.s3_lists * S3_PER_LIST, 6),
        }

    def report(self) -> dict:
        # snapshot under the lock: concurrent jobs bill from many threads
        # and a torn read here would misreport a live tenant's totals
        with self._lock:
            return self._report_locked()

    def _report_locked(self) -> dict:
        return {
            "lambda_usd": round(self.lambda_usd, 6),
            "sqs_usd": round(self.sqs_usd, 6),
            "s3_usd": round(self.s3_usd, 6),
            "total_usd": round(self.total_usd, 6),
            "lambda_gb_seconds": round(self.lambda_gb_seconds, 3),
            "lambda_requests": self.lambda_requests,
            "sqs_requests": self.sqs_requests,
            "s3_gets": self.s3_gets,
            "s3_puts": self.s3_puts,
            "s3_lists": self.s3_lists,
            "s3_upload_parts": self.s3_upload_parts,
            "s3_deletes": self.s3_deletes,
            "bytes_to_sqs": self.bytes_to_sqs,
            "bytes_from_sqs": self.bytes_from_sqs,
            "bytes_to_s3": self.bytes_to_s3,
            "bytes_from_s3": self.bytes_from_s3,
            "service_faults": self.service_faults,
            "lambda_throttles": self.lambda_throttles,
        }
