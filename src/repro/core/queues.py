"""Simulated state-as-a-service backends: SQS (queue shuffle) and S3
(object store) — semantics matched to the paper's execution environment.

SQSSim reproduces what matters for Flint's correctness story:
  * batched sends (<=10 messages, <=256 KiB each), billing per 64 KiB chunk;
  * AT-LEAST-ONCE delivery: a seeded duplicator re-delivers a configurable
    fraction of messages (paper §VI flags this; core.dedup handles it);
  * no ordering guarantees (receive shuffles within the visible set).

ObjectStoreSim is the S3 stand-in: ranged GETs over byte blobs for input
splits, PUT/GET for the Qubole-style object-store shuffle (paper §V) and
for the >6 MB payload spill (paper §III-B).
"""

from __future__ import annotations

import pickle
import random
import threading
from collections import defaultdict, deque
from typing import Any, Iterable

from repro.core.costs import (SQS_BATCH_MESSAGES, SQS_MESSAGE_LIMIT,
                              CostLedger)


class Message:
    __slots__ = ("body", "seq", "src")

    def __init__(self, body: bytes, seq: int, src: str):
        self.body = body
        self.seq = seq
        self.src = src


class SQSSim:
    """In-process SQS with at-least-once semantics and per-request billing."""

    def __init__(self, ledger: CostLedger, *, duplicate_prob: float = 0.0,
                 seed: int = 0):
        self.ledger = ledger
        self.duplicate_prob = duplicate_prob
        self._rng = random.Random(seed)
        self._queues: dict[str, deque[Message]] = defaultdict(deque)
        self._lock = threading.Lock()

    def create_queue(self, name: str):
        with self._lock:
            self._queues.setdefault(name, deque())
        self.ledger.add_sqs_control()

    def delete_queue(self, name: str):
        with self._lock:
            self._queues.pop(name, None)
        self.ledger.add_sqs_control()

    def send_batch(self, name: str, messages: list[Message]):
        if len(messages) > SQS_BATCH_MESSAGES:
            raise ValueError("SQS batch send limited to 10 messages")
        payload = 0
        for m in messages:
            if len(m.body) > SQS_MESSAGE_LIMIT:
                raise ValueError("SQS message exceeds 256 KiB")
            payload += len(m.body)
        self.ledger.add_sqs(payload)
        with self._lock:
            q = self._queues[name]
            for m in messages:
                q.append(m)
                # at-least-once: occasionally deliver a duplicate
                if self._rng.random() < self.duplicate_prob:
                    q.append(Message(m.body, m.seq, m.src))

    def receive_batch(self, name: str, max_messages: int = SQS_BATCH_MESSAGES
                      ) -> list[Message]:
        with self._lock:
            q = self._queues.get(name)
            out = []
            if q:
                # no ordering guarantee: rotate by a random offset
                k = min(max_messages, len(q))
                if len(q) > k and self._rng.random() < 0.5:
                    q.rotate(-self._rng.randrange(len(q) - k + 1))
                for _ in range(k):
                    out.append(q.popleft())
        payload = sum(len(m.body) for m in out)
        self.ledger.add_sqs(max(payload, 1), receive=True)
        return out

    def approx_len(self, name: str) -> int:
        with self._lock:
            return len(self._queues.get(name, ()))


class ObjectStoreSim:
    """S3 stand-in: named byte blobs with ranged reads and listing."""

    def __init__(self, ledger: CostLedger):
        self.ledger = ledger
        self._objects: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes):
        with self._lock:
            self._objects[key] = bytes(data)
        self.ledger.add_s3(len(data), put=True)

    def get(self, key: str, start: int = 0, end: int | None = None) -> bytes:
        with self._lock:
            data = self._objects[key]
        out = data[start:end]
        self.ledger.add_s3(len(out))
        return out

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._objects[key])

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def list(self, prefix: str) -> list[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key: str):
        with self._lock:
            self._objects.pop(key, None)

    # convenience for pickled python values (payload spill, shuffle blobs)
    def put_obj(self, key: str, value: Any):
        self.put(key, pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))

    def get_obj(self, key: str) -> Any:
        return pickle.loads(self.get(key))


def pack_records(records: Iterable[Any], limit: int = SQS_MESSAGE_LIMIT
                 ) -> list[bytes]:
    """Greedily pack records into pickled message bodies under the 256 KiB
    SQS cap. Returns a list of message bodies."""
    bodies: list[bytes] = []
    buf: list[Any] = []
    size = 64  # pickle overhead headroom
    for r in records:
        est = len(pickle.dumps(r, protocol=pickle.HIGHEST_PROTOCOL))
        if buf and size + est > limit:
            bodies.append(pickle.dumps(buf, protocol=pickle.HIGHEST_PROTOCOL))
            buf, size = [], 64
        buf.append(r)
        size += est
    if buf:
        bodies.append(pickle.dumps(buf, protocol=pickle.HIGHEST_PROTOCOL))
    return bodies


def unpack_records(body: bytes) -> list[Any]:
    return pickle.loads(body)
