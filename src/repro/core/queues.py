"""Simulated state-as-a-service backends: SQS (queue shuffle) and S3
(object store) — semantics matched to the paper's execution environment.

SQSSim reproduces what matters for Flint's correctness story:
  * batched sends (<=10 messages, <=256 KiB each), billing per 64 KiB chunk;
  * AT-LEAST-ONCE delivery: a seeded duplicator re-delivers a configurable
    fraction of messages (paper §VI flags this; core.dedup handles it);
  * no ordering guarantees (receive shuffles within the visible set);
  * two message kinds: "data" (packed record batches) and "eos" — the
    per-producer end-of-stream control message that lets consumers start
    draining BEFORE their producers finish (pipelined stage execution).
    An EOS message carries the producer's total sequence count in ``seq``;
  * a condition variable on arrival, so consumers block instead of
    sleep-spinning while their producers are still computing.

ObjectStoreSim is the S3 stand-in: ranged GETs over byte blobs for input
splits, PUT/GET for the Qubole-style object-store shuffle (paper §V) and
for the >6 MB payload spill (paper §III-B).
"""

from __future__ import annotations

import pickle
import random
import struct
import threading
from collections import deque
from typing import Any, Iterable

from repro.core.costs import (SQS_BATCH_MESSAGES, SQS_MESSAGE_LIMIT,
                              CostLedger)


class Message:
    __slots__ = ("body", "seq", "src", "kind")

    def __init__(self, body: bytes, seq: int, src: str, kind: str = "data"):
        self.body = body
        self.seq = seq
        self.src = src
        self.kind = kind


def eos_message(src: str, total: int) -> Message:
    """End-of-stream control message: ``total`` is the number of data
    messages (sequence ids 0..total-1) this producer sent to the queue."""
    return Message(b"", total, src, kind="eos")


class SQSSim:
    """In-process SQS with at-least-once semantics and per-request billing."""

    def __init__(self, ledger: CostLedger, *, duplicate_prob: float = 0.0,
                 seed: int = 0):
        self.ledger = ledger
        self.duplicate_prob = duplicate_prob
        self._rng = random.Random(seed)
        self._queues: dict[str, deque[Message]] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        """Release every blocked consumer (scheduler shutdown/abort)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def create_queue(self, name: str):
        with self._cond:
            self._queues.setdefault(name, deque())
        self.ledger.add_sqs_control()

    def delete_queue(self, name: str):
        with self._cond:
            self._queues.pop(name, None)
        self.ledger.add_sqs_control()

    def send_batch(self, name: str, messages: list[Message]):
        if len(messages) > SQS_BATCH_MESSAGES:
            raise ValueError("SQS batch send limited to 10 messages")
        payload = 0
        for m in messages:
            if len(m.body) > SQS_MESSAGE_LIMIT:
                raise ValueError("SQS message exceeds 256 KiB")
            payload += len(m.body)
        self.ledger.add_sqs(payload)  # a rejected send still bills
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                # queue was deleted (e.g. a losing speculative duplicate
                # still flushing after its stage completed) — like real
                # SQS's QueueDoesNotExist, the send goes nowhere; it must
                # NOT resurrect the queue and strand messages
                return
            for m in messages:
                q.append(m)
                # at-least-once: occasionally deliver a duplicate
                if self._rng.random() < self.duplicate_prob:
                    q.append(Message(m.body, m.seq, m.src, m.kind))
            self._cond.notify_all()

    def receive_batch(self, name: str, max_messages: int = SQS_BATCH_MESSAGES
                      ) -> list[Message]:
        with self._cond:
            q = self._queues.get(name)
            out = []
            if q:
                # no ordering guarantee: rotate by a random offset
                k = min(max_messages, len(q))
                if len(q) > k and self._rng.random() < 0.5:
                    q.rotate(-self._rng.randrange(len(q) - k + 1))
                for _ in range(k):
                    out.append(q.popleft())
        payload = sum(len(m.body) for m in out)
        self.ledger.add_sqs(max(payload, 1), receive=True)
        return out

    def receive_many(self, name: str, max_messages: int = 100
                     ) -> list[Message]:
        """Drain up to ``max_messages`` in one scheduler step. Physically
        this is ceil(n/10) batch-receive API calls, and it bills as such."""
        with self._cond:
            q = self._queues.get(name)
            out = []
            if q:
                k = min(max_messages, len(q))
                if len(q) > k and self._rng.random() < 0.5:
                    q.rotate(-self._rng.randrange(len(q) - k + 1))
                for _ in range(k):
                    out.append(q.popleft())
        if not out:
            self.ledger.add_sqs(1, receive=True)  # one empty receive
            return out
        for i in range(0, len(out), SQS_BATCH_MESSAGES):
            chunk = out[i:i + SQS_BATCH_MESSAGES]
            payload = sum(len(m.body) for m in chunk)
            self.ledger.add_sqs(max(payload, 1), receive=True)
        return out

    def wait_for_messages(self, name: str, timeout: float) -> bool:
        """Block until the queue is non-empty (or the sim is closed).
        Long polling: waiting itself is not a billable request."""
        with self._cond:
            return self._cond.wait_for(
                lambda: self._closed or bool(self._queues.get(name)),
                timeout)

    def approx_len(self, name: str) -> int:
        with self._lock:
            return len(self._queues.get(name, ()))


class ObjectStoreSim:
    """S3 stand-in: named byte blobs with ranged reads and listing."""

    def __init__(self, ledger: CostLedger):
        self.ledger = ledger
        self._objects: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes):
        with self._lock:
            self._objects[key] = bytes(data)
        self.ledger.add_s3(len(data), put=True)

    def get(self, key: str, start: int = 0, end: int | None = None) -> bytes:
        with self._lock:
            data = self._objects[key]
        out = data[start:end]
        self.ledger.add_s3(len(out))
        return out

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._objects[key])

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def list(self, prefix: str) -> list[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key: str):
        with self._lock:
            self._objects.pop(key, None)

    # convenience for pickled python values (payload spill, shuffle blobs)
    def put_obj(self, key: str, value: Any):
        self.put(key, pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))

    def get_obj(self, key: str) -> Any:
        return pickle.loads(self.get(key))


_FRAME = struct.Struct("<I")  # 4-byte little-endian record-length prefix


def pack_records(records: Iterable[Any], limit: int = SQS_MESSAGE_LIMIT
                 ) -> list[bytes]:
    """Pack records into length-prefixed message bodies under the 256 KiB
    SQS cap, pickling each record EXACTLY once (single-pass incremental
    framing — the old implementation pickled twice: once to estimate the
    size, once inside the batch pickle)."""
    bodies: list[bytes] = []
    frames: list[bytes] = []
    size = 0
    for r in records:
        blob = pickle.dumps(r, protocol=pickle.HIGHEST_PROTOCOL)
        need = _FRAME.size + len(blob)
        if frames and size + need > limit:
            bodies.append(b"".join(frames))
            frames, size = [], 0
        frames.append(_FRAME.pack(len(blob)))
        frames.append(blob)
        size += need
    if frames:
        bodies.append(b"".join(frames))
    return bodies


def unpack_records(body: bytes) -> list[Any]:
    out = []
    off, n = 0, len(body)
    while off < n:
        (ln,) = _FRAME.unpack_from(body, off)
        off += _FRAME.size
        out.append(pickle.loads(body[off:off + ln]))
        off += ln
    return out
