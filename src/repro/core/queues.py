"""Simulated state-as-a-service backends: SQS (queue shuffle) and S3
(object store) — semantics matched to the paper's execution environment.

SQSSim reproduces what matters for Flint's correctness story:
  * batched sends (<=10 messages, <=256 KiB each), billing per 64 KiB chunk;
  * AT-LEAST-ONCE delivery: a seeded duplicator re-delivers a configurable
    fraction of messages (paper §VI flags this; core.dedup handles it);
  * no ordering guarantees (receive shuffles within the visible set);
  * VISIBILITY-TIMEOUT receives: a receive does not pop a message — it
    moves it to a per-queue in-flight set under a fresh receipt handle and
    a visibility deadline. ``delete_batch`` (the ack) removes in-flight
    messages for good; ``change_visibility`` extends a consumer's claim
    (the heartbeat). A lazy sweep returns expired in-flight messages to
    the visible set, where their redelivery bills as a fresh receive —
    so a consumer that dies without acking leaves everything it read to
    reappear for its retry (paper §III/§VI: "retry with the same
    identity"), and two competing drains merely race on acks instead of
    destructively splitting a queue;
  * three message kinds: "data" (packed record batches), "eos" — the
    per-producer end-of-stream control message that lets consumers start
    draining BEFORE their producers finish (pipelined stage execution);
    an EOS message carries the producer's total sequence count in ``seq``
    — and "wmark", the streaming generalization of EOS: where EOS closes
    a finite stream at a plan-time quorum, a watermark message closes an
    event-time WINDOW of an unbounded stream, carrying the max event
    time a producer (micro-batch) has observed (repro.streaming,
    docs/streaming.md);
  * a condition variable on arrival, so consumers block instead of
    sleep-spinning while their producers are still computing.

ObjectStoreSim is the S3 stand-in: ranged GETs over byte blobs for input
splits, PUT/GET/LIST (with multipart-aware billing) for the Lambada-style
exchange shuffle (core.shuffle.s3), the >6 MB payload spill (paper
§III-B), and the >256 KiB record spill (SpillPointer messages).

The shuffle data plane itself — transport selection, drain protocol,
batch framing — lives in core.shuffle; this module only simulates the
services. pack_records/unpack_records remain here as the length-prefixed
pickle framing that core.shuffle.batch falls back to for ragged data.
"""

from __future__ import annotations

import itertools
import pickle
import random
import struct
import threading
import time
from collections import deque
from typing import Any, Callable, Iterable

from repro.core.costs import (SQS_BATCH_MESSAGES, SQS_MESSAGE_LIMIT,
                              CostLedger)


class QueueGone(RuntimeError):
    """Receive from a deleted queue — like SQS's QueueDoesNotExist. Raised
    so a losing speculative consumer aborts the moment the winner's
    completion deletes the queue, instead of waiting out the drain
    timeout."""


class Message:
    __slots__ = ("body", "seq", "src", "kind", "receipt")

    def __init__(self, body: bytes, seq: int, src: str, kind: str = "data"):
        self.body = body
        self.seq = seq
        self.src = src
        self.kind = kind
        self.receipt = None  # set per receive; a redelivery gets a new one


def eos_message(src: str, total: int) -> Message:
    """End-of-stream control message: ``total`` is the number of data
    messages (sequence ids 0..total-1) this producer sent to the queue."""
    return Message(b"", total, src, kind="eos")


def watermark_message(src: str, ts: float, batch: int = 0) -> Message:
    """Event-time watermark control message — the streaming sibling of
    ``eos_message``. ``src`` identifies the emitting micro-batch/source,
    ``ts`` is the maximum event time it has observed (packed in ``body``,
    read back with ``watermark_ts``), ``batch`` rides in ``seq``. The
    micro-batch driver folds these monotonically and closes every window
    whose end the folded watermark has passed (docs/streaming.md); a
    drained finite stream is signalled with ``ts=float("inf")``, which
    degenerates to EOS — every window closes."""
    return Message(struct.pack("<d", float(ts)), batch, src, kind="wmark")


def watermark_ts(msg: Message) -> float:
    """The event-time carried by a ``watermark_message``."""
    if msg.kind != "wmark":
        raise ValueError(f"not a watermark message (kind={msg.kind!r})")
    return struct.unpack("<d", msg.body)[0]


class _QueueState:
    __slots__ = ("visible", "inflight", "delayed")

    def __init__(self):
        self.visible: deque[Message] = deque()
        self.inflight: dict[int, tuple[Message, float]] = {}  # receipt ->
        #                                           (message, visibility deadline)
        # injected delivery delay: (deliver_at, message), moved to visible
        # by the lazy sweep — SQS makes no latency promise
        self.delayed: list[tuple[float, Message]] = []


class SQSSim:
    """In-process SQS with at-least-once + visibility-timeout semantics and
    per-request billing."""

    def __init__(self, ledger: CostLedger, *, duplicate_prob: float = 0.0,
                 seed: int = 0, visibility_timeout: float = 30.0):
        self.ledger = ledger
        self.duplicate_prob = duplicate_prob
        self.visibility_timeout = visibility_timeout
        self._rng = random.Random(seed)
        self._queues: dict[str, _QueueState] = {}
        self._receipts = itertools.count(1)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False
        self.redeliveries = 0  # expired in-flight messages returned visible
        # chaos hook: a FaultInjector installed by the scheduler for the
        # duration of a run; consulted on every data-plane call
        self.faults = None

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        """Release every blocked consumer (scheduler shutdown/abort)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def create_queue(self, name: str):
        with self._cond:
            self._queues.setdefault(name, _QueueState())
        self.ledger.add_sqs_control()

    def delete_queue(self, name: str):
        with self._cond:
            self._queues.pop(name, None)
            # a consumer blocked in wait_for_messages must wake and observe
            # QueueGone on its next receive
            self._cond.notify_all()
        self.ledger.add_sqs_control()

    def _sweep(self, q: _QueueState):
        """Lazy redelivery: return expired in-flight messages to the
        visible set (their next receive bills fresh), and surface delayed
        deliveries whose time has come. Caller holds lock."""
        now = time.monotonic()
        if q.delayed:
            due = [m for t, m in q.delayed if t <= now]
            if due:
                q.delayed = [(t, m) for t, m in q.delayed if t > now]
                q.visible.extend(due)
                self._cond.notify_all()
        if not q.inflight:
            return
        expired = [r for r, (_, dl) in q.inflight.items() if dl <= now]
        for r in expired:
            msg, _ = q.inflight.pop(r)
            msg.receipt = None  # the old receipt handle is now stale
            q.visible.append(msg)
        if expired:
            self.redeliveries += len(expired)
            self._cond.notify_all()

    def send_batch(self, name: str, messages: list[Message]):
        if len(messages) > SQS_BATCH_MESSAGES:
            raise ValueError("SQS batch send limited to 10 messages")
        payload = 0
        for m in messages:
            if len(m.body) > SQS_MESSAGE_LIMIT:
                raise ValueError("SQS message exceeds 256 KiB")
            payload += len(m.body)
        inj = self.faults
        delay = 0.0
        if inj is not None:
            # an injected 5xx fails the request before anything is
            # enqueued or billed (AWS does not bill server errors)
            inj.sqs_call("send", name)
            delay = inj.delivery_delay(name)
        self.ledger.add_sqs(payload)  # a rejected send still bills
        deliver_at = time.monotonic() + delay if delay else 0.0
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                # queue was deleted (e.g. a losing speculative duplicate
                # still flushing after its stage completed) — like real
                # SQS's QueueDoesNotExist, the send goes nowhere; it must
                # NOT resurrect the queue and strand messages
                return
            for m in messages:
                if deliver_at:
                    q.delayed.append((deliver_at, m))
                else:
                    q.visible.append(m)
                # at-least-once: occasionally deliver a duplicate
                if self._rng.random() < self.duplicate_prob:
                    dup = Message(m.body, m.seq, m.src, m.kind)
                    if deliver_at:
                        q.delayed.append((deliver_at, dup))
                    else:
                        q.visible.append(dup)
            self._cond.notify_all()

    def _take_visible(self, q: _QueueState, max_messages: int
                      ) -> list[Message]:
        """Move up to ``max_messages`` from visible to in-flight under
        fresh receipt handles. Caller holds lock."""
        self._sweep(q)
        out: list[Message] = []
        vis = q.visible
        k = min(max_messages, len(vis))
        if k:
            # no ordering guarantee: rotate by a random offset
            if len(vis) > k and self._rng.random() < 0.5:
                vis.rotate(-self._rng.randrange(len(vis) - k + 1))
            deadline = time.monotonic() + self.visibility_timeout
            for _ in range(k):
                m = vis.popleft()
                m.receipt = next(self._receipts)
                q.inflight[m.receipt] = (m, deadline)
                out.append(m)
        return out

    def receive_batch(self, name: str, max_messages: int = SQS_BATCH_MESSAGES
                      ) -> list[Message]:
        """One batch-receive API call (<=10 messages)."""
        return self.receive_many(name, min(max_messages, SQS_BATCH_MESSAGES))

    def receive_many(self, name: str, max_messages: int = 100
                     ) -> list[Message]:
        """Drain up to ``max_messages`` in one scheduler step. Physically
        this is ceil(n/10) batch-receive API calls, and it bills as such."""
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                raise QueueGone(name)
            if self.faults is not None:
                # transient receive error: fails the request before any
                # message is claimed, and before billing
                self.faults.sqs_call("receive", name)
            out = self._take_visible(q, max_messages)
        if not out:
            self.ledger.add_sqs(1, receive=True)  # one empty receive
            return out
        for i in range(0, len(out), SQS_BATCH_MESSAGES):
            chunk = out[i:i + SQS_BATCH_MESSAGES]
            payload = sum(len(m.body) for m in chunk)
            self.ledger.add_sqs(max(payload, 1), receive=True)
        return out

    def delete_batch(self, name: str, receipts: list[int]):
        """Ack: remove in-flight messages for good. Stale receipts (already
        acked, or expired and redelivered under a new handle) and deleted
        queues are no-ops, so duplicate acks from racing attempts are
        idempotent."""
        if len(receipts) > SQS_BATCH_MESSAGES:
            raise ValueError("SQS batch delete limited to 10 receipts")
        self.ledger.add_sqs_control()
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                return
            for r in receipts:
                q.inflight.pop(r, None)

    def change_visibility(self, name: str, receipts: list[int],
                          timeout: float):
        """Heartbeat: extend the visibility deadline of held messages so a
        long fold does not leak them to a rival mid-task. Stale receipts
        are no-ops."""
        if len(receipts) > SQS_BATCH_MESSAGES:
            raise ValueError("SQS visibility batch limited to 10 receipts")
        self.ledger.add_sqs_control()
        deadline = time.monotonic() + timeout
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                return
            for r in receipts:
                entry = q.inflight.get(r)
                if entry is not None:
                    q.inflight[r] = (entry[0], deadline)

    def wait_for_messages(self, name: str, timeout: float) -> bool:
        """Block until the queue has a visible message, the queue is gone,
        or the sim is closed. Long polling: waiting itself is not a
        billable request."""
        def ready() -> bool:
            if self._closed:
                return True
            q = self._queues.get(name)
            if q is None:
                return True  # wake: the next receive raises QueueGone
            self._sweep(q)
            return bool(q.visible)

        with self._cond:
            return self._cond.wait_for(ready, timeout)

    def approx_len(self, name: str) -> int:
        """Visible-message backlog estimate (SQS's
        ApproximateNumberOfMessages — in-flight messages excluded). A
        GetQueueAttributes call, so it bills like any other request."""
        self.ledger.add_sqs_control()
        with self._cond:
            q = self._queues.get(name)
            if q is None:
                return 0
            self._sweep(q)
            return len(q.visible)

    def inflight_len(self, name: str) -> int:
        with self._cond:
            q = self._queues.get(name)
            return len(q.inflight) if q is not None else 0


class ObjectStoreSim:
    """S3 stand-in: named byte blobs with ranged reads and listing.

    Billing matches the request it models: a put above the multipart
    threshold bills as Create + UploadParts + Complete, every ``list`` is a
    LIST request (the recurring cost of the S3-exchange shuffle's polling
    discovery), and deletes are free but counted."""

    def __init__(self, ledger: CostLedger):
        self.ledger = ledger
        self._objects: dict[str, bytes] = {}
        self._lock = threading.Lock()
        # chaos hook: a FaultInjector installed by the scheduler for the
        # duration of a run; consulted on the billable data-plane calls
        # (PUT/GET/LIST — never on deletes or metadata, so GC stays clean)
        self.faults = None

    def put(self, key: str, data: bytes):
        inj = self.faults
        if inj is not None:
            inj.s3_call("put", key)  # 5xx: nothing stored, nothing billed
        with self._lock:
            self._objects[key] = bytes(data)
        self.ledger.add_s3_put(len(data))
        if inj is not None and inj.object_written(key):
            # the durability fault: the write was ACKNOWLEDGED (billed,
            # caller saw success) and the object silently vanishes
            with self._lock:
                self._objects.pop(key, None)

    def get(self, key: str, start: int = 0, end: int | None = None) -> bytes:
        inj = self.faults
        if inj is not None:
            inj.s3_call("get", key)
        with self._lock:
            data = self._objects[key]
        out = data[start:end]
        self.ledger.add_s3(len(out))
        return out

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._objects[key])

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def list(self, prefix: str) -> list[str]:
        if self.faults is not None:
            self.faults.s3_call("list", prefix)
        self.ledger.add_s3_list()
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def prefix_bytes(self, prefix: str) -> int:
        """Total stored bytes under a prefix, unbilled: object sizes are
        metadata a real driver already holds (LIST responses carry them,
        and the driver wrote these keys' registry itself) — the planner's
        cost model reads them like any other client-side bookkeeping."""
        with self._lock:
            return sum(len(v) for k, v in self._objects.items()
                       if k.startswith(prefix))

    def delete(self, key: str):
        self.ledger.add_s3_delete()
        with self._lock:
            self._objects.pop(key, None)

    def delete_prefix(self, prefix: str) -> int:
        """Job-scoped GC sweep: one LIST + a (free) DELETE per key."""
        self.ledger.add_s3_list()
        with self._lock:
            doomed = [k for k in self._objects if k.startswith(prefix)]
            for k in doomed:
                del self._objects[k]
        for _ in doomed:
            self.ledger.add_s3_delete()
        return len(doomed)

    # convenience for pickled python values (payload spill, shuffle blobs)
    def put_obj(self, key: str, value: Any):
        self.put(key, pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))

    def get_obj(self, key: str) -> Any:
        return pickle.loads(self.get(key))


_FRAME = struct.Struct("<I")  # 4-byte little-endian record-length prefix


class SpillPointer:
    """Stand-in record for a single pickle too large for one SQS message:
    the real bytes ride the object store (paper §III-B large-payload
    handling) and the queue carries this pointer instead."""

    __slots__ = ("key",)

    def __init__(self, key: str):
        self.key = key

    def __reduce__(self):
        return (SpillPointer, (self.key,))


def pack_records(records: Iterable[Any], limit: int = SQS_MESSAGE_LIMIT,
                 spill: Callable[[bytes], str] | None = None) -> list[bytes]:
    """Pack records into length-prefixed message bodies under the 256 KiB
    SQS cap, pickling each record EXACTLY once (single-pass incremental
    framing — the old implementation pickled twice: once to estimate the
    size, once inside the batch pickle).

    A single record whose pickle alone exceeds the cap would make every
    ``send_batch`` of its body raise — an unrecoverable task. With
    ``spill`` given (blob -> object-store key), the oversized pickle is
    stored out-of-band and a small SpillPointer record is framed in its
    place; ``unpack_records`` resolves it against the store."""
    bodies: list[bytes] = []
    frames: list[bytes] = []
    size = 0
    for r in records:
        blob = pickle.dumps(r, protocol=pickle.HIGHEST_PROTOCOL)
        need = _FRAME.size + len(blob)
        if spill is not None and need > limit:
            blob = pickle.dumps(SpillPointer(spill(blob)),
                                protocol=pickle.HIGHEST_PROTOCOL)
            need = _FRAME.size + len(blob)
        if frames and size + need > limit:
            bodies.append(b"".join(frames))
            frames, size = [], 0
        frames.append(_FRAME.pack(len(blob)))
        frames.append(blob)
        size += need
    if frames:
        bodies.append(b"".join(frames))
    return bodies


def unpack_records(body: bytes, store: ObjectStoreSim | None = None
                   ) -> list[Any]:
    out = []
    off, n = 0, len(body)
    while off < n:
        (ln,) = _FRAME.unpack_from(body, off)
        off += _FRAME.size
        rec = pickle.loads(body[off:off + ln])
        off += ln
        if isinstance(rec, SpillPointer):
            if store is None:
                raise ValueError(
                    f"spilled record {rec.key} needs an object store")
            rec = pickle.loads(store.get(rec.key))
        out.append(rec)
    return out
