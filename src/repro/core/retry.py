"""Layered retry/backoff for transient service failures
(docs/fault_tolerance.md).

Every service call Flint's data plane makes — SQS send/receive, S3
PUT/GET/LIST, the executors' store access — can fail transiently and
independently (Lambada's S3 throttling experience; ServerMix's
disaggregated-failure framing). This module is the innermost of the three
recovery layers: it retries the *call*, the scheduler retries the *task*,
and lineage resubmission retries the *stage*.

The error taxonomy splits RETRYABLE from FATAL:

  * ``TransientServiceError`` — a 5xx/SlowDown: the request failed but the
    identical call is expected to succeed. Retried here.
  * ``ThrottledError`` — 429: capacity, not failure. Retried here when it
    escapes the scheduler's dispatch backoff.
  * everything else — ``KeyError`` (a missing object is MISSING, not
    flaky; re-GETting it cannot help — that is lost-input territory,
    handled by lineage recovery), ``QueueGone``, ``AbortedError``,
    injected task faults — passes straight through.

``RetryPolicy.call`` wraps one service call with exponential backoff and
DECORRELATED JITTER (sleep ~ U(base, 3*prev) capped at ``cap``), a
per-call attempt cap, and an optional job-wide ``RetryBudget``: every
retry spends one unit, and exhausting the budget raises
``RetryBudgetExhausted`` — a FATAL error, because a job burning its whole
budget is systemically unhealthy, not unlucky.
"""

from __future__ import annotations

import pickle
import random
import threading
import time


class TransientServiceError(RuntimeError):
    """Service-side 5xx/SlowDown: the request failed, nothing happened,
    retrying the identical call is expected to succeed."""

    def __init__(self, msg: str, service: str = "", op: str = ""):
        super().__init__(msg)
        self.service = service
        self.op = op


class ThrottledError(RuntimeError):
    """429 / Rate exceeded: the service is shedding load. Retryable, but
    the right first response is to back off dispatch, not hammer."""


class RetryExhausted(RuntimeError):
    """One call failed transiently more times than the per-call attempt
    cap allows. Carries the last underlying error as ``cause``."""

    def __init__(self, msg: str, cause: BaseException | None = None):
        super().__init__(msg)
        self.cause = cause


class RetryBudgetExhausted(RuntimeError):
    """The job-wide retry budget is spent. Fatal by design: a job that
    needs this many service-call retries is failing systemically and
    should surface that instead of grinding on."""


#: the retryable side of the taxonomy — everything else is fatal here
RETRYABLE_ERRORS = (TransientServiceError, ThrottledError)


def is_retryable(exc: BaseException) -> bool:
    return isinstance(exc, RETRYABLE_ERRORS)


class RetryBudget:
    """Thread-safe job-wide cap on the total number of service-call
    retries (not calls — first attempts are free)."""

    def __init__(self, total: int):
        if total <= 0:
            raise ValueError(f"retry budget must be > 0, got {total}")
        self.total = total
        self.spent = 0
        self._lock = threading.Lock()

    def spend(self, n: int = 1):
        with self._lock:
            if self.spent + n > self.total:
                self.spent = self.total
                raise RetryBudgetExhausted(
                    f"job retry budget exhausted: {self.total} service-call "
                    f"retries spent")
            self.spent += n

    @property
    def remaining(self) -> int:
        with self._lock:
            return self.total - self.spent


class RetryPolicy:
    """Exponential backoff with decorrelated jitter around one service
    call. Instances are shared across threads (one per job or transport
    set); the RNG is locked, the rest is immutable."""

    def __init__(self, max_attempts: int = 5, base_s: float = 0.002,
                 cap_s: float = 0.05, budget: RetryBudget | None = None,
                 seed: int = 0):
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if base_s <= 0 or cap_s < base_s:
            raise ValueError(
                f"backoff must satisfy 0 < base_s <= cap_s, got "
                f"base_s={base_s} cap_s={cap_s}")
        self.max_attempts = max_attempts
        self.base_s = base_s
        self.cap_s = cap_s
        self.budget = budget
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    @classmethod
    def from_config(cls, cfg, budget: RetryBudget | None = None,
                    seed: int = 0) -> "RetryPolicy":
        return cls(max_attempts=cfg.retry_max_attempts,
                   base_s=cfg.retry_base_s, cap_s=cfg.retry_cap_s,
                   budget=budget, seed=seed)

    def next_sleep(self, prev: float) -> float:
        """Decorrelated jitter (AWS builders'-library flavor): sample
        U(base, 3*prev), clamp to [base, cap]. Spreads retry storms
        without the synchronized waves plain exponential produces."""
        with self._lock:
            s = self._rng.uniform(self.base_s, max(prev * 3, self.base_s))
        return min(self.cap_s, max(self.base_s, s))

    def call(self, fn, *args, **kwargs):
        """Invoke ``fn`` retrying RETRYABLE_ERRORS only. Raises
        ``RetryExhausted`` past the attempt cap, ``RetryBudgetExhausted``
        if the shared budget runs dry first."""
        prev = self.base_s
        for attempt in range(1, self.max_attempts + 1):
            try:
                return fn(*args, **kwargs)
            except RETRYABLE_ERRORS as e:
                if attempt >= self.max_attempts:
                    raise RetryExhausted(
                        f"{getattr(fn, '__name__', fn)} failed after "
                        f"{attempt} attempts: {e}", cause=e) from e
                if self.budget is not None:
                    self.budget.spend()
                prev = self.next_sleep(prev)
                time.sleep(prev)
        raise AssertionError("unreachable")


class RetryingStore:
    """View of an ObjectStoreSim that routes the billable data-plane calls
    (PUT/GET/LIST) through a RetryPolicy — the executors' store access.
    Control-plane calls (size/exists/delete) delegate untouched: the sim
    never injects faults there, and the GC must not burn retry budget."""

    def __init__(self, store, policy: RetryPolicy):
        self._store = store
        self.retry = policy

    def put(self, key, data):
        return self.retry.call(self._store.put, key, data)

    def get(self, key, start=0, end=None):
        return self.retry.call(self._store.get, key, start, end)

    def list(self, prefix):
        return self.retry.call(self._store.list, prefix)

    def put_obj(self, key, value):
        self.put(key, pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL))

    def get_obj(self, key):
        return pickle.loads(self.get(key))

    def size(self, key):
        return self._store.size(key)

    def exists(self, key):
        return self._store.exists(key)

    def prefix_bytes(self, prefix):
        return self._store.prefix_bytes(prefix)

    def delete(self, key):
        return self._store.delete(key)

    def delete_prefix(self, prefix):
        return self._store.delete_prefix(prefix)
