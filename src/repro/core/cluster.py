"""Cluster baseline backends (paper Table I comparison conditions).

Runs the SAME physical plan as FlintScheduler, but the way a provisioned
Spark cluster would: a persistent pool of long-running workers, direct
in-memory shuffle (no queue service, no per-invocation billing), cost =
wall-clock x per-second cluster price — including idle time.

``pipe_overhead=True`` models the PySpark condition: every record crosses
the JVM<->Python boundary, simulated as a per-record serialize/deserialize
round-trip (the paper attributes PySpark's 1.5-2x slowdown to exactly
this; Flint avoids it by running Python end-to-end).
"""

from __future__ import annotations

import concurrent.futures as cf
import pickle
import time
from collections import defaultdict
from typing import Any

from repro.core.costs import CLUSTER_INSTANCES, CostLedger, cluster_cost
from repro.core.dag import (CacheInput, CollectionInput, ShuffleRead,
                            SourceInput, StagePlan)
from repro.core.executors import (FlintConfig, _apply_ops, _SourceReader,
                                  cache_partition_iter)
from repro.core.queues import ObjectStoreSim
from repro.core.shuffle import iter_records


class ClusterScheduler:
    def __init__(self, cfg: FlintConfig, ledger: CostLedger | None = None,
                 store: ObjectStoreSim | None = None, *,
                 workers: int = 80, pipe_overhead: bool = False):
        self.cfg = cfg
        self.ledger = ledger or CostLedger()
        self.store = store or ObjectStoreSim(self.ledger)
        self.pool = cf.ThreadPoolExecutor(max_workers=workers)
        self.pipe_overhead = pipe_overhead
        self.wall_seconds = 0.0
        self.stage_stats: list[dict] = []

    # ------------------------------------------------------------------
    def run(self, stages: list[StagePlan]):
        t0 = time.monotonic()
        shuffles: dict[int, dict[int, list]] = defaultdict(
            lambda: defaultdict(list))
        result = None
        for stage in stages:
            result = self._run_stage(stage, shuffles)
        self.wall_seconds += time.monotonic() - t0
        return result

    def _records_in(self, task, shuffles):
        inp = task.input
        if isinstance(inp, SourceInput):
            return iter(_SourceReader(inp, self.store, self.cfg, None))
        if isinstance(inp, CollectionInput):
            return iter(self.store.get_obj(f"{inp.key}/{inp.index}"))
        if isinstance(inp, CacheInput):
            return cache_partition_iter(inp, self.store)
        assert isinstance(inp, ShuffleRead)
        if inp.self_join or len(inp.parts) == 2:  # join
            if inp.self_join:
                sl, _ = inp.parts[0]
                sr = sl  # one shared shuffle feeds both sides
            else:
                (sl, _), (sr, _) = inp.parts
            left: dict = defaultdict(list)
            right: dict = defaultdict(list)
            for k, v in shuffles[sl][inp.partition]:
                left[k].append(v)
            for k, v in shuffles[sr][inp.partition]:
                right[k].append(v)
            how = inp.join_how
            pairs = [(k, (lv, rv)) for k in left if k in right
                     for lv in left[k] for rv in right[k]]
            if how in ("left", "outer"):
                pairs += [(k, (lv, None)) for k in left if k not in right
                          for lv in left[k]]
            if how in ("right", "outer"):
                pairs += [(k, (None, rv)) for k in right if k not in left
                          for rv in right[k]]
            return iter(pairs)
        sid, mode = inp.parts[0]
        records = shuffles[sid][inp.partition]
        if mode == "agg":
            agg: dict = {}
            fn = inp.combine_fn
            for k, v in records:
                agg[k] = fn(agg[k], v) if k in agg else v
            return iter(agg.items())
        if mode == "group":
            g: dict = defaultdict(list)
            for k, v in records:
                g[k].append(v)
            return iter(g.items())
        return iter(records)

    def _run_stage(self, stage: StagePlan, shuffles) -> Any:
        t0 = time.monotonic()

        def run_task(task):
            it = self._records_in(task, shuffles)
            if self.pipe_overhead:  # JVM -> Python pipe: serde per record
                it = (pickle.loads(pickle.dumps(r)) for r in it)
            it = _apply_ops(it, [(k, fn) for k, fn in task.ops], self.store)
            # fused vectorized ops may yield KVBatch column carriers; this
            # backend's write loops iterate row-at-a-time
            it = iter_records(it)
            if stage.write is not None:
                w = stage.write
                out: dict[int, list] = defaultdict(list)
                if w.mode == "repart":
                    if w.partition_fn is not None:
                        for rec in it:
                            out[w.partition_fn(rec) % w.nparts].append(rec)
                    else:
                        for i, rec in enumerate(it):
                            out[i % w.nparts].append(rec)
                elif w.mode == "agg" and w.combine_fn is not None:
                    combined: dict = {}
                    for k, v in it:
                        combined[k] = (w.combine_fn(combined[k], v)
                                       if k in combined else v)
                    for k, v in combined.items():
                        out[hash(k) % w.nparts].append((k, v))
                else:
                    for k, v in it:
                        out[hash(k) % w.nparts].append((k, v))
                return ("shuffle", w.shuffle_id, out)
            result = list(it)
            if stage.save_prefix:
                key = f"{stage.save_prefix}/part-{task.index:05d}"
                self.store.put(key, "\n".join(str(r) for r in result).encode())
                return ("saved", key, None)
            return ("result", task.index, result)

        outs = list(self.pool.map(run_task, stage.tasks))
        self.stage_stats.append({"stage": stage.id, "tasks": len(stage.tasks),
                                 "wall_s": round(time.monotonic() - t0, 4)})
        partials: dict[int, list] = {}
        for kind, a, b in outs:
            if kind == "shuffle":
                for p, recs in b.items():
                    shuffles[a][p].extend(recs)
            elif kind == "result":
                partials[a] = b
        if stage.action in ("collect", "sum"):
            out: list = []
            for i in range(len(stage.tasks)):
                out.extend(partials.get(i, []))
                if stage.limit is not None and len(out) >= stage.limit:
                    return out[:stage.limit]  # take(n) merge short-circuit
            return sum(out) if stage.action == "sum" else out
        if stage.action == "save":
            return [f"{stage.save_prefix}/part-{i:05d}"
                    for i in range(len(stage.tasks))]
        return None

    def cost_usd(self, wall_seconds: float | None = None) -> float:
        return cluster_cost(wall_seconds if wall_seconds is not None
                            else self.wall_seconds, CLUSTER_INSTANCES)

    def shutdown(self):
        self.pool.shutdown(wait=False)
