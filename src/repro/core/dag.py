"""DAG scheduler: cut the RDD lineage into stages at wide dependencies.

Faithful to the paper's division of labor: this layer produces the physical
plan (stages of tasks + shuffle specs); the pluggable backend
(core.scheduler.FlintScheduler or core.cluster.ClusterScheduler) only ever
sees stages and tasks.

A TaskDef is fully self-describing: an input spec (source byte-range,
driver collection partition, or shuffle read) plus the chain of narrow ops
to apply. Functions are shipped with core.serde (mini-cloudpickle).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

from repro.core import rdd as R

_next_shuffle = itertools.count()


@dataclasses.dataclass
class SourceInput:
    key: str
    start: int
    end: int
    size: int


@dataclasses.dataclass
class CollectionInput:
    key: str
    index: int


@dataclasses.dataclass
class ShuffleRead:
    """One or two (join) shuffle inputs feeding a task."""
    parts: list  # list of (shuffle_id, mode) — mode: agg|group|join|repart
    partition: int
    combine_fn: Any = None  # serialized via serde at task-build time
    # shuffle_id -> transport name, mirroring the producing ShuffleWrite's
    # hint so both ends of a shuffle always agree on the backend
    transports: dict | None = None


@dataclasses.dataclass
class ShuffleWrite:
    shuffle_id: int
    nparts: int
    mode: str  # agg | group | join | repart
    combine_fn: Any = None  # map-side combine (reduceByKey)
    key_side: str = ""  # join: 'left' | 'right'
    # per-shuffle transport hint (core.shuffle registry name); "" defers
    # to FlintConfig.shuffle_backend — the Flock-style per-shuffle choice
    transport: str = ""


@dataclasses.dataclass
class TaskDef:
    stage_id: int
    index: int
    input: Any  # SourceInput | CollectionInput | ShuffleRead
    ops: list  # [(kind, fn), ...]
    write: ShuffleWrite | None  # None => result/save stage


@dataclasses.dataclass
class StagePlan:
    id: int
    tasks: list
    write: ShuffleWrite | None
    action: str | None = None  # set on the final stage
    save_prefix: str | None = None
    # shuffle_id -> number of producer TASKS feeding it. Known at plan time
    # (it is just the producing stage's task count), which is what lets the
    # scheduler hand consumers an EOS quorum up front and launch them
    # concurrently with their producers instead of waiting for post-hoc
    # per-queue message-count expectations.
    producer_counts: dict = dataclasses.field(default_factory=dict)


class _Chain:
    """A stage under construction: per-task (input, ops)."""

    def __init__(self, task_inputs, deps, producer_counts=None):
        self.task_inputs = task_inputs  # list of input specs
        self.ops_per_task = [[] for _ in task_inputs]
        self.deps = deps  # upstream StagePlans
        self.producer_counts = dict(producer_counts or {})

    def add_op(self, kind, fn):
        for ops in self.ops_per_task:
            ops.append((kind, fn))


def _visit(node, stages: list, mult: int) -> _Chain:
    """Returns the open chain for `node`; appends completed upstream stages
    to `stages` in topological order. ``mult`` scales wide-op partition
    counts — the paper's elasticity answer to the executor memory cap."""
    if isinstance(node, R.Source):
        size = node.ctx.store.size(node.key)
        step = max(1, -(-size // node.nparts))
        inputs = [SourceInput(node.key, i * step, min(size, (i + 1) * step), size)
                  for i in range(node.nparts)]
        return _Chain(inputs, [])
    if isinstance(node, R.ParallelCollection):
        return _Chain([CollectionInput(node.key, i) for i in range(node.nparts)], [])
    if isinstance(node, R.Narrow):
        chain = _visit(node.parent, stages, mult)
        chain.add_op(node.kind, node.fn)
        return chain
    if isinstance(node, R.Union):
        ca = _visit(node.a, stages, mult)
        cb = _visit(node.b, stages, mult)
        merged = _Chain(ca.task_inputs + cb.task_inputs, ca.deps + cb.deps,
                        {**ca.producer_counts, **cb.producer_counts})
        merged.ops_per_task = ca.ops_per_task + cb.ops_per_task
        return merged
    if isinstance(node, R.ShuffleAgg):
        mode = "agg" if node.map_side_combine else "group"
        nparts = node.nparts * mult
        tr = node.transport or ""
        sid = _close_stage(node.parent, stages, mult,
                           ShuffleWrite(next(_next_shuffle), nparts, mode,
                                        combine_fn=node.fn, transport=tr))
        inputs = [ShuffleRead([(sid, mode)], p, combine_fn=node.fn,
                              transports={sid: tr})
                  for p in range(nparts)]
        return _Chain(inputs, [stages[-1]],
                      {sid: len(stages[-1].tasks)})
    if isinstance(node, R.Repartition):
        nparts = node.nparts * mult
        tr = node.transport or ""
        sid = _close_stage(node.parent, stages, mult,
                           ShuffleWrite(next(_next_shuffle), nparts,
                                        "repart", transport=tr))
        inputs = [ShuffleRead([(sid, "repart")], p, transports={sid: tr})
                  for p in range(nparts)]
        return _Chain(inputs, [stages[-1]],
                      {sid: len(stages[-1].tasks)})
    if isinstance(node, R.Join):
        nparts = node.nparts * mult
        tr = node.transport or ""
        sid_l = _close_stage(node.left, stages, mult,
                             ShuffleWrite(next(_next_shuffle), nparts,
                                          "join", key_side="left",
                                          transport=tr))
        n_left = len(stages[-1].tasks)
        sid_r = _close_stage(node.right, stages, mult,
                             ShuffleWrite(next(_next_shuffle), nparts,
                                          "join", key_side="right",
                                          transport=tr))
        n_right = len(stages[-1].tasks)
        inputs = [ShuffleRead([(sid_l, "join"), (sid_r, "join")], p,
                              transports={sid_l: tr, sid_r: tr})
                  for p in range(nparts)]
        return _Chain(inputs, [], {sid_l: n_left, sid_r: n_right})
    raise TypeError(f"unknown RDD node {type(node).__name__}")


def _close_stage(node, stages: list, mult: int, write: ShuffleWrite) -> int:
    chain = _visit(node, stages, mult)
    sid = write.shuffle_id
    stage_id = len(stages)
    tasks = [TaskDef(stage_id, i, inp, ops, write)
             for i, (inp, ops) in enumerate(
                 zip(chain.task_inputs, chain.ops_per_task))]
    stages.append(StagePlan(stage_id, tasks, write,
                            producer_counts=chain.producer_counts))
    return sid


def build_plan(node, action: str, save_prefix: str | None = None,
               partition_multiplier: int = 1) -> list[StagePlan]:
    stages: list[StagePlan] = []
    chain = _visit(node, stages, partition_multiplier)
    stage_id = len(stages)
    tasks = [TaskDef(stage_id, i, inp, ops, None)
             for i, (inp, ops) in enumerate(
                 zip(chain.task_inputs, chain.ops_per_task))]
    stages.append(StagePlan(stage_id, tasks, None, action=action,
                            save_prefix=save_prefix,
                            producer_counts=chain.producer_counts))
    return stages
