"""DAG scheduler: cut the RDD lineage into stages at wide dependencies.

Faithful to the paper's division of labor: this layer produces the physical
plan (stages of tasks + shuffle specs); the pluggable backend
(core.scheduler.FlintScheduler or core.cluster.ClusterScheduler) only ever
sees stages and tasks.

A TaskDef is fully self-describing: an input spec (source byte-range,
driver collection partition, shuffle read, or cached-partition read) plus
the chain of narrow ops to apply. Functions are shipped with core.serde
(mini-cloudpickle).

Two plan-level optimizations live here (docs/dag_fanout.md):

COMMON-SUBEXPRESSION ELIMINATION: the planner fingerprints every lineage
node (structure + serialized functions), so when the same shuffle — same
input lineage, mode, partition count, combiner, and transport — is needed
by more than one consumer (a self-join, a diamond where one RDD feeds two
wide ops, a union of two derivations of one RDD), its producer stage is
planned exactly ONCE and the shared ``ShuffleWrite`` is tagged with one
CONSUMER GROUP per read site. Each ``ShuffleRead`` carries its group
index; transports fan data out (or multi-read it non-destructively) per
group, so every consumer sees the full stream independently. A self-join
collapses further: both sides fingerprint identically, so the join reads
a single shuffle once (``ShuffleRead.self_join``) instead of draining two
copies of the same data.

CACHE MATERIALIZATION: an RDD marked ``.cache()`` gets a per-task
``("cache", ...)`` op that tees its computed partitions to
content-addressed ``_cache/{token}/{nparts}/p{i}/`` object-store keys
(columnar batches). A later ACTION whose lineage reaches the same
fingerprint reads ``CacheInput`` partitions instead of replanning the
upstream stages. The token is the lineage fingerprint, so caching assumes
the same determinism the rest of the fault-tolerance story already does.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import pickle
from typing import Any

from repro.core import costs
from repro.core import rdd as R
from repro.core import serde

_next_shuffle = itertools.count()


@dataclasses.dataclass
class SourceInput:
    key: str
    start: int
    end: int
    size: int


@dataclasses.dataclass
class CollectionInput:
    key: str
    index: int


@dataclasses.dataclass
class CacheInput:
    """One materialized partition of a cached lineage: columnar batches
    under ``_cache/{token}/{nparts}/p{index}/``."""
    token: str
    nparts: int
    index: int


@dataclasses.dataclass
class ShuffleRead:
    """One or two (join) shuffle inputs feeding a task."""
    parts: list  # list of (shuffle_id, mode) — mode: agg|group|join|repart
    partition: int
    combine_fn: Any = None  # serialized via serde at task-build time
    # shuffle_id -> transport name, mirroring the producing ShuffleWrite's
    # hint so both ends of a shuffle always agree on the backend
    transports: dict | None = None
    # consumer-group index per ``parts`` entry (None => group 0): each read
    # site of a CSE-shared shuffle drains its own group, so sibling
    # consumers never steal each other's messages
    groups: list | None = None
    # a self-join reads ONE shared shuffle and uses the drained aggregate
    # as both sides, instead of shipping the same data twice
    self_join: bool = False
    # join semantics: inner | left | right | outer — which side's
    # unmatched rows survive (paired with None)
    join_how: str = "inner"
    # adaptive partition coalescing (runtime rewrite only — never set at
    # plan time): the CONTIGUOUS list of producer partitions this task
    # drains instead of just ``partition``; order is preserved so an
    # index-ordered merge still yields globally sorted output
    partitions: list | None = None


@dataclasses.dataclass
class ShuffleWrite:
    shuffle_id: int
    nparts: int
    mode: str  # agg | group | join | repart
    combine_fn: Any = None  # map-side combine (reduceByKey)
    key_side: str = ""  # join: 'left' | 'right'
    # per-shuffle transport hint (core.shuffle registry name); "" defers
    # to FlintConfig.shuffle_backend — the Flock-style per-shuffle choice
    transport: str = ""
    # number of independent consumer groups reading this shuffle (CSE fans
    # one producer stage out to N consuming read sites); fixed by the time
    # planning completes, before any channel opens
    consumer_groups: int = 1
    # declared (key, value) column schemas for this shuffle's typed
    # columnar batches (serde schema grammar); None => per-batch sniffing.
    # The SQL lowering sets this — it knows row types at plan time.
    batch_schema: tuple | None = None
    # repart mode: explicit record -> partition routing (range
    # partitioner for distributed orderBy); None => round-robin
    partition_fn: Any = None
    # planner's shuffle-volume estimate (bytes) — the adaptive scheduler
    # compares it against measured stage output at runtime
    est_bytes: float = 0.0
    # True when ``transport`` was resolved by the cost model ("auto"
    # default, no per-shuffle hint): only those choices may be revisited
    # at runtime from measured volume — explicit hints stay pinned
    auto_transport: bool = False


@dataclasses.dataclass
class TaskDef:
    stage_id: int
    index: int
    input: Any  # SourceInput | CollectionInput | CacheInput | ShuffleRead
    ops: list  # [(kind, fn), ...]
    write: ShuffleWrite | None  # None => result/save stage


@dataclasses.dataclass
class StagePlan:
    id: int
    tasks: list
    write: ShuffleWrite | None
    action: str | None = None  # set on the final stage
    save_prefix: str | None = None
    # RDD.take(n) / DataFrame.limit(n): the action merge stops consuming
    # partition results once this many records have accumulated (each
    # partition also carries a per-task "limit" op capping evaluation)
    limit: int | None = None
    # shuffle_id -> number of producer TASKS feeding it. Known at plan time
    # (it is just the producing stage's task count), which is what lets the
    # scheduler hand consumers an EOS quorum up front and launch them
    # concurrently with their producers instead of waiting for post-hoc
    # per-queue message-count expectations.
    producer_counts: dict = dataclasses.field(default_factory=dict)


# ------------------------------------------------------ lineage fingerprints


def _fn_fingerprint(fn, memo: dict | None = None) -> bytes:
    if fn is None:
        return b"-"
    try:
        return serde.dumps_fn(fn)
    except Exception:
        # unserializable callable: the id() keeps it distinct from every
        # OTHER live object, so within one plan (where the RDD graph pins
        # the objects) CSE stays conservative. Across actions id reuse
        # could alias a released function, so the walk is flagged
        # unstable and cache_token refuses to content-address it.
        if memo is not None:
            memo["unstable"] = True
        return f"unserializable:{id(fn)}".encode()


def lineage_fingerprint(node, _memo: dict | None = None) -> bytes:
    """Structural content hash of a lineage: node types, parameters, and
    the serialized bytes of every user function. Two RDDs with equal
    fingerprints compute the same partitions, so the planner may share
    their shuffles — separately-constructed but identical derivations
    merge just like reuse of one RDD object. Falls back to object
    identity for anything it cannot serialize (no false merges)."""
    memo = {} if _memo is None else _memo
    got = memo.get(id(node))
    if got is not None:
        return got
    if isinstance(node, R.Source):
        parts: tuple = (b"src", node.key, node.nparts)
    elif isinstance(node, R.ParallelCollection):
        parts = (b"coll", node.key, node.nparts)
    elif isinstance(node, R.Narrow):
        parts = (b"narrow", node.kind, _fn_fingerprint(node.fn, memo),
                 lineage_fingerprint(node.parent, memo))
    elif isinstance(node, R.ShuffleAgg):
        parts = (b"agg", node.map_side_combine, node.nparts,
                 node.transport or "", _fn_fingerprint(node.fn, memo),
                 lineage_fingerprint(node.parent, memo))
    elif isinstance(node, R.Repartition):
        parts = (b"repart", node.nparts, node.transport or "",
                 _fn_fingerprint(getattr(node, "partition_fn", None), memo),
                 lineage_fingerprint(node.parent, memo))
    elif isinstance(node, R.Join):
        parts = (b"join", node.nparts, node.transport or "",
                 getattr(node, "how", "inner"),
                 lineage_fingerprint(node.left, memo),
                 lineage_fingerprint(node.right, memo))
    elif isinstance(node, R.Union):
        parts = (b"union", lineage_fingerprint(node.a, memo),
                 lineage_fingerprint(node.b, memo))
    else:
        raise TypeError(f"unknown RDD node {type(node).__name__}")
    digest = hashlib.sha1(pickle.dumps(parts)).digest()
    memo[id(node)] = digest
    return digest


def cache_token(node) -> str | None:
    """Content-addressed cache identity for ``RDD.cache()`` partitions,
    or None when the lineage contains an unserializable callable — its
    identity-based fingerprint is not stable across actions (CPython id
    reuse could alias a different function), so such lineages simply
    recompute instead of risking a false cache hit."""
    memo: dict = {}
    fp = lineage_fingerprint(node, memo)
    if memo.get("unstable"):
        return None
    return fp.hex()[:24]


class _Chain:
    """A stage under construction: per-task (input, ops)."""

    def __init__(self, task_inputs, producer_counts=None):
        self.task_inputs = task_inputs  # list of input specs
        self.ops_per_task = [[] for _ in task_inputs]
        self.producer_counts = dict(producer_counts or {})

    def add_op(self, kind, fn):
        for ops in self.ops_per_task:
            ops.append((kind, fn))


class _Planner:
    """One build_plan invocation: carries the stage list, the CSE memo of
    closed shuffles, and the cache registry shared with the context."""

    def __init__(self, mult: int, cse: bool, cache_index: dict | None,
                 default_transport: str = "", share=None):
        self.stages: list[StagePlan] = []
        self.mult = mult
        self.cse = cse
        self.cache_index = cache_index
        self.default_transport = default_transport
        # service-wide CSE (docs/multi_tenant.md): a per-job view of the
        # share registry. ``lookup(key)`` answers with another LIVE job's
        # identical shuffle — this plan then reads it as a FOREIGN input
        # (no producer stage of its own) via a fresh consumer group;
        # ``publish`` offers this plan's own closed shuffles in return
        self.share = share
        self._fps: dict[int, bytes] = {}
        # close-site key -> (sid, n_producer_tasks, ShuffleWrite)
        self._shared: dict[tuple, tuple] = {}
        # close-site key -> (sid, n_prod) for foreign (cross-job) hits
        self._foreign: dict[tuple, tuple] = {}
        self._materializing: set[str] = set()
        # id(node) -> (node, estimate). The node reference is kept ON
        # PURPOSE: a memo keyed by bare id() could hand a GC'd node's
        # reused id the estimate of an unrelated lineage; pinning the
        # node makes the id stable for this planner's lifetime and the
        # identity check below rejects any entry that isn't ours.
        self._est_memo: dict[int, tuple] = {}

    def fp(self, node) -> bytes:
        return lineage_fingerprint(node, self._fps)

    # ------------------------------------------ adaptive transport choice
    def _cache_entry(self, node) -> dict | None:
        if not getattr(node, "cached", False) or self.cache_index is None:
            return None
        entry = self.cache_index.get(cache_token(node))
        return entry if entry and entry.get("ready") else None

    def _est_bytes(self, node) -> float:
        """Planner-side shuffle-volume estimate: source object sizes
        scaled by textbook selectivity constants, or the ACTUAL stored
        batch sizes when the lineage is a ready cache() materialization.
        Drives the cost-model transport choice — it only has to land on
        the right side of the SQS/S3 crossover, not be exact."""
        got = self._est_memo.get(id(node))
        if got is not None and got[0] is node:
            return got[1]
        val = None
        entry = self._cache_entry(node)
        if entry is not None:
            token = cache_token(node)
            stored = float(node.ctx.store.prefix_bytes(
                f"_cache/{token}/{entry['nparts']}/"))
            # staleness check: a just-uncache()d token can linger in the
            # index while its prefix is already swept — 0 stored bytes
            # for a "ready" entry means fall through to the lineage walk
            # instead of estimating a non-empty dataset at zero
            if stored > 0:
                val = stored
        if val is not None:
            pass
        elif isinstance(node, R.Source):
            val = float(node.ctx.store.size(node.key))
        elif isinstance(node, R.ParallelCollection):
            val = float(sum(node.ctx.store.size(f"{node.key}/{i}")
                            for i in range(node.nparts)))
        elif isinstance(node, R.Narrow):
            factor = (costs.EST_FILTER_SELECTIVITY
                      if node.kind == "filter" else 1.0)
            val = self._est_bytes(node.parent) * factor
        elif isinstance(node, R.ShuffleAgg):
            val = self._est_bytes(node.parent) * costs.EST_AGG_OUTPUT_FACTOR
        elif isinstance(node, R.Repartition):
            val = self._est_bytes(node.parent)
        elif isinstance(node, R.Join):
            val = self._est_bytes(node.left) + self._est_bytes(node.right)
        elif isinstance(node, R.Union):
            val = self._est_bytes(node.a) + self._est_bytes(node.b)
        else:
            raise TypeError(f"unknown RDD node {type(node).__name__}")
        self._est_memo[id(node)] = (node, val)
        return val

    def _est_producers(self, node) -> int:
        """Approximate producer TASK count for a shuffle fed by ``node`` —
        per-channel object/request overheads scale with it."""
        entry = self._cache_entry(node)
        if entry is not None:
            return entry["nparts"]
        if isinstance(node, R.Source):
            return node.nparts * self.mult
        if isinstance(node, R.ParallelCollection):
            return node.nparts
        if isinstance(node, R.Narrow):
            return self._est_producers(node.parent)
        if isinstance(node, R.Union):
            return (self._est_producers(node.a)
                    + self._est_producers(node.b))
        return node.nparts * self.mult  # wide op: its own partition count

    def _auto_transport(self, parent, nparts: int) -> str:
        """Cost-model SQS-vs-S3 choice for one shuffle (engine default
        "auto", no per-shuffle hint). Falls back to the paper's SQS when
        the lineage offers no size information."""
        try:
            est = self._est_bytes(parent)
        except Exception:
            return "sqs"
        return costs.pick_shuffle_transport(est,
                                            self._est_producers(parent),
                                            nparts)

    def _transport_for(self, node_hint: str | None, parent,
                       nparts: int) -> tuple[str, bool]:
        """Resolve one shuffle's transport; the second element records
        whether the COST MODEL chose it (vs an explicit hint / engine
        default), i.e. whether the adaptive runtime may re-choose it."""
        tr = node_hint or ""
        if not tr and self.default_transport == "auto":
            return self._auto_transport(parent, nparts), True
        return tr, False

    # ------------------------------------------------------------- visit
    def visit(self, node) -> _Chain:
        """Returns the open chain for ``node``; completed upstream stages
        land in ``self.stages`` in topological order."""
        token = None
        if getattr(node, "cached", False) and self.cache_index is not None:
            token = cache_token(node)
            entry = self.cache_index.get(token)
            if entry and entry.get("ready"):
                n = entry["nparts"]
                return _Chain([CacheInput(token, n, i)
                               for i in range(n)])
        chain = self._visit(node)
        if token is not None and token not in self._materializing:
            # first read site of this cached lineage in this plan tees its
            # partitions to the store; later sites share the CSE'd shuffle
            # instead of writing the same bytes twice
            self._materializing.add(token)
            n = len(chain.task_inputs)
            self.cache_index[token] = {"nparts": n, "ready": False}
            for i, ops in enumerate(chain.ops_per_task):
                ops.append(("cache", (token, n, i)))
        return chain

    def _visit(self, node) -> _Chain:
        if isinstance(node, R.Source):
            # byte-range splits re-cut freely, so the elasticity
            # multiplier scales them too — a source-rooted task past the
            # memory cap (e.g. a cache() materialization) must shrink on
            # the re-plan like any wide partition would
            nparts = node.nparts * self.mult
            size = node.ctx.store.size(node.key)
            step = max(1, -(-size // nparts))
            inputs = [SourceInput(node.key, i * step,
                                  min(size, (i + 1) * step), size)
                      for i in range(nparts)]
            return _Chain(inputs)
        if isinstance(node, R.ParallelCollection):
            return _Chain([CollectionInput(node.key, i)
                           for i in range(node.nparts)])
        if isinstance(node, R.Narrow):
            chain = self.visit(node.parent)
            chain.add_op(node.kind, node.fn)
            return chain
        if isinstance(node, R.Union):
            ca = self.visit(node.a)
            cb = self.visit(node.b)
            merged = _Chain(ca.task_inputs + cb.task_inputs,
                            {**ca.producer_counts, **cb.producer_counts})
            merged.ops_per_task = ca.ops_per_task + cb.ops_per_task
            return merged
        if isinstance(node, R.ShuffleAgg):
            mode = "agg" if node.map_side_combine else "group"
            nparts = node.nparts * self.mult
            tr, auto = self._transport_for(node.transport, node.parent,
                                           nparts)
            sid, n_prod, group = self._close_shared(
                node.parent, mode, nparts, node.fn, tr,
                batch_schema=node.batch_schema, auto_transport=auto)
            inputs = [ShuffleRead([(sid, mode)], p, combine_fn=node.fn,
                                  transports={sid: tr}, groups=[group])
                      for p in range(nparts)]
            return _Chain(inputs, {sid: n_prod})
        if isinstance(node, R.Repartition):
            nparts = node.nparts * self.mult
            tr, auto = self._transport_for(node.transport, node.parent,
                                           nparts)
            sid, n_prod, group = self._close_shared(
                node.parent, "repart", nparts, None, tr,
                partition_fn=node.partition_fn, auto_transport=auto)
            inputs = [ShuffleRead([(sid, "repart")], p,
                                  transports={sid: tr}, groups=[group])
                      for p in range(nparts)]
            return _Chain(inputs, {sid: n_prod})
        if isinstance(node, R.Join):
            nparts = node.nparts * self.mult
            how = node.how
            tr_l, auto_l = self._transport_for(node.transport, node.left,
                                               nparts)
            tr_r, auto_r = self._transport_for(node.transport, node.right,
                                               nparts)
            schemas = node.batch_schemas or (None, None, None)
            bs_l = (schemas[0], schemas[1]) if schemas[0] else None
            bs_r = (schemas[0], schemas[2]) if schemas[0] else None
            sid_l, n_left, g_l = self._close_shared(
                node.left, "join", nparts, None, tr_l, key_side="left",
                batch_schema=bs_l, auto_transport=auto_l)
            if (self.cse and self._close_key(node.right, "join", nparts,
                                             None, tr_r, bs_r)
                    == self._close_key(node.left, "join", nparts, None,
                                       tr_l, bs_l)):
                # SELF-JOIN: both sides are the same lineage — one shared
                # shuffle, drained once, used as left AND right (every
                # outer-join variant degenerates to inner here: a key
                # always matches itself)
                inputs = [ShuffleRead([(sid_l, "join")], p,
                                      transports={sid_l: tr_l},
                                      groups=[g_l], self_join=True,
                                      join_how=how)
                          for p in range(nparts)]
                return _Chain(inputs, {sid_l: n_left})
            sid_r, n_right, g_r = self._close_shared(
                node.right, "join", nparts, None, tr_r, key_side="right",
                batch_schema=bs_r, auto_transport=auto_r)
            inputs = [ShuffleRead([(sid_l, "join"), (sid_r, "join")], p,
                                  transports={sid_l: tr_l, sid_r: tr_r},
                                  groups=[g_l, g_r], join_how=how)
                      for p in range(nparts)]
            return _Chain(inputs, {sid_l: n_left, sid_r: n_right})
        raise TypeError(f"unknown RDD node {type(node).__name__}")

    # ------------------------------------------------------- shuffle CSE
    def _close_key(self, node, mode: str, nparts: int, combine,
                   transport: str, batch_schema: tuple | None = None,
                   partition_fn=None) -> tuple:
        """What makes two shuffles interchangeable: identical input
        lineage, mode, partition count, combiner, transport, declared
        batch schema, and (repart) partition function. A join's
        ``key_side`` is deliberately EXCLUDED — a self-join's two sides
        carry identical data."""
        return (self.fp(node), mode, nparts, _fn_fingerprint(combine),
                transport, batch_schema, _fn_fingerprint(partition_fn))

    def _close_shared(self, node, mode: str, nparts: int, combine,
                      transport: str, key_side: str = "",
                      batch_schema: tuple | None = None,
                      partition_fn=None,
                      auto_transport: bool = False) -> tuple[int, int, int]:
        """Close (or reuse) the producer stage for one shuffle. Returns
        (shuffle_id, producer task count, consumer-group index for this
        read site)."""
        key = self._close_key(node, mode, nparts, combine, transport,
                              batch_schema, partition_fn) \
            if self.cse else None
        if key is not None:
            hit = self._shared.get(key)
            if hit is not None:
                sid, n_prod, write = hit
                write.consumer_groups += 1
                return sid, n_prod, write.consumer_groups - 1
            if self.share is not None:
                fhit = self._foreign.get(key)
                if fhit is None:
                    fhit = self.share.lookup(key)
                    if fhit is not None:
                        self._foreign[key] = fhit
                if fhit is not None:
                    # another live job already plans (or ran) this exact
                    # shuffle: skip the producer stage entirely and drain
                    # its stream through a fresh consumer group. Only
                    # S3-routed shuffles resolve here — the registry
                    # refuses destructive (queue) transports
                    sid, n_prod = fhit
                    return sid, n_prod, self.share.join_group(sid)
        try:
            est = float(self._est_bytes(node))
        except Exception:
            est = 0.0
        write = ShuffleWrite(next(_next_shuffle), nparts, mode,
                             combine_fn=combine, key_side=key_side,
                             transport=transport,
                             batch_schema=batch_schema,
                             partition_fn=partition_fn,
                             est_bytes=est,
                             auto_transport=auto_transport)
        chain = self.visit(node)
        sid = write.shuffle_id
        stage_id = len(self.stages)
        tasks = [TaskDef(stage_id, i, inp, ops, write)
                 for i, (inp, ops) in enumerate(
                     zip(chain.task_inputs, chain.ops_per_task))]
        self.stages.append(StagePlan(stage_id, tasks, write,
                                     producer_counts=chain.producer_counts))
        n_prod = len(tasks)
        if key is not None:
            self._shared[key] = (sid, n_prod, write)
            if self.share is not None:
                self.share.publish(key, sid, n_prod, write)
        return sid, n_prod, 0


def estimate_lineage_bytes(node, cache_index: dict | None = None) -> float:
    """Standalone shuffle-volume estimate for an RDD lineage (the SQL
    optimizer prices toDF sources with it; the planner uses the same walk
    internally for "auto" transport resolution)."""
    return _Planner(1, True, cache_index)._est_bytes(node)


def build_plan(node, action: str, save_prefix: str | None = None,
               partition_multiplier: int = 1, *, cse: bool = True,
               cache_index: dict | None = None,
               default_transport: str = "",
               limit: int | None = None, share=None) -> list[StagePlan]:
    """Physical plan for one action. ``partition_multiplier`` scales wide-op
    partition counts — the paper's elasticity answer to the executor memory
    cap. ``cse=False`` restores the one-consumer-per-shuffle planner (kept
    for the fan-out A/B benchmark); ``cache_index`` is the context-owned
    registry of materialized ``RDD.cache()`` lineages.

    ``default_transport="auto"`` makes the planner resolve every unhinted
    shuffle to SQS or the S3 exchange via the cost model (estimated volume
    x the ledger's price constants); any other value leaves unhinted
    shuffles to the runtime fallback (FlintConfig.shuffle_backend).
    ``limit`` caps the action merge (RDD.take / DataFrame.limit).
    ``share`` is a per-job view of the multi-tenant service's cross-job
    CSE registry (repro.svc.share) — None outside the service."""
    planner = _Planner(partition_multiplier, cse, cache_index,
                       default_transport, share=share)
    chain = planner.visit(node)
    stages = planner.stages
    stage_id = len(stages)
    tasks = [TaskDef(stage_id, i, inp, ops, None)
             for i, (inp, ops) in enumerate(
                 zip(chain.task_inputs, chain.ops_per_task))]
    stages.append(StagePlan(stage_id, tasks, None, action=action,
                            save_prefix=save_prefix, limit=limit,
                            producer_counts=chain.producer_counts))
    return stages
