"""Mini-cloudpickle: serialize task code (lambdas, closures, module refs)
for shipping to executors (paper §III: "the serialized code to execute").

Standard pickle refuses lambdas and local functions; Flint tasks are built
from exactly those. We serialize the code object with ``marshal`` plus the
pieces needed to rebuild the function: positional AND keyword-only
defaults, closure cells, and the referenced globals (recursively for
function-valued globals; by name for modules). Self- and mutually-
recursive functions are handled with a memo: a function re-encountered
while it is still being packed becomes a reference node, resolved back to
the (partially built) function object at unpack time. Scope is
intentionally bounded: anything else must already be picklable.
"""

from __future__ import annotations

import importlib
import marshal
import pickle
import types
from typing import Any

_FN_TAG = "__flint_fn__"
_MOD_TAG = "__flint_mod__"
_REF_TAG = "__flint_fnref__"


def _pack_cell(value, memo: dict):
    return _pack(value, memo)


def _pack(value: Any, memo: dict):
    if isinstance(value, types.ModuleType):
        return {_MOD_TAG: value.__name__}
    if isinstance(value, types.FunctionType):
        if id(value) in memo:
            # cycle (fact -> fact, even -> odd -> even): emit a reference
            # to the ancestor already being packed
            return {_REF_TAG: memo[id(value)]}
        return _pack_function(value, memo)
    return value


def _pack_function(fn: types.FunctionType, memo: dict) -> dict:
    uid = len(memo)
    memo[id(fn)] = uid
    code = fn.__code__
    globs = {}
    for name in code.co_names:
        if name in fn.__globals__:
            g = fn.__globals__[name]
            if isinstance(g, (types.FunctionType, types.ModuleType)):
                globs[name] = _pack(g, memo)
            else:
                try:
                    pickle.dumps(g)
                    globs[name] = g
                except Exception:
                    pass  # unpicklable global never touched at runtime, or KeyError later
    closure = None
    if fn.__closure__:
        closure = [_pack_cell(c.cell_contents, memo) for c in fn.__closure__]
    return {
        _FN_TAG: True,
        "id": uid,
        "code": marshal.dumps(code),
        "name": fn.__name__,
        "defaults": fn.__defaults__,
        "kwdefaults": fn.__kwdefaults__,
        "closure": closure,
        "globals": globs,
    }


def _unpack(value: Any, memo: dict):
    if isinstance(value, dict):
        if value.get(_FN_TAG):
            return _unpack_function(value, memo)
        if _REF_TAG in value:
            return memo[value[_REF_TAG]]  # ancestor registered before descent
        if _MOD_TAG in value:
            return importlib.import_module(value[_MOD_TAG])
    return value


def _unpack_function(packed: dict, memo: dict) -> types.FunctionType:
    code = marshal.loads(packed["code"])
    globs = {"__builtins__": __builtins__}
    # the function object must exist BEFORE its globals/closure unpack, so
    # reference nodes inside them can resolve to it; empty cells are
    # filled afterwards (cell_contents is writable)
    closure = None
    if packed["closure"] is not None:
        closure = tuple(types.CellType() for _ in packed["closure"])
    fn = types.FunctionType(code, globs, packed["name"], packed["defaults"],
                            closure)
    fn.__kwdefaults__ = packed.get("kwdefaults")
    if packed.get("id") is not None:
        memo[packed["id"]] = fn
    for k, v in packed["globals"].items():
        globs[k] = _unpack(v, memo)
    if closure is not None:
        for cell, v in zip(closure, packed["closure"]):
            cell.cell_contents = _unpack(v, memo)
    return fn


def dumps_fn(fn) -> bytes:
    """Serialize a callable (plain function, lambda, or closure)."""
    if not isinstance(fn, types.FunctionType):
        return pickle.dumps(fn)  # builtins / partials / callables
    return pickle.dumps(_pack_function(fn, {}))


def loads_fn(data: bytes):
    obj = pickle.loads(data)
    return _unpack(obj, {})
