"""Mini-cloudpickle: serialize task code (lambdas, closures, module refs)
for shipping to executors (paper §III: "the serialized code to execute").

Standard pickle refuses lambdas and local functions; Flint tasks are built
from exactly those. We serialize the code object with ``marshal`` plus the
pieces needed to rebuild the function: positional AND keyword-only
defaults, closure cells, and the referenced globals (recursively for
function-valued globals; by name for modules). Self- and mutually-
recursive functions are handled with a memo: a function re-encountered
while it is still being packed becomes a reference node, resolved back to
the (partially built) function object at unpack time. Closure cells and
globals holding CONTAINERS of functions (a list of compiled column
expressions, a dict of named handlers) are walked recursively — the SQL
layer's expression compiler closes over exactly those. Containers must be
acyclic. Scope is intentionally bounded: anything else must already be
picklable.
"""

from __future__ import annotations

import importlib
import marshal
import pickle
import struct
import types
from typing import Any

_FN_TAG = "__flint_fn__"
_MOD_TAG = "__flint_mod__"
_REF_TAG = "__flint_fnref__"
_SEQ_TAG = "__flint_seq__"  # list/tuple/dict carrying packed functions


def _pack_cell(value, memo: dict):
    return _pack(value, memo)


def _pack(value: Any, memo: dict):
    if isinstance(value, types.ModuleType):
        return {_MOD_TAG: value.__name__}
    if isinstance(value, types.FunctionType):
        if id(value) in memo:
            # cycle (fact -> fact, even -> odd -> even): emit a reference
            # to the ancestor already being packed
            return {_REF_TAG: memo[id(value)]}
        return _pack_function(value, memo)
    # EXACT list/tuple/dict only: a subclass (namedtuple, OrderedDict)
    # rebuilt from items would lose its type on the executor — those keep
    # the pre-existing pickle-by-value path. A container already on the
    # walk stack is CYCLIC: functions inside one can't be packed, so it
    # is left as-is for pickle (which handles cycles), same as before
    # containers were walked at all.
    if type(value) in (list, tuple):
        stack = memo.setdefault("_container_stack", set())
        if id(value) in stack:
            return value
        stack.add(id(value))
        try:
            packed = [_pack(v, memo) for v in value]
        finally:
            stack.discard(id(value))
        if any(p is not v for p, v in zip(packed, value)):
            kind = "list" if type(value) is list else "tuple"
            return {_SEQ_TAG: kind, "items": packed}
        return value
    if type(value) is dict and _SEQ_TAG not in value:
        stack = memo.setdefault("_container_stack", set())
        if id(value) in stack:
            return value
        stack.add(id(value))
        try:
            vals = {k: _pack(v, memo) for k, v in value.items()}
        finally:
            stack.discard(id(value))
        if any(vals[k] is not value[k] for k in value):
            return {_SEQ_TAG: "dict", "items": list(vals.items())}
        return value
    return value


def _referenced_names(code: types.CodeType) -> set:
    """Global names referenced by ``code`` INCLUDING its nested code
    objects — a comprehension or generator expression compiles to its own
    code object, and a global called from inside one (``sum(f(x) for x in
    v)``) appears only in the nested co_names."""
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names |= _referenced_names(const)
    return names


def _pack_function(fn: types.FunctionType, memo: dict) -> dict:
    uid = len(memo)
    memo[id(fn)] = uid
    code = fn.__code__
    globs = {}
    for name in sorted(_referenced_names(code)):
        if name in fn.__globals__:
            g = fn.__globals__[name]
            if isinstance(g, (types.FunctionType, types.ModuleType)):
                globs[name] = _pack(g, memo)
            else:
                packed = _pack(g, memo)  # containers of functions walk too
                if packed is not g:
                    globs[name] = packed
                    continue
                try:
                    pickle.dumps(g)
                    globs[name] = g
                except Exception:
                    pass  # unpicklable global never touched at runtime, or KeyError later
    closure = None
    if fn.__closure__:
        closure = [_pack_cell(c.cell_contents, memo) for c in fn.__closure__]
    return {
        _FN_TAG: True,
        "id": uid,
        "code": marshal.dumps(code),
        "name": fn.__name__,
        "defaults": fn.__defaults__,
        "kwdefaults": fn.__kwdefaults__,
        "closure": closure,
        "globals": globs,
    }


def _unpack(value: Any, memo: dict):
    if isinstance(value, dict):
        if value.get(_FN_TAG):
            return _unpack_function(value, memo)
        if _REF_TAG in value:
            return memo[value[_REF_TAG]]  # ancestor registered before descent
        if _MOD_TAG in value:
            return importlib.import_module(value[_MOD_TAG])
        if _SEQ_TAG in value:
            kind = value[_SEQ_TAG]
            if kind == "dict":
                return {k: _unpack(v, memo) for k, v in value["items"]}
            items = [_unpack(v, memo) for v in value["items"]]
            return items if kind == "list" else tuple(items)
    return value


def _unpack_function(packed: dict, memo: dict) -> types.FunctionType:
    code = marshal.loads(packed["code"])
    globs = {"__builtins__": __builtins__}
    # the function object must exist BEFORE its globals/closure unpack, so
    # reference nodes inside them can resolve to it; empty cells are
    # filled afterwards (cell_contents is writable)
    closure = None
    if packed["closure"] is not None:
        closure = tuple(types.CellType() for _ in packed["closure"])
    fn = types.FunctionType(code, globs, packed["name"], packed["defaults"],
                            closure)
    fn.__kwdefaults__ = packed.get("kwdefaults")
    if packed.get("id") is not None:
        memo[packed["id"]] = fn
    for k, v in packed["globals"].items():
        globs[k] = _unpack(v, memo)
    if closure is not None:
        for cell, v in zip(closure, packed["closure"]):
            cell.cell_contents = _unpack(v, memo)
    return fn


# ------------------------------------------------------- columnar codecs
#
# Typed-array column codecs for the shuffle's columnar record batches
# (core.shuffle.batch). A column is homogeneous when every element has the
# same CONCRETE type (bool is not int, 1.0 is not 1 — the partitioner may
# canonicalize, but the wire must round-trip values exactly). Schema
# grammar:
#
#   "i"  int64        "f"  float64      "b"  bool
#   "s"  utf-8 string (u16 length prefixes; "S" when any string is >64 KiB)
#   "t(a,b,...)"  fixed-arity tuple of columns, recursively
#   "l(a)"  ragged lists with a homogeneous element type (u32 per-value
#           lengths + one flattened element column); "l()" when every list
#           in the column is empty. groupByKey value-lists re-shuffled
#           downstream ride this instead of falling back to pickle framing.
#
# Anything else (mixed types, ints beyond int64, None, ...) has no schema;
# the batch falls back to length-prefixed pickle framing.

_INT64_MIN, _INT64_MAX = -(2**63), 2**63 - 1
_U32 = struct.Struct("<I")


def column_schema(values: list) -> str | None:
    """Schema of a homogeneous column, or None if the column is ragged."""
    t = type(values[0])
    if any(type(v) is not t for v in values):
        return None
    if t is int:
        if all(_INT64_MIN <= v <= _INT64_MAX for v in values):
            return "i"
        return None
    if t is float:
        return "f"
    if t is bool:
        return "b"
    if t is str:
        return ("s" if all(len(v.encode("utf-8")) <= 0xFFFF for v in values)
                else "S")
    if t is tuple:
        arity = len(values[0])
        if arity == 0 or any(len(v) != arity for v in values):
            return None
        subs = []
        for j in range(arity):
            sub = column_schema([v[j] for v in values])
            if sub is None:
                return None
            subs.append(sub)
        return "t(%s)" % ",".join(subs)
    if t is list:
        flat = [x for v in values for x in v]
        if not flat:
            return "l()"  # all-empty: lengths alone reconstruct
        sub = column_schema(flat)
        if sub is None:
            return None
        return "l(%s)" % sub
    return None


def column_conforms(schema: str, values: list) -> bool:
    """Cheap exact-type check of a column against a DECLARED schema.
    struct.pack would silently coerce (int -> float64, bool -> int64), so
    a declared-schema encode must verify concrete types first — the wire
    round-trips values exactly or not at all (mismatch => the caller
    falls back to sniffing)."""
    if schema == "i":
        return all(type(v) is int and _INT64_MIN <= v <= _INT64_MAX
                   for v in values)
    if schema == "f":
        return all(type(v) is float for v in values)
    if schema == "b":
        return all(type(v) is bool for v in values)
    if schema == "s":
        return all(type(v) is str and len(v.encode("utf-8")) <= 0xFFFF
                   for v in values)
    if schema == "S":
        return all(type(v) is str for v in values)
    if schema.startswith("t("):
        subs = _split_tuple_schema(schema)
        if not all(type(v) is tuple and len(v) == len(subs)
                   for v in values):
            return False
        return all(column_conforms(sub, [v[j] for v in values])
                   for j, sub in enumerate(subs))
    if schema.startswith("l("):
        if not all(type(v) is list for v in values):
            return False
        sub = schema[2:-1]
        flat = [x for v in values for x in v]
        if not sub:
            return not flat  # "l()" declares all-empty lists
        return column_conforms(sub, flat)
    return False


def _split_tuple_schema(schema: str) -> list[str]:
    """Top-level comma split of the "..." in "t(...)" (parens may nest)."""
    inner = schema[2:-1]
    subs, depth, start = [], 0, 0
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == "," and depth == 0:
            subs.append(inner[start:i])
            start = i + 1
    subs.append(inner[start:])
    return subs


def encode_column(schema: str, values: list) -> bytes:
    n = len(values)
    if schema == "i":
        return struct.pack("<%dq" % n, *values)
    if schema == "f":
        return struct.pack("<%dd" % n, *values)
    if schema == "b":
        return bytes(values)
    if schema == "s" or schema == "S":
        blobs = [v.encode("utf-8") for v in values]
        fmt = "<%dH" if schema == "s" else "<%dI"
        return struct.pack(fmt % n, *map(len, blobs)) + b"".join(blobs)
    if schema.startswith("t("):
        out = []
        for j, sub in enumerate(_split_tuple_schema(schema)):
            blob = encode_column(sub, [v[j] for v in values])
            out.append(_U32.pack(len(blob)))
            out.append(blob)
        return b"".join(out)
    if schema.startswith("l("):
        lengths = struct.pack("<%dI" % n, *map(len, values))
        sub = schema[2:-1]
        if not sub:  # "l()": every list is empty
            return lengths
        flat = [x for v in values for x in v]
        return lengths + encode_column(sub, flat)
    raise ValueError(f"unknown column schema {schema!r}")


def decode_column(schema: str, blob: bytes, n: int) -> list:
    if schema == "i":
        return list(struct.unpack("<%dq" % n, blob))
    if schema == "f":
        return list(struct.unpack("<%dd" % n, blob))
    if schema == "b":
        return [bool(b) for b in blob]
    if schema == "s" or schema == "S":
        width = 2 if schema == "s" else 4
        lens = struct.unpack_from(("<%dH" if schema == "s" else "<%dI") % n,
                                  blob)
        off = width * n
        out = []
        for ln in lens:
            out.append(blob[off:off + ln].decode("utf-8"))
            off += ln
        return out
    if schema.startswith("t("):
        cols, off = [], 0
        for sub in _split_tuple_schema(schema):
            (ln,) = _U32.unpack_from(blob, off)
            off += _U32.size
            cols.append(decode_column(sub, blob[off:off + ln], n))
            off += ln
        return list(zip(*cols))
    if schema.startswith("l("):
        lengths = struct.unpack_from("<%dI" % n, blob)
        sub = schema[2:-1]
        flat = (decode_column(sub, blob[4 * n:], sum(lengths))
                if sub else [])
        out, off = [], 0
        for ln in lengths:
            out.append(flat[off:off + ln])
            off += ln
        return out
    raise ValueError(f"unknown column schema {schema!r}")


def column_value_sizes(schema: str, values: list) -> list[int]:
    """Exact encoded bytes per value (framing prefixes excluded) — lets the
    batch packer split a column set under a byte cap without encoding
    speculative chunks."""
    if schema == "i" or schema == "f":
        return [8] * len(values)
    if schema == "b":
        return [1] * len(values)
    if schema == "s" or schema == "S":
        width = 2 if schema == "s" else 4
        return [width + len(v.encode("utf-8")) for v in values]
    if schema.startswith("t("):
        sizes = [0] * len(values)
        for j, sub in enumerate(_split_tuple_schema(schema)):
            for i, s in enumerate(
                    column_value_sizes(sub, [v[j] for v in values])):
                sizes[i] += s
        return sizes
    if schema.startswith("l("):
        sub = schema[2:-1]
        if not sub:
            return [4] * len(values)
        flat_sizes = iter(column_value_sizes(
            sub, [x for v in values for x in v]))
        return [4 + sum(next(flat_sizes) for _ in v) for v in values]
    raise ValueError(f"unknown column schema {schema!r}")


def dumps_fn(fn) -> bytes:
    """Serialize a callable (plain function, lambda, or closure)."""
    if not isinstance(fn, types.FunctionType):
        return pickle.dumps(fn)  # builtins / partials / callables
    return pickle.dumps(_pack_function(fn, {}))


def loads_fn(data: bytes):
    obj = pickle.loads(data)
    return _unpack(obj, {})
