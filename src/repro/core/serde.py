"""Mini-cloudpickle: serialize task code (lambdas, closures, module refs)
for shipping to executors (paper §III: "the serialized code to execute").

Standard pickle refuses lambdas and local functions; Flint tasks are built
from exactly those. We serialize the code object with ``marshal`` plus the
pieces needed to rebuild the function: defaults, closure cells, and the
referenced globals (recursively for function-valued globals; by name for
modules). Scope is intentionally bounded: anything else must already be
picklable.
"""

from __future__ import annotations

import importlib
import marshal
import pickle
import types
from typing import Any

_FN_TAG = "__flint_fn__"
_MOD_TAG = "__flint_mod__"


def _pack_cell(value):
    return _pack(value)


def _pack(value: Any):
    if isinstance(value, types.ModuleType):
        return {_MOD_TAG: value.__name__}
    if isinstance(value, types.FunctionType):
        return _pack_function(value)
    return value


def _pack_function(fn: types.FunctionType) -> dict:
    code = fn.__code__
    globs = {}
    for name in code.co_names:
        if name in fn.__globals__:
            g = fn.__globals__[name]
            if isinstance(g, (types.FunctionType, types.ModuleType)):
                globs[name] = _pack(g)
            else:
                try:
                    pickle.dumps(g)
                    globs[name] = g
                except Exception:
                    pass  # unpicklable global never touched at runtime, or KeyError later
    closure = None
    if fn.__closure__:
        closure = [_pack_cell(c.cell_contents) for c in fn.__closure__]
    return {
        _FN_TAG: True,
        "code": marshal.dumps(code),
        "name": fn.__name__,
        "defaults": fn.__defaults__,
        "closure": closure,
        "globals": globs,
    }


def _unpack(value: Any):
    if isinstance(value, dict) and value.get(_FN_TAG):
        return _unpack_function(value)
    if isinstance(value, dict) and _MOD_TAG in value:
        return importlib.import_module(value[_MOD_TAG])
    return value


def _unpack_function(packed: dict) -> types.FunctionType:
    code = marshal.loads(packed["code"])
    globs = {"__builtins__": __builtins__}
    for k, v in packed["globals"].items():
        globs[k] = _unpack(v)
    closure = None
    if packed["closure"] is not None:
        closure = tuple(types.CellType(_unpack(v)) for v in packed["closure"])
    fn = types.FunctionType(code, globs, packed["name"], packed["defaults"],
                            closure)
    return fn


def dumps_fn(fn) -> bytes:
    """Serialize a callable (plain function, lambda, or closure)."""
    if not isinstance(fn, types.FunctionType):
        return pickle.dumps(fn)  # builtins / partials / callables
    return pickle.dumps(_pack_function(fn))


def loads_fn(data: bytes):
    obj = pickle.loads(data)
    return _unpack(obj)
