"""Flint core — serverless analytics engine (the paper's contribution).

Public API mirrors the PySpark surface the paper targets, on BOTH the RDD
and the structured DataFrame surfaces:

    from repro.core import FlintContext
    ctx = FlintContext()                      # serverless backend
    ctx.upload("taxi.csv", data_bytes)        # stand-in for S3
    arr = (ctx.textFile("taxi.csv", 32)
              .map(lambda x: x.split(','))
              .filter(lambda x: inside(x, goldman))
              .map(lambda x: (get_hour(x[2]), 1))
              .reduceByKey(lambda a, b: a + b, 30)
              .collect())
    print(ctx.cost_report())                  # pure pay-as-you-go USD

    from repro.sql import Schema, col, lit, sum_, count_
    df = ctx.read_csv("taxi.csv", Schema([("pickup", "str"), ...]), 32)
    rows = (df.where(col("payment_type") == lit("credit"))
              .withColumn("hour", col("pickup").substr(12, 2))
              .groupBy("hour")
              .agg(sum_(col("tip")).alias("tips"), count_().alias("n"))
              .collect())
    print(df.explain())                       # optimized logical plan

The DataFrame surface (docs/dataframe.md) carries schemas through a
logical plan, optimizes it (projection pruning, predicate/limit pushdown,
map-side-combine selection, cost-model transport choice), and lowers onto
the same RDD lineage — scheduler, EOS shuffle, transports, CSE and
cache() all apply unchanged.

Backends: "flint" (Lambda+SQS simulation, pay-per-use), "cluster"
(provisioned Spark, per-second billing), "pyspark" (cluster + the
JVM<->Python record pipe overhead).
"""

from __future__ import annotations

from typing import Any

from repro.core.costs import CostLedger, cluster_cost
from repro.core.dag import build_plan
from repro.core.executors import FlintConfig
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.queues import ObjectStoreSim
from repro.core.rdd import RDD, ParallelCollection, Source
from repro.core.cluster import ClusterScheduler
from repro.core.scheduler import FlintScheduler, StageFailure


class FlintContext:
    def __init__(self, backend: str = "flint",
                 config: FlintConfig | None = None, *,
                 fault_plan: FaultPlan | dict | None = None,
                 elastic_retries: int = 2,
                 store: ObjectStoreSim | None = None,
                 ledger: CostLedger | None = None,
                 cache_index=None,
                 verbose: bool = False):
        self.config = config or FlintConfig()
        self.config.validate()  # reject incoherent resilience knobs early
        self.backend_name = backend
        self.ledger = ledger if ledger is not None else CostLedger()
        self.store = store or ObjectStoreSim(self.ledger)
        self.fault_plan = fault_plan or {}
        self.elastic_retries = elastic_retries
        self.verbose = verbose
        self.partition_multiplier = 1
        self.last_scheduler = None
        self._collection_counter = 0
        # RDD.cache() registry: lineage token -> {"nparts", "ready"}.
        # Owned by the context (caches span actions/schedulers); the
        # job-scoped GC keeps only keys registered here. The multi-tenant
        # service substitutes its byte-capped SharedCache (repro.svc) —
        # same mapping protocol, shared across every session
        self._cache_index = (cache_index if cache_index is not None
                             else {})

    # -------------------------------------------------------------- data
    def upload(self, key: str, data: bytes):
        self.store.put(key, data)

    def textFile(self, key: str, numPartitions: int = 8) -> RDD:
        return Source(self, key, numPartitions)

    def read_csv(self, key: str, schema, numPartitions: int = 8):
        """Structured entry point: a DataFrame over a CSV object in the
        store, with a declared schema (repro.sql.Schema or a list of
        (name, dtype) pairs) — see docs/dataframe.md."""
        from repro.sql import DataFrame  # lazy: sql imports core
        return DataFrame.from_csv(self, key, schema, numPartitions)

    def parallelize(self, data: list, numPartitions: int = 8) -> RDD:
        key = f"_collections/{self._collection_counter}"
        self._collection_counter += 1
        n = len(data)
        step = max(1, -(-n // numPartitions))
        parts = [data[i * step:(i + 1) * step] for i in range(numPartitions)]
        while len(parts) < numPartitions:
            parts.append([])
        for i, p in enumerate(parts):
            self.store.put_obj(f"{key}/{i}", p)
        return ParallelCollection(self, key, numPartitions)

    # --------------------------------------------------------- execution
    def _make_scheduler(self):
        if self.backend_name == "flint":
            return FlintScheduler(self.config, self.ledger, self.store,
                                  fault_plan=self.fault_plan,
                                  verbose=self.verbose,
                                  cache_index=self._cache_index)
        if self.backend_name == "cluster":
            return ClusterScheduler(self.config, self.ledger, self.store)
        if self.backend_name == "pyspark":
            return ClusterScheduler(self.config, self.ledger, self.store,
                                    pipe_overhead=True)
        raise ValueError(f"unknown backend {self.backend_name!r}")

    def run_action(self, rdd: RDD, action: str,
                   save_prefix: str | None = None,
                   limit: int | None = None) -> Any:
        mult = self.partition_multiplier
        elastic_left = self.elastic_retries
        # lost durable cache data is recovered by replanning the cached
        # lineage from source — bounded like any stage resubmission
        cache_replans_left = self.config.max_stage_retries
        while True:
            plan = self._build_plan(rdd, action, save_prefix, mult, limit)
            sched = self._make_scheduler()
            self.last_scheduler = sched
            try:
                result = sched.run(plan)
                # materializations this action teed to _cache/ are now
                # durable and complete — later actions may plan from them
                self._mark_caches_ready(plan)
                return result
            except StageFailure as e:
                # a failed materializing action must not pin its partial
                # _cache/ batches: drop the still-pending registrations so
                # the job GC (scheduler shutdown, below) sweeps them; an
                # elastic retry re-registers on the re-plan
                self._unregister_pending_caches(plan)
                if (e.error_type == "MemoryCapExceeded"
                        and elastic_left > 0):
                    # the paper's elasticity move: more partitions, re-run
                    elastic_left -= 1
                    mult *= 2
                    self.partition_multiplier = mult
                    if self.verbose:
                        print(f"[flint] memory cap hit -> partitions x{mult}")
                    continue
                if (e.error_type == "LostCacheInput"
                        and cache_replans_left > 0):
                    # an acknowledged _cache/ batch vanished: retrying the
                    # reading task cannot recreate durable data, so drop
                    # the damaged materialization and replan — the next
                    # plan rebuilds the cached lineage from source and
                    # re-materializes it (docs/fault_tolerance.md)
                    cache_replans_left -= 1
                    token = (e.detail or {}).get("token", "")
                    self._cache_index.pop(token, None)
                    self.store.delete_prefix(f"_cache/{token}/")
                    if self.verbose:
                        print(f"[flint] cache {token or '?'} lost -> "
                              f"replanning from source")
                    continue
                raise
            finally:
                sched.shutdown()

    def _build_plan(self, rdd, action, save_prefix, mult, limit):
        """Planning hook: the service session overrides this to thread
        its cross-job share-registry view into the planner."""
        return build_plan(rdd, action, save_prefix,
                          partition_multiplier=mult,
                          cse=self.config.plan_cse,
                          cache_index=self._cache_index,
                          default_transport=self.config.shuffle_backend,
                          limit=limit)

    def _plan_cache_tokens(self, plan):
        return {arg[0] for stage in plan for task in stage.tasks
                for kind, arg in task.ops if kind == "cache"}

    def _mark_caches_ready(self, plan):
        committed = getattr(self._cache_index, "committed", None)
        for token in self._plan_cache_tokens(plan):
            entry = self._cache_index.get(token)
            if entry is not None:
                entry["ready"] = True
                if committed is not None:
                    # byte-capped shared cache (repro.svc): size the new
                    # materialization and evict LRU entries over the cap
                    committed(token)

    def _unregister_pending_caches(self, plan):
        for token in self._plan_cache_tokens(plan):
            entry = self._cache_index.get(token)
            if entry is not None and not entry.get("ready"):
                del self._cache_index[token]

    def clear_cache(self) -> int:
        """Drop every RDD.cache() materialization (billed free DELETEs);
        returns the number of keys removed. A byte-capped shared index
        (repro.svc.SharedCache) clears through its own ``drop_all`` so
        entries pinned by running jobs survive."""
        drop_all = getattr(self._cache_index, "drop_all", None)
        if drop_all is not None:
            return drop_all()
        self._cache_index.clear()
        return self.store.delete_prefix("_cache/")

    def uncache(self, token: str) -> int:
        """Drop ONE cached lineage's materialization by token (see
        ``RDD.uncache``); returns the number of keys removed. No-op on
        an unknown or already-dropped token."""
        drop = getattr(self._cache_index, "drop", None)
        if drop is not None:
            return drop(token)
        if self._cache_index.pop(token, None) is None:
            return 0
        return self.store.delete_prefix(f"_cache/{token}/")

    # ------------------------------------------------------------- costs
    def cost_report(self) -> dict:
        rep = self.ledger.report()
        if self.backend_name in ("cluster", "pyspark") and self.last_scheduler:
            wall = getattr(self.last_scheduler, "wall_seconds", 0.0)
            rep["cluster_usd"] = round(cluster_cost(wall), 6)
            rep["total_usd"] = rep["cluster_usd"]
        return rep


__all__ = ["FlintContext", "FlintConfig", "FlintScheduler", "ClusterScheduler",
           "CostLedger", "StageFailure", "FaultPlan", "FaultInjector",
           "build_plan"]
