"""The Flint executor — a process inside a (simulated) Lambda invocation —
plus the Lambda runtime simulation itself.

Semantics preserved from the paper (§III-A/B):
  * one task per invocation; executors are stateless between invocations;
  * input iterator reads an S3 byte range (stage 0) or drains a shuffle
    transport (intermediate stages) — a pluggable backend behind the
    ``core.shuffle.ShuffleTransport`` contract, chosen per shuffle
    (``ShuffleWrite.transport`` hint, default ``cfg.shuffle_backend``).
    Both execution modes terminate the drain on per-producer EOS at the
    plan-time quorum (docs/eos_shuffle.md); dedup of at-least-once,
    unordered delivery by (producer task, sequence id) is shared drain
    state, and ACK-AFTER-FOLD (docs/shuffle_transports.md) means the
    drained input is released only once the task's OUTPUT is durable;
  * outputs are hash-partitioned, buffered in memory, and FLUSHED to the
    transport as columnar record batches (shuffle.batch) when the buffer
    grows past its cap (the 3008 MB limit made concrete as a record-count
    proxy);
  * executor CHAINING: when the invocation lease is nearly exhausted the
    executor stops ingesting, flushes, and returns a continuation cursor
    that the scheduler re-invokes on a warm container (map-side combine
    partials are safe to flush early because combiners are associative);
  * responses above the payload cap spill to the object store (6 MB cap,
    both directions).

Failure injection + the record-count lease hook make chaining, retry and
straggler behavior deterministic in tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import pickle
import threading
import time
import zlib
from typing import Any

from repro.core import serde
from repro.core.costs import (LAMBDA_PAYLOAD_LIMIT,
                              S3_EXCHANGE_BATCH_LIMIT, CostLedger)
from repro.core.dag import (CacheInput, CollectionInput, ShuffleRead,
                            SourceInput, TaskDef)
from repro.core.faults import ConcurrencyGauge
from repro.core.queues import ObjectStoreSim, SQSSim
from repro.core.retry import (RetryBudget, RetryBudgetExhausted,
                              RetryExhausted, RetryingStore, RetryPolicy,
                              TransientServiceError)
from repro.core.shuffle import (KVBatch, TransportSet, iter_records,
                                pack_batch, pack_batch_columns, queue_name,
                                unpack_batch)
from repro.core.shuffle.base import AbortedError  # noqa: F401 (re-export:
#                       pre-subsystem callers import it from here)
from repro.core.shuffle.base import LostShuffleInput


class InjectedFailure(RuntimeError):
    pass


class InvocationTimeout(RuntimeError):
    """The invocation lease expired mid-task: the container is killed with
    no final flush — whatever full batches already flushed are durable
    (partial shuffle writes LAND), and the retry re-emits byte-identical
    batches that downstream (src, seq) dedup absorbs."""


class LostCacheInput(RuntimeError):
    """A cache partition's manifest disagrees with the batches actually on
    the store: a materialized batch was acknowledged and then lost.
    Retrying the reading task cannot help — the context must replan and
    re-materialize the cached lineage (docs/fault_tolerance.md)."""

    def __init__(self, msg: str, token: str = ""):
        super().__init__(msg)
        self.detail = {"token": token}


class LostBroadcastInput(RuntimeError):
    """A broadcast object's manifest disagrees with the batches actually
    on the store: the small-side data a broadcast hash join depends on
    was acknowledged and then lost. Retrying the reading task cannot
    help — the scheduler must re-run the small side's lineage and
    re-publish the broadcast (docs/adaptive_execution.md)."""

    def __init__(self, msg: str, prefix: str = ""):
        super().__init__(msg)
        self.detail = {"broadcast_prefix": prefix}


class MemoryCapExceeded(RuntimeError):
    """Aggregation state outgrew the executor memory cap — the paper's
    answer is elasticity: raise the partition count and re-run."""


@dataclasses.dataclass
class FlintConfig:
    memory_mb: int = 3008
    time_limit_s: float = 300.0
    # default intermediate-data transport: "auto" lets the planner pick
    # SQS or the Lambada-style S3 exchange PER SHUFFLE from estimated
    # volume and the cost model (docs/dataframe.md); "sqs" (the paper's
    # choice) or "s3" pin one engine-wide. A ShuffleWrite.transport hint
    # overrides either, per shuffle. The env var lets CI run the whole
    # tier-1 suite under each backend without touching test code.
    shuffle_backend: str = dataclasses.field(
        default_factory=lambda: os.environ.get("FLINT_SHUFFLE_BACKEND",
                                               "auto"))
    # frame shuffle batches as typed key/value columns where the data is
    # homogeneous (shuffle.batch); False forces per-record pickle framing
    # everywhere (the pre-columnar wire format, kept for A/B measurement)
    columnar_batches: bool = True
    # pipelined stage execution: launch consumer tasks concurrently with
    # their producers; consumers terminate on per-producer EOS control
    # messages. False restores barrier scheduling (A/B comparison).
    pipeline_stages: bool = True
    # plan-time common-subexpression elimination: shared lineages
    # (self-joins, diamonds, unions of two derivations) plan ONE producer
    # stage with per-read-site consumer groups. False restores the
    # one-consumer-per-shuffle planner (A/B comparison).
    plan_cse: bool = True
    # adaptive query execution (docs/adaptive_execution.md): collect
    # per-stage shuffle-output statistics and re-optimize the REMAINING
    # plan at stage boundaries — broadcast-join conversion, tiny-partition
    # coalescing, measured-volume transport re-choice, and the sampled
    # range partitioner behind distributed orderBy. False freezes the
    # static plan (A/B comparison).
    adaptive: bool = dataclasses.field(
        default_factory=lambda: os.environ.get("FLINT_ADAPTIVE",
                                               "1") not in ("0", "false"))
    # measured small-side cap for switching a planned shuffle join to a
    # broadcast hash join (the small side ships as a content-addressed
    # _broadcast/ object every map task reads — no shuffle for either
    # side, the join fuses into the large side's producer stage)
    broadcast_threshold_bytes: int = 512 * 2**10
    # coalesce adjacent reduce partitions whose measured input falls
    # below this floor into one consumer task (0 disables)
    coalesce_min_bytes: int = 16 * 2**10
    # vectorized columnar execution (docs/vectorized_execution.md): the SQL
    # lowering fuses scan→filter→project→partial-agg chains into one
    # batch-in/batch-out operator evaluating whole column arrays; False
    # keeps the pure-Python per-row closures (A/B comparison). The backend
    # picks the array engine for grouped aggregation ("numpy", or "jax" to
    # route integer sums through kernels/ — see kernels.ops.grouped_reduce).
    vectorize: bool = True
    vector_backend: str = dataclasses.field(
        default_factory=lambda: os.environ.get("FLINT_VECTOR_BACKEND",
                                               "numpy"))
    vector_batch_rows: int = 8192  # rows per column batch in fused ops
    lease_safety: float = 0.8  # stop ingesting at this fraction of the lease
    concurrency: int = 80
    cold_start_s: float = 0.4
    warm_start_s: float = 0.01
    start_latency_scale: float = 0.0  # 0 => don't actually sleep in tests
    flush_records: int = 20_000  # shuffle buffer cap (memory proxy)
    agg_memory_records: int = 2_000_000  # consumer-side aggregation cap
    max_records_per_invoke: int = 0  # test hook: deterministic chaining
    max_task_retries: int = 3
    speculation_factor: float = 4.0  # straggler duplicate threshold
    speculation_min_done: int = 4
    drain_timeout_s: float = 30.0
    # SQS visibility timeout: how long a received-but-unacked message stays
    # invisible before redelivery. Must stay below drain_timeout_s or a
    # retried consumer times out waiting for its predecessor's claims to
    # expire.
    visibility_timeout_s: float = 10.0
    duplicate_prob: float = 0.0  # SQS at-least-once duplication rate
    chunk_fetch_bytes: int = 4 * 2**20
    # --- resilience knobs (docs/fault_tolerance.md) ---
    # lineage recovery: how many times one producing stage may be
    # resubmitted to re-create permanently missing exchange/cache input
    max_stage_retries: int = 2
    # service-call retry layer: per-call attempt cap, decorrelated-jitter
    # backoff bounds, and the job-wide retry budget
    retry_max_attempts: int = 5
    retry_base_s: float = 0.002
    retry_cap_s: float = 0.05
    retry_budget: int = 100_000
    # scheduler dispatch backoff after a 429-throttled invocation
    dispatch_backoff_base_s: float = 0.05
    dispatch_backoff_cap_s: float = 1.0

    @property
    def fallback_backend(self) -> str:
        """Concrete transport for shuffles whose plan carries no resolved
        hint. The planner resolves "auto" per shuffle at plan time; this
        runtime fallback only fires for hand-built plans, where it keeps
        the paper's SQS default."""
        return "sqs" if self.shuffle_backend == "auto" \
            else self.shuffle_backend

    @property
    def invocation_timeout_s(self) -> float:
        """The Lambda lease: a task is killed this many seconds in."""
        return self.time_limit_s

    def validate(self):
        """Reject incoherent resilience knobs at construction, mirroring
        the scheduler's visibility_timeout_s < drain_timeout_s check."""
        if self.retry_budget <= 0:
            raise ValueError(
                f"retry_budget must be > 0, got {self.retry_budget}")
        if self.retry_max_attempts < 1:
            raise ValueError(f"retry_max_attempts must be >= 1, got "
                             f"{self.retry_max_attempts}")
        if not 0 < self.retry_base_s <= self.retry_cap_s:
            raise ValueError(
                f"retry backoff must satisfy 0 < retry_base_s <= "
                f"retry_cap_s, got base {self.retry_base_s} / cap "
                f"{self.retry_cap_s}")
        if not 0 < self.dispatch_backoff_base_s <= self.dispatch_backoff_cap_s:
            raise ValueError(
                f"dispatch backoff must satisfy 0 < base <= cap, got base "
                f"{self.dispatch_backoff_base_s} / cap "
                f"{self.dispatch_backoff_cap_s}")
        if self.max_stage_retries < 0:
            raise ValueError(f"max_stage_retries must be >= 0, got "
                             f"{self.max_stage_retries}")
        if self.vector_backend not in ("numpy", "jax"):
            raise ValueError(f"vector_backend must be 'numpy' or 'jax', "
                             f"got {self.vector_backend!r}")
        if self.vector_batch_rows < 1:
            raise ValueError(f"vector_batch_rows must be >= 1, got "
                             f"{self.vector_batch_rows}")
        if self.broadcast_threshold_bytes < 0:
            raise ValueError(f"broadcast_threshold_bytes must be >= 0, "
                             f"got {self.broadcast_threshold_bytes}")
        if self.coalesce_min_bytes < 0:
            raise ValueError(f"coalesce_min_bytes must be >= 0, got "
                             f"{self.coalesce_min_bytes}")
        if self.drain_timeout_s >= self.invocation_timeout_s * self.lease_safety:
            # a drain allowed to out-wait the invocation lease converts
            # every slow producer into an invocation timeout instead of a
            # clean drain timeout — the same shape of incoherence as
            # visibility_timeout_s >= drain_timeout_s
            raise ValueError(
                f"drain_timeout_s ({self.drain_timeout_s}) must be < "
                f"invocation_timeout_s * lease_safety "
                f"({self.invocation_timeout_s} * {self.lease_safety}) or "
                f"consumers time out their own invocation before the drain "
                f"deadline can fire")


# --------------------------------------------------------------- payloads


def serialize_task(task: TaskDef, attempt: int, extra: dict | None = None
                   ) -> dict:
    # a ("cache", (token, nparts, index)), ("limit", n) or ("bcjoin",
    # spec) op carries plan data, not a user function — it ships as-is
    ops = [(kind, fn if kind in ("cache", "limit", "bcjoin")
            else serde.dumps_fn(fn))
           for kind, fn in task.ops]
    inp = task.input
    if isinstance(inp, ShuffleRead) and inp.combine_fn is not None:
        inp = dataclasses.replace(inp, combine_fn=serde.dumps_fn(inp.combine_fn))
    write = task.write
    if write is not None and (write.combine_fn is not None
                              or write.partition_fn is not None):
        write = dataclasses.replace(
            write,
            combine_fn=(serde.dumps_fn(write.combine_fn)
                        if write.combine_fn is not None else None),
            partition_fn=(serde.dumps_fn(write.partition_fn)
                          if write.partition_fn is not None else None))
    return {"stage": task.stage_id, "index": task.index, "input": inp,
            "ops": ops, "write": write, "attempt": attempt,
            **(extra or {})}


# ------------------------------------------------------------ the Lambda


class LambdaSim:
    """Invocation environment: containers (cold/warm), leases, payload caps,
    per-invocation billing."""

    def __init__(self, cfg: FlintConfig, ledger: CostLedger,
                 store: ObjectStoreSim, sqs: SQSSim,
                 transports: TransportSet | None = None, *,
                 faults=None, budget: RetryBudget | None = None,
                 gauge=None):
        self.cfg = cfg
        self.ledger = ledger
        self.store = store
        self.sqs = sqs
        self.transports = transports or TransportSet(cfg, ledger, store, sqs)
        # chaos admission hook (FaultInjector) + the executors' retrying
        # view of the store: every in-task store access rides rstore so
        # transient S3 errors are absorbed by the call-level retry layer
        self.faults = faults
        self.rstore = RetryingStore(store, RetryPolicy.from_config(
            cfg, budget=budget))
        self._warm = 0
        self._lock = threading.Lock()
        # account-concurrency gauge: private by default; the multi-tenant
        # service passes ONE shared ConcurrencyGauge so every session's
        # in-flight invocations count against the same account cap
        self.gauge = gauge if gauge is not None else ConcurrencyGauge()
        # key-space scope for this sim's transient spill keys ("" outside
        # the service; "j{n}/" per job under it, so the job-scoped GC can
        # sweep _payload/_result without touching other live jobs' keys)
        self.scope = ""
        self.invocations = 0
        self.cold_starts = 0
        self.throttles = 0

    def _acquire_container(self) -> bool:
        """Returns True on a cold start."""
        with self._lock:
            self.invocations += 1
            if self._warm > 0:
                self._warm -= 1
                return False
            self.cold_starts += 1
            return True

    def _release_container(self):
        with self._lock:
            self._warm += 1

    def invoke(self, payload: dict) -> dict:
        # the account-concurrency gauge counts this invocation from request
        # arrival (incremented BEFORE the admission check, so simultaneous
        # dispatches see each other) until the response is produced
        running = self.gauge.enter()
        try:
            return self._invoke(payload, running)
        finally:
            self.gauge.exit()

    def _invoke(self, payload: dict, running: int) -> dict:
        if self.faults is not None:
            # admission control BEFORE any container is acquired: a 429
            # never runs (and never bills GB-seconds)
            kind = self.faults.invoke_fault(
                payload.get("stage", -1), payload.get("index", -1),
                payload.get("attempt", 0), running)
            if kind == "throttle":
                with self._lock:
                    self.throttles += 1
                self.ledger.add_lambda_throttle()
                return {"status": "throttled", "error_type": "Throttled",
                        "error": "Rate exceeded (429)"}
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > LAMBDA_PAYLOAD_LIMIT:
            # paper §III-B: split/spill oversized payloads through S3
            key = (f"_payload/{self.scope}{payload['stage']}/"
                   f"{payload['index']}/{time.monotonic_ns()}")
            try:
                self.rstore.put(key, blob)
            except (RetryExhausted, RetryBudgetExhausted) as e:
                # the invocation request itself failed — no container ran
                return {"status": "error", "error_type": type(e).__name__,
                        "error": str(e)}
            payload = {"spilled": key}
        cold = self._acquire_container()
        start = (self.cfg.cold_start_s if cold else self.cfg.warm_start_s)
        if self.cfg.start_latency_scale > 0:
            time.sleep(start * self.cfg.start_latency_scale)
        t0 = time.monotonic()
        try:
            if "spilled" in payload:
                payload = pickle.loads(self.rstore.get(payload["spilled"]))
            if self.faults is not None:
                t = self.faults.timeout_after(payload.get("stage", -1),
                                              payload.get("index", -1),
                                              payload.get("attempt", 0))
                if t:
                    payload = dict(payload, timeout_after_records=t)
            resp = executor_main(payload, self)
        except (InjectedFailure, InvocationTimeout, MemoryCapExceeded,
                AbortedError, TimeoutError, KeyError, LostShuffleInput,
                LostCacheInput, LostBroadcastInput, RetryExhausted,
                RetryBudgetExhausted, TransientServiceError) as e:
            resp = {"status": "error", "error_type": type(e).__name__,
                    "error": str(e)}
            detail = getattr(e, "detail", None)
            if detail:
                resp["detail"] = detail
        finally:
            # billed for the time actually consumed — an invocation
            # timeout bills what ran, not the full lease
            duration = time.monotonic() - t0 + start
            self.ledger.add_lambda(duration, self.cfg.memory_mb)
            self._release_container()
        resp.setdefault("duration_s", time.monotonic() - t0)
        blob = pickle.dumps(resp, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > LAMBDA_PAYLOAD_LIMIT:
            key = f"_result/{self.scope}{time.monotonic_ns()}"
            try:
                self.rstore.put(key, blob)
            except (RetryExhausted, RetryBudgetExhausted) as e:
                return {"status": "error", "error_type": type(e).__name__,
                        "error": str(e),
                        "duration_s": resp["duration_s"]}
            resp = {"status": resp.get("status", "ok"), "spilled": key,
                    "duration_s": resp["duration_s"]}
        return resp


# ------------------------------------------------------ executor internals


class _Lease:
    def __init__(self, cfg: FlintConfig):
        self.deadline = time.monotonic() + cfg.time_limit_s * cfg.lease_safety
        self.max_records = cfg.max_records_per_invoke or None
        self.records = 0

    def consumed(self, n: int = 1) -> bool:
        """Count ingested records; True when the lease is exhausted."""
        self.records += n
        if self.max_records is not None and self.records >= self.max_records:
            return True
        if (self.records & 0xFF) == 0 and time.monotonic() > self.deadline:
            return True
        return False


class _SourceReader:
    """Line records over a byte range with Hadoop LineRecordReader
    semantics: a non-first split always skips its first (possibly partial)
    line, and every split reads lines whose start offset is <= end — so the
    line starting exactly at a boundary belongs to the EARLIER split.
    ``consumed_until`` is the absolute offset of the first unconsumed line
    (the chaining cursor)."""

    def __init__(self, inp: SourceInput, store: ObjectStoreSim,
                 cfg: FlintConfig, resume_offset: int | None):
        self.inp = inp
        self.store = store
        self.cfg = cfg
        self.offset = resume_offset  # absolute byte offset to resume at
        self.consumed_until = resume_offset if resume_offset is not None \
            else inp.start

    def _find_line_start(self, pos: int) -> int:
        """First line start at or after pos (skipping a partial line)."""
        scan = pos
        while scan < self.inp.size:
            probe = self.store.get(self.inp.key, scan,
                                   min(self.inp.size,
                                       scan + self.cfg.chunk_fetch_bytes))
            nl = probe.find(b"\n")
            if nl >= 0:
                return scan + nl + 1
            scan += len(probe)
        return self.inp.size

    def __iter__(self):
        inp, store, chunk = self.inp, self.store, self.cfg.chunk_fetch_bytes
        if self.offset is not None:
            line_start = self.offset
        elif inp.start == 0:
            line_start = 0
        else:
            line_start = self._find_line_start(inp.start)
        self.consumed_until = line_start
        pos = line_start  # next byte to fetch
        carry = b""
        while line_start <= inp.end:
            if pos >= inp.size:
                if carry and line_start <= inp.end:
                    # final line without trailing newline
                    self.consumed_until = inp.size
                    yield carry.decode("utf-8", "replace")
                return
            data = store.get(inp.key, pos, min(inp.size, pos + chunk))
            pos += len(data)
            data = carry + data
            lines = data.split(b"\n")
            carry = lines.pop()
            for ln in lines:
                if line_start > inp.end:
                    return
                line_start += len(ln) + 1
                self.consumed_until = line_start
                yield ln.decode("utf-8", "replace")


def _stable_order(rec) -> bytes:
    """Deterministic total order on records (their pickle bytes) — used to
    make a shuffle-reading task's re-emission byte-identical across
    attempts whose drains arrived in different orders."""
    return pickle.dumps(rec, protocol=pickle.HIGHEST_PROTOCOL)


def _read_transport_name(read: ShuffleRead, sid: int, cfg: FlintConfig
                         ) -> str:
    """The per-shuffle transport hint recorded at plan time, falling back
    to the engine default."""
    return (read.transports or {}).get(sid) or cfg.fallback_backend


def _drain_shuffle(read: ShuffleRead, env: LambdaSim, n_producers: dict, *,
                   sort_groups: bool = False) -> tuple:
    """Drain this partition's shuffle input(s) through their transports,
    folding each record batch into the aggregate AS IT ARRIVES (streaming —
    transport time overlaps the fold). Termination, dedup of at-least-once
    unordered delivery, claim leases and abort detection all live in the
    transport's DrainHandle; the per-producer EOS quorum comes from
    ``n_producers`` (fixed at plan time) in BOTH scheduler modes.

    Returns ({(sid, mode): folded-aggregate}, stats, ack) where ``ack``
    releases every drained input for good — the caller invokes it only
    once the task's output is durable (ack-after-fold), so an earlier
    death leaves the whole input to redeliver for the retry.

    ``sort_groups`` (set when this task WRITES another shuffle): group/
    join value-lists collect in arrival order, which differs across
    attempts — sort them so the records this task re-emits are
    byte-identical and downstream (src, seq) dedup stays sound."""
    out = {}
    stats = {"messages": 0, "duplicates": 0, "records": 0}
    combine = (serde.loads_fn(read.combine_fn)
               if isinstance(read.combine_fn, bytes) else read.combine_fn)

    def fold(agg, records, mode):
        if mode == "agg":
            for k, v in records:
                agg[k] = combine(agg[k], v) if k in agg else v
        elif mode in ("group", "join"):
            for k, v in records:
                agg.setdefault(k, []).append(v)
        else:  # repart
            agg.extend(records)
        if (mode in ("agg", "group", "join")
                and len(agg) > env.cfg.agg_memory_records):
            raise MemoryCapExceeded(
                f"aggregation state {len(agg)} records > cap "
                f"{env.cfg.agg_memory_records}")

    # the task-scoped claim group: a join drains two shuffles in sequence,
    # and lease-based transports must keep the first drain's claims alive
    # through the second's folds (heartbeats extend the whole group)
    claim_group: list = []
    handles = []
    groups = read.groups or [0] * len(read.parts)
    # adaptive coalescing: one task may drain SEVERAL contiguous producer
    # partitions (read.partitions), folding them in listed order into one
    # aggregate — repart streams stay globally ordered because the merge
    # concatenates in partition-index order
    partitions = read.partitions or [read.partition]
    for (sid, mode), consumer_group in zip(read.parts, groups):
        transport = env.transports.get(_read_transport_name(read, sid,
                                                            env.cfg))
        agg: Any = {} if mode in ("agg", "group", "join") else []
        for part in partitions:
            handle = transport.open_drain(sid, part,
                                          int(n_producers.get(str(sid), 0)),
                                          group=claim_group,
                                          consumer_group=consumer_group)
            for _src, _seq, body in handle:
                records = unpack_batch(body, env.rstore)
                stats["records"] += len(records)
                fold(agg, records, mode)
            stats["messages"] += handle.stats["messages"]
            stats["duplicates"] += handle.stats["duplicates"]
            handles.append(handle)
        if sort_groups and mode in ("group", "join"):
            for vals in agg.values():
                vals.sort(key=_stable_order)
        out[(sid, mode)] = agg

    def ack():
        for handle in handles:
            handle.ack()

    return out, stats, ack


def _shuffle_input_iter(read: ShuffleRead, env: LambdaSim,
                        n_producers: dict, *, sort_groups: bool = False):
    data, stats, ack = _drain_shuffle(read, env, n_producers,
                                      sort_groups=sort_groups)
    if read.self_join or len(read.parts) == 2:  # join
        if read.self_join:
            # CSE collapsed both sides onto one shared shuffle: the single
            # drained aggregate IS both the left and the right input
            left = right = data[read.parts[0]]
        else:
            left, right = data[read.parts[0]], data[read.parts[1]]
        how = read.join_how
        def it():
            for k, lvals in left.items():
                rvals = right.get(k)
                if rvals:
                    for lv in lvals:
                        for rv in rvals:
                            yield (k, (lv, rv))
                elif how in ("left", "outer"):
                    # left/full outer: unmatched left rows survive,
                    # paired with None
                    for lv in lvals:
                        yield (k, (lv, None))
            if how in ("right", "outer"):
                for k, rvals in right.items():
                    if k not in left:
                        for rv in rvals:
                            yield (k, (None, rv))
        return it(), stats, ack
    (sid, mode) = read.parts[0]
    agg = data[(sid, mode)]
    if mode in ("agg", "group"):
        return iter(agg.items()), stats, ack
    return iter(agg), stats, ack


def _flatmap_iter(it, fn):  # immediate fn binding (no late closure capture)
    for x in it:
        yield from fn(x)


def _cache_partition_prefix(token: str, nparts: int, index: int) -> str:
    return f"_cache/{token}/{nparts}/p{index}/"


def _cache_tee(it, spec, store, cap=None):
    """The ("cache", ...) plan op: materialize this partition at the
    cached lineage point, persist it as content-addressed columnar batches
    (billed PUTs), and pass the records on. Sorting the FULL partition
    first makes the pack a pure function of the record multiset, so
    retries and speculative twins overwrite the same keys with the same
    bytes instead of accumulating divergent copies — which is why tasks
    carrying a cache op never chain (per-link slices would pack with
    attempt-dependent boundaries). The materialization is executor state
    like any other: past the memory cap the answer is elasticity."""
    token, nparts, index = spec
    records = sorted(it, key=_stable_order)
    if cap is not None and len(records) > cap:
        raise MemoryCapExceeded(
            f"cache materialization {len(records)} records > cap {cap}")
    if store is not None:
        prefix = _cache_partition_prefix(token, nparts, index)
        bodies = pack_batch(records, limit=S3_EXCHANGE_BATCH_LIMIT)
        for seq, body in enumerate(bodies):
            digest = hashlib.sha1(body).hexdigest()[:12]
            store.put(f"{prefix}{seq:06d}-{digest}", body)
        # batch-count manifest, written LAST: a reader can tell a lost
        # batch (manifest disagrees with the store) from an unreadable or
        # partial materialization. Deterministic across attempts — the
        # sorted pack yields the same bodies every time.
        store.put_obj(f"{prefix}manifest", len(bodies))
    return iter(records)


def cache_partition_iter(inp: CacheInput, store):
    """Read one materialized cache partition back (billed LIST + GETs),
    verifying the batch-count manifest first: an acknowledged-then-lost
    batch (or a vanished manifest) raises LostCacheInput so the CONTEXT
    replans the cached lineage — retrying the reading task cannot recreate
    durable data that no longer exists."""
    prefix = _cache_partition_prefix(inp.token, inp.nparts, inp.index)
    expected = None
    data_keys = []
    for key in store.list(prefix):
        if key.endswith("manifest"):
            expected = store.get_obj(key)
        else:
            data_keys.append(key)
    if expected != len(data_keys):
        raise LostCacheInput(
            f"cache partition {prefix} incomplete: manifest says "
            f"{expected!r} batches, store holds {len(data_keys)} — a "
            f"materialized batch was lost after being written",
            token=inp.token)
    for key in data_keys:
        yield from unpack_batch(store.get(key), store)


def broadcast_read(prefix: str, store) -> dict:
    """Read a broadcast hash-join build side back from its
    content-addressed ``_broadcast/`` object(s) (billed LIST + GETs per
    reading task — the cost the threshold weighs against a shuffle),
    verifying the batch-count manifest first: an acknowledged-then-lost
    batch raises LostBroadcastInput so the scheduler re-runs the small
    side's lineage and re-publishes identical bytes."""
    expected = None
    data_keys = []
    for key in store.list(prefix):
        if key.endswith("manifest"):
            expected = store.get_obj(key)
        else:
            data_keys.append(key)
    if expected is None or expected != len(data_keys):
        raise LostBroadcastInput(
            f"broadcast {prefix} incomplete: manifest says {expected!r} "
            f"batches, store holds {len(data_keys)}", prefix=prefix)
    build: dict = {}
    for key in data_keys:
        for k, v in unpack_batch(store.get(key), store):
            build.setdefault(k, []).append(v)
    return build


def _bcjoin_iter(it, spec: dict, store):
    """The ("bcjoin", spec) plan op the adaptive scheduler splices into a
    large-side producer stage: hash-join the streaming records against the
    broadcast build side. ``spec['side']`` names which JOIN side the
    broadcast data is; the stream is the other side. Only non-preserved
    broadcast sides are ever planned (inner either; left join broadcasts
    right; right join broadcasts left), so unmatched BUILD rows — which a
    single map task could not decide globally — never need emitting."""
    build = broadcast_read(spec["prefix"], store)
    side, how = spec["side"], spec["how"]
    for k, v in it:
        hits = build.get(k)
        if side == "right":  # stream is the left side
            if hits:
                for rv in hits:
                    yield (k, (v, rv))
            elif how in ("left", "outer"):
                yield (k, (v, None))
        else:  # broadcast left, stream is the right side
            if hits:
                for lv in hits:
                    yield (k, (lv, v))
            elif how in ("right", "outer"):
                yield (k, (None, v))


def _apply_ops(it, ops, store=None, cap=None):
    for kind, blob in ops:
        fn = serde.loads_fn(blob) if isinstance(blob, bytes) else blob
        if kind == "map":
            it = map(fn, it)
        elif kind == "filter":
            it = filter(fn, it)
        elif kind == "flatmap":
            it = _flatmap_iter(it, fn)
        elif kind == "mappartitions":
            it = fn(it)
        elif kind == "mapbatches":
            # batch-level narrow op (RDD.mapBatches): fn consumes the whole
            # partition iterator and may yield KVBatch column carriers
            # alongside plain records — row consumers downstream expand
            # them via shuffle.iter_records
            it = fn(it)
        elif kind == "cache":
            it = _cache_tee(it, fn, store, cap)
        elif kind == "bcjoin":
            it = _bcjoin_iter(it, fn, store)
        elif kind == "limit":
            # RDD.take / DataFrame.limit: stop pulling from upstream —
            # and therefore stop READING the source — after fn records
            it = itertools.islice(it, fn)
        else:
            raise ValueError(f"unknown op {kind}")
    return it


def _canonical_key(key):
    """Normalize keys that compare equal but pickle differently, so they
    route to the same partition: Python guarantees 1 == 1.0 == True (and
    dict folding merges them), so the partitioner must agree. Integral
    floats and bools collapse to int; tuples normalize recursively."""
    if isinstance(key, bool):
        return int(key)
    if isinstance(key, float) and key.is_integer():
        return int(key)
    if isinstance(key, tuple):
        return tuple(_canonical_key(k) for k in key)
    return key


class _ColumnBuffer:
    """Per-partition column-major output buffer: rows routed here from
    KVBatch carriers never transpose back to tuples — flush packs wire
    bodies straight from the columns (shuffle.pack_batch_columns). Falls
    back to a plain record list if a row with a different shape shows up
    mid-stream (e.g. a per-row fallback chunk emitting ragged data)."""

    __slots__ = ("kcols", "vcols", "kschema", "vschema", "n")

    def __init__(self, batch: KVBatch):
        self.kcols = [[] for _ in batch.kcols]
        self.vcols = [[] for _ in batch.vcols]
        self.kschema = batch.kschema
        self.vschema = batch.vschema
        self.n = 0

    def matches(self, batch: KVBatch) -> bool:
        return (len(batch.kcols) == len(self.kcols)
                and len(batch.vcols) == len(self.vcols)
                and batch.kschema == self.kschema
                and batch.vschema == self.vschema)

    def extend(self, batch: KVBatch, idxs: list[int]):
        for dst, src in zip(self.kcols, batch.kcols):
            dst.extend(src[i] for i in idxs)
        for dst, src in zip(self.vcols, batch.vcols):
            dst.extend(src[i] for i in idxs)
        self.n += len(idxs)

    def append_row(self, record) -> bool:
        """True if the row fit the column layout, False to demote."""
        if (type(record) is not tuple or len(record) != 2
                or type(record[0]) is not tuple
                or len(record[0]) != len(self.kcols)
                or type(record[1]) is not tuple
                or len(record[1]) != len(self.vcols)):
            return False
        for dst, x in zip(self.kcols, record[0]):
            dst.append(x)
        for dst, x in zip(self.vcols, record[1]):
            dst.append(x)
        self.n += 1
        return True

    def to_records(self) -> list:
        return list(zip(zip(*self.kcols), zip(*self.vcols)))

    def to_batch(self) -> KVBatch:
        return KVBatch(self.kcols, self.vcols, self.kschema, self.vschema)


class _ShuffleWriter:
    """Hash-partitioned buffered writer with overflow flush (§III-A),
    shipping columnar record batches over the shuffle's transport."""

    def __init__(self, write, env: LambdaSim, task_src: str,
                 seq_start: dict | None):
        self.write = write
        self.env = env
        self.src = task_src
        self.combine = (serde.loads_fn(write.combine_fn)
                        if isinstance(write.combine_fn, bytes)
                        else write.combine_fn)
        self.partition_fn = (serde.loads_fn(write.partition_fn)
                             if isinstance(write.partition_fn, bytes)
                             else write.partition_fn)
        self.buffers: dict[int, Any] = {}
        self.buffered = 0
        self.seq = {int(k): v for k, v in (seq_start or {}).items()}
        # per-output-partition [wire bytes, records] — reported back to
        # the scheduler as stats["shuffle_out"], the measured volume the
        # adaptive planner replaces its estimates with
        self.out_stats: dict[int, list] = {}

    def _transport(self):
        return self.env.transports.get(self.write.transport
                                       or self.env.cfg.fallback_backend)

    def _partition_of(self, key) -> int:
        # stable across interpreter runs / PYTHONHASHSEED — a retried or
        # speculated re-invocation MUST route every key to the same
        # partition with the same sequence ids, or dedup breaks
        blob = pickle.dumps(_canonical_key(key),
                            protocol=pickle.HIGHEST_PROTOCOL)
        return zlib.crc32(blob) % self.write.nparts

    def add(self, record):
        w = self.write
        if w.mode == "repart":
            if self.partition_fn is not None:
                # explicit routing (range partitioner): deterministic per
                # record, so retries re-route identically with no cursor
                p = int(self.partition_fn(record)) % w.nparts
            else:
                p = self.seq.get(-1, 0) % w.nparts  # round-robin
                self.seq[-1] = self.seq.get(-1, 0) + 1
            self._append(p, record)
        else:
            k, v = record
            p = self._partition_of(k)
            if w.mode == "agg" and self.combine is not None:
                buf = self.buffers.setdefault(p, {})
                before = len(buf)
                buf[k] = self.combine(buf[k], v) if k in buf else v
                self.buffered += len(buf) - before
                if self.buffered >= self.env.cfg.flush_records:
                    self.flush()
                return
            self._append(p, record)
        self.buffered += 1
        if self.buffered >= self.env.cfg.flush_records:
            self.flush()

    def _append(self, p: int, record):
        buf = self.buffers.get(p)
        if buf is None:
            buf = self.buffers[p] = []
        elif isinstance(buf, _ColumnBuffer):
            if buf.append_row(record):
                return
            # shape mismatch: demote the partition buffer to a record list
            buf = self.buffers[p] = buf.to_records()
        buf.append(record)

    def add_batch(self, batch: KVBatch):
        """Column-major fast path for fused vectorized operators. Map-side
        combine still folds record-at-a-time (the combine dict's insertion
        order and flush boundaries must not depend on how the stream was
        batched); group/join/plain shuffles keep the columns intact per
        output partition so flush() packs without transposing."""
        w = self.write
        if w.mode == "repart" or (w.mode == "agg" and self.combine is not None):
            for rec in batch.iter_rows():
                self.add(rec)
            return
        by_p: dict[int, list[int]] = {}
        for i, k in enumerate(batch.key_tuples()):
            by_p.setdefault(self._partition_of(k), []).append(i)
        for p, idxs in by_p.items():
            buf = self.buffers.get(p)
            if buf is None:
                buf = self.buffers[p] = _ColumnBuffer(batch)
            if isinstance(buf, _ColumnBuffer) and buf.matches(batch):
                buf.extend(batch, idxs)
            else:
                if isinstance(buf, _ColumnBuffer):
                    buf = self.buffers[p] = buf.to_records()
                kt, vt = zip(*batch.kcols), zip(*batch.vcols)
                rows = list(zip(kt, vt))
                buf.extend(rows[i] for i in idxs)
        self.buffered += batch.n
        if self.buffered >= self.env.cfg.flush_records:
            self.flush()

    def flush(self):
        transport = self._transport()
        for p, buf in self.buffers.items():
            if isinstance(buf, _ColumnBuffer):
                if not buf.n:
                    continue
                nrecs = buf.n
                # schema from the plan when declared, else the batch's own
                cb = buf.to_batch()
                if self.write.batch_schema is not None:
                    cb.kschema, cb.vschema = self.write.batch_schema
                bodies = pack_batch_columns(
                    cb, limit=transport.batch_limit, spill=transport.spill,
                    columnar=self.env.cfg.columnar_batches)
            else:
                records = list(buf.items()) if isinstance(buf, dict) else buf
                if not records:
                    continue
                nrecs = len(records)
                bodies = pack_batch(records, limit=transport.batch_limit,
                                    spill=transport.spill,
                                    columnar=self.env.cfg.columnar_batches,
                                    schema=self.write.batch_schema)
            seq = self.seq.get(p, 0)
            transport.send(self.write.shuffle_id, p, self.src, seq, bodies)
            self.seq[p] = seq + len(bodies)
            st = self.out_stats.setdefault(p, [0, 0])
            st[0] += sum(len(b) for b in bodies)
            st[1] += nrecs
        self.buffers = {}
        self.buffered = 0

    def finalize(self):
        """Emit EOS on every output partition — INCLUDING partitions this
        task never wrote to (total 0) — carrying the total sequence count,
        so consumers can count down a fixed producer quorum. Only the final
        (non-continuation) link of a chained task calls this; a retried/
        speculated duplicate re-emits identical EOS (partitioning and
        sequence assignment are deterministic), which consumers dedup by
        producer id."""
        self._transport().emit_eos(self.write.shuffle_id, self.write.nparts,
                                   self.src, self.seq)


def executor_main(payload: dict, env: LambdaSim) -> dict:
    """The Lambda function body: deserialize task, build input iterator,
    run the pipeline, sink outputs, chain if the lease runs out."""
    fail_after = payload.get("fail_after_records")
    timeout_after = payload.get("timeout_after_records")
    inject = payload.get("inject_failure")
    if inject:
        raise InjectedFailure(f"injected failure for task "
                              f"{payload['stage']}/{payload['index']}")
    slow = payload.get("straggle_s", 0.0)
    if slow:
        time.sleep(slow)

    lease = _Lease(env.cfg)
    src_id = f"s{payload['stage']}t{payload['index']}"
    stats: dict[str, Any] = {"records_in": 0}
    inp = payload["input"]
    # a task carrying a cache op never chains: the tee must see the FULL
    # partition in one link so its content-addressed pack is deterministic
    # across attempts (per-link slices would cut at lease-dependent
    # boundaries and leave divergent key sets behind)
    chainable = (isinstance(inp, SourceInput)
                 and not any(kind == "cache" for kind, _ in payload["ops"]))

    ack_shuffle = None
    if isinstance(inp, SourceInput):
        reader = _SourceReader(inp, env.rstore, env.cfg,
                               payload.get("resume_offset"))
        base_iter = iter(reader)
    elif isinstance(inp, CollectionInput):
        base_iter = iter(env.rstore.get_obj(f"{inp.key}/{inp.index}"))
        reader = None
    elif isinstance(inp, CacheInput):
        # a cached lineage hit: the upstream stages were never planned
        base_iter = cache_partition_iter(inp, env.rstore)
        reader = None
    else:
        base_iter, drain_stats, ack_shuffle = _shuffle_input_iter(
            inp, env, payload.get("n_producers") or {},
            sort_groups=payload["write"] is not None)
        stats.update(drain_stats)
        reader = None

    exhausted = {"flag": False}

    def metered():
        n = 0
        try:
            for rec in base_iter:
                n += 1
                if fail_after and n > fail_after:
                    raise InjectedFailure("injected mid-task failure")
                if timeout_after and n > timeout_after:
                    # the simulated lease expiry: killed mid-flight with NO
                    # final flush — only count-boundary flushes that
                    # already happened are durable, so the retry's
                    # byte-identical re-emission overlaps them exactly
                    raise InvocationTimeout(
                        f"invocation lease expired after {n} records "
                        f"(simulated Lambda timeout)")
                yield rec
                if lease.consumed() and chainable:
                    exhausted["flag"] = True
                    return
        finally:
            # also on the early (chaining) return — every link reports
            # what it actually ingested, not just the last one
            stats["records_in"] = n

    out_iter = _apply_ops(metered(), payload["ops"], env.rstore,
                          env.cfg.agg_memory_records)

    write = payload["write"]
    if write is not None:
        writer = _ShuffleWriter(write, env, src_id, payload.get("seq_start"))
        if ack_shuffle is not None:
            # a shuffle-reading task's output follows its drain's arrival
            # order, which differs across attempts. Downstream dedup keys
            # on (src, seq), so a retry or speculative twin MUST re-emit
            # byte-identical messages: materialize and sort before
            # partitioning/packing (sorted input makes partition routing,
            # flush boundaries, and body framing all deterministic).
            # KVBatch carriers expand to rows first — a batch boundary is
            # an artifact of this attempt's drain, not of the data.
            out_iter = sorted(iter_records(out_iter), key=_stable_order)
            if len(out_iter) > env.cfg.agg_memory_records:
                # the materialized output (e.g. a join cross-product) is
                # state too — answer overflow with elasticity, like the
                # drain aggregate
                raise MemoryCapExceeded(
                    f"materialized shuffle output {len(out_iter)} records "
                    f"> cap {env.cfg.agg_memory_records}")
        for rec in out_iter:
            if isinstance(rec, KVBatch):
                writer.add_batch(rec)
            else:
                writer.add(rec)
        writer.flush()
        # per-link deltas: the scheduler sums links/attempts per shuffle
        stats["shuffle_out"] = {p: list(v)
                                for p, v in writer.out_stats.items()}
        if not exhausted["flag"]:
            # EOS protocol (both scheduler modes): the LAST link of the
            # (possibly chained) task closes the stream for this producer
            writer.finalize()
        if ack_shuffle is not None:
            # input acked only now that the output is durable downstream;
            # dying any earlier leaves it all to redeliver for the retry
            ack_shuffle()
        resp = {"status": "ok", "stats": stats}
        if exhausted["flag"]:
            resp["continuation"] = {
                "resume_offset": reader.consumed_until,
                "seq_start": writer.seq,
            }
        return resp

    result = list(iter_records(out_iter))
    resp = {"status": "ok", "stats": stats}
    if payload.get("save_prefix"):
        key = f"{payload['save_prefix']}/part-{payload['index']:05d}"
        env.rstore.put(key, "\n".join(str(r) for r in result).encode())
        resp["saved_key"] = key
    else:
        resp["result"] = result
    if ack_shuffle is not None:
        ack_shuffle()  # input acked only once the sink is durable
    if exhausted["flag"]:
        resp["continuation"] = {"resume_offset": reader.consumed_until,
                                "partial": True}
    return resp
