"""The Flint executor — a process inside a (simulated) Lambda invocation —
plus the Lambda runtime simulation itself.

Semantics preserved from the paper (§III-A/B):
  * one task per invocation; executors are stateless between invocations;
  * input iterator reads an S3 byte range (stage 0) or drains SQS queues
    (intermediate stages), deduplicating at-least-once deliveries by
    (producer task, sequence id); under pipelined execution the drain
    starts BEFORE producers finish and terminates on per-producer EOS
    control messages (docs/eos_shuffle.md) instead of a count table;
  * ACK-AFTER-FOLD: SQS receives are visibility-timeout claims, not pops.
    The drain folds each message, accumulates its receipt handle, and
    heartbeats ``change_visibility`` through long folds; the batched
    delete (ack) happens only once the task's OUTPUT is durable — so a
    consumer that dies anywhere mid-task leaves every message it read to
    redeliver to its retry (or to a speculative twin);
  * outputs are hash-partitioned, buffered in memory, and FLUSHED to the
    per-partition queues when the buffer grows past its cap (the 3008 MB
    limit made concrete as a record-count proxy);
  * executor CHAINING: when the invocation lease is nearly exhausted the
    executor stops ingesting, flushes, and returns a continuation cursor
    that the scheduler re-invokes on a warm container (map-side combine
    partials are safe to flush early because combiners are associative);
  * responses above the payload cap spill to the object store (6 MB cap,
    both directions).

Failure injection + the record-count lease hook make chaining, retry and
straggler behavior deterministic in tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pickle
import threading
import time
import zlib
from typing import Any

from repro.core import serde
from repro.core.costs import (LAMBDA_PAYLOAD_LIMIT, SQS_BATCH_MESSAGES,
                              CostLedger)
from repro.core.dag import CollectionInput, ShuffleRead, SourceInput, TaskDef
from repro.core.queues import (Message, ObjectStoreSim, QueueGone, SQSSim,
                               eos_message, pack_records, unpack_records)


class InjectedFailure(RuntimeError):
    pass


class AbortedError(RuntimeError):
    """The scheduler shut the shuffle transport down mid-drain (fatal
    stage failure or elastic re-plan) — unblock and exit quietly."""


class MemoryCapExceeded(RuntimeError):
    """Aggregation state outgrew the executor memory cap — the paper's
    answer is elasticity: raise the partition count and re-run."""


@dataclasses.dataclass
class FlintConfig:
    memory_mb: int = 3008
    time_limit_s: float = 300.0
    # intermediate-data transport: "sqs" (the paper's choice) or "s3"
    # (Qubole's choice, paper SSV/SVI flag the comparison as open work)
    shuffle_backend: str = "sqs"
    # pipelined stage execution: launch consumer tasks concurrently with
    # their producers; consumers terminate on per-producer EOS control
    # messages. False restores barrier scheduling (A/B comparison).
    pipeline_stages: bool = True
    lease_safety: float = 0.8  # stop ingesting at this fraction of the lease
    concurrency: int = 80
    cold_start_s: float = 0.4
    warm_start_s: float = 0.01
    start_latency_scale: float = 0.0  # 0 => don't actually sleep in tests
    flush_records: int = 20_000  # shuffle buffer cap (memory proxy)
    agg_memory_records: int = 2_000_000  # consumer-side aggregation cap
    max_records_per_invoke: int = 0  # test hook: deterministic chaining
    max_task_retries: int = 3
    speculation_factor: float = 4.0  # straggler duplicate threshold
    speculation_min_done: int = 4
    drain_timeout_s: float = 30.0
    # SQS visibility timeout: how long a received-but-unacked message stays
    # invisible before redelivery. Must stay below drain_timeout_s or a
    # retried consumer times out waiting for its predecessor's claims to
    # expire.
    visibility_timeout_s: float = 10.0
    duplicate_prob: float = 0.0  # SQS at-least-once duplication rate
    chunk_fetch_bytes: int = 4 * 2**20


def queue_name(shuffle_id: int, partition: int) -> str:
    return f"shuffle{shuffle_id}-p{partition}"


# --------------------------------------------------------------- payloads


def serialize_task(task: TaskDef, attempt: int, extra: dict | None = None
                   ) -> dict:
    ops = [(kind, serde.dumps_fn(fn)) for kind, fn in task.ops]
    inp = task.input
    if isinstance(inp, ShuffleRead) and inp.combine_fn is not None:
        inp = dataclasses.replace(inp, combine_fn=serde.dumps_fn(inp.combine_fn))
    write = task.write
    if write is not None and write.combine_fn is not None:
        write = dataclasses.replace(write,
                                    combine_fn=serde.dumps_fn(write.combine_fn))
    return {"stage": task.stage_id, "index": task.index, "input": inp,
            "ops": ops, "write": write, "attempt": attempt,
            **(extra or {})}


# ------------------------------------------------------------ the Lambda


class LambdaSim:
    """Invocation environment: containers (cold/warm), leases, payload caps,
    per-invocation billing."""

    def __init__(self, cfg: FlintConfig, ledger: CostLedger,
                 store: ObjectStoreSim, sqs: SQSSim):
        self.cfg = cfg
        self.ledger = ledger
        self.store = store
        self.sqs = sqs
        self._warm = 0
        self._lock = threading.Lock()
        self.invocations = 0
        self.cold_starts = 0

    def _acquire_container(self) -> bool:
        """Returns True on a cold start."""
        with self._lock:
            self.invocations += 1
            if self._warm > 0:
                self._warm -= 1
                return False
            self.cold_starts += 1
            return True

    def _release_container(self):
        with self._lock:
            self._warm += 1

    def invoke(self, payload: dict) -> dict:
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > LAMBDA_PAYLOAD_LIMIT:
            # paper §III-B: split/spill oversized payloads through S3
            key = f"_payload/{payload['stage']}/{payload['index']}/{time.monotonic_ns()}"
            self.store.put(key, blob)
            payload = {"spilled": key}
        cold = self._acquire_container()
        start = (self.cfg.cold_start_s if cold else self.cfg.warm_start_s)
        if self.cfg.start_latency_scale > 0:
            time.sleep(start * self.cfg.start_latency_scale)
        t0 = time.monotonic()
        try:
            if "spilled" in payload:
                payload = pickle.loads(self.store.get(payload["spilled"]))
            resp = executor_main(payload, self)
        except (InjectedFailure, MemoryCapExceeded, AbortedError,
                TimeoutError) as e:
            resp = {"status": "error", "error_type": type(e).__name__,
                    "error": str(e)}
        finally:
            duration = time.monotonic() - t0 + start
            self.ledger.add_lambda(duration, self.cfg.memory_mb)
            self._release_container()
        resp.setdefault("duration_s", time.monotonic() - t0)
        blob = pickle.dumps(resp, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > LAMBDA_PAYLOAD_LIMIT:
            key = f"_result/{time.monotonic_ns()}"
            self.store.put(key, blob)
            resp = {"status": resp.get("status", "ok"), "spilled": key,
                    "duration_s": resp["duration_s"]}
        return resp


# ------------------------------------------------------ executor internals


class _Lease:
    def __init__(self, cfg: FlintConfig):
        self.deadline = time.monotonic() + cfg.time_limit_s * cfg.lease_safety
        self.max_records = cfg.max_records_per_invoke or None
        self.records = 0

    def consumed(self, n: int = 1) -> bool:
        """Count ingested records; True when the lease is exhausted."""
        self.records += n
        if self.max_records is not None and self.records >= self.max_records:
            return True
        if (self.records & 0xFF) == 0 and time.monotonic() > self.deadline:
            return True
        return False


class _SourceReader:
    """Line records over a byte range with Hadoop LineRecordReader
    semantics: a non-first split always skips its first (possibly partial)
    line, and every split reads lines whose start offset is <= end — so the
    line starting exactly at a boundary belongs to the EARLIER split.
    ``consumed_until`` is the absolute offset of the first unconsumed line
    (the chaining cursor)."""

    def __init__(self, inp: SourceInput, store: ObjectStoreSim,
                 cfg: FlintConfig, resume_offset: int | None):
        self.inp = inp
        self.store = store
        self.cfg = cfg
        self.offset = resume_offset  # absolute byte offset to resume at
        self.consumed_until = resume_offset if resume_offset is not None \
            else inp.start

    def _find_line_start(self, pos: int) -> int:
        """First line start at or after pos (skipping a partial line)."""
        scan = pos
        while scan < self.inp.size:
            probe = self.store.get(self.inp.key, scan,
                                   min(self.inp.size,
                                       scan + self.cfg.chunk_fetch_bytes))
            nl = probe.find(b"\n")
            if nl >= 0:
                return scan + nl + 1
            scan += len(probe)
        return self.inp.size

    def __iter__(self):
        inp, store, chunk = self.inp, self.store, self.cfg.chunk_fetch_bytes
        if self.offset is not None:
            line_start = self.offset
        elif inp.start == 0:
            line_start = 0
        else:
            line_start = self._find_line_start(inp.start)
        self.consumed_until = line_start
        pos = line_start  # next byte to fetch
        carry = b""
        while line_start <= inp.end:
            if pos >= inp.size:
                if carry and line_start <= inp.end:
                    # final line without trailing newline
                    self.consumed_until = inp.size
                    yield carry.decode("utf-8", "replace")
                return
            data = store.get(inp.key, pos, min(inp.size, pos + chunk))
            pos += len(data)
            data = carry + data
            lines = data.split(b"\n")
            carry = lines.pop()
            for ln in lines:
                if line_start > inp.end:
                    return
                line_start += len(ln) + 1
                self.consumed_until = line_start
                yield ln.decode("utf-8", "replace")


def _heartbeat(env: LambdaSim, held: dict, vis: float):
    """Extend the visibility deadline of every receipt this drain holds
    (stale receipts and deleted queues are no-ops)."""
    for qname, rcpts in held.items():
        receipts = list(rcpts.values())
        for i in range(0, len(receipts), SQS_BATCH_MESSAGES):
            env.sqs.change_visibility(qname,
                                      receipts[i:i + SQS_BATCH_MESSAGES], vis)


def _stable_order(rec) -> bytes:
    """Deterministic total order on records (their pickle bytes) — used to
    make a shuffle-reading task's re-emission byte-identical across
    attempts whose drains arrived in different orders."""
    return pickle.dumps(rec, protocol=pickle.HIGHEST_PROTOCOL)


def _drain_shuffle(read: ShuffleRead, env: LambdaSim, expected: dict,
                   n_producers: dict | None = None, *,
                   sort_groups: bool = False) -> dict:
    """Drain queue(s) for this partition with seq-id dedup, folding each
    message into the aggregate AS IT ARRIVES (streaming — transport time
    overlaps the fold). Two termination protocols:

      * pipelined (``n_producers`` given): drain until an EOS control
        message has arrived from every one of the ``n_producers[sid]``
        producer tasks AND every producer's advertised sequence count has
        been seen. EOS may outrun data (no ordering guarantee), duplicated
        EOS (speculation, at-least-once delivery) is idempotent.
      * barrier (``expected`` given): the legacy post-hoc message-count
        table handed over after the producer stage fully completed.

    Receives are visibility-timeout claims: every message stays in-flight
    under a receipt handle this drain holds and heartbeats; nothing is
    acked here. Returns ({(sid, mode): folded-aggregate}, stats, ack)
    where ``ack`` batch-deletes every held receipt — the caller invokes
    it only once the task's output is durable, so an earlier death leaves
    the whole input to redeliver for the retry.

    ``sort_groups`` (set when this task WRITES another shuffle): group/
    join value-lists collect in arrival order, which differs across
    attempts — sort them so the records this task re-emits are
    byte-identical and downstream (src, seq) dedup stays sound."""
    out = {}
    stats = {"messages": 0, "duplicates": 0, "records": 0}
    combine = (serde.loads_fn(read.combine_fn)
               if isinstance(read.combine_fn, bytes) else read.combine_fn)
    timeout = env.cfg.drain_timeout_s
    # queue -> {(src, seq, kind): latest receipt handle}. Keyed, not a
    # list: an idle wait lets claims lapse and redeliver every visibility
    # period, and keeping only the freshest handle per message bounds
    # held (and the heartbeat/ack request counts) by the distinct message
    # count instead of growing per redelivery cycle.
    held: dict[str, dict] = {}

    def ack():
        # batched ack-after-fold, deferred to task completion; duplicate
        # or stale receipts are idempotent no-ops inside delete_batch
        for qname, rcpts in held.items():
            receipts = list(rcpts.values())
            for i in range(0, len(receipts), SQS_BATCH_MESSAGES):
                env.sqs.delete_batch(qname,
                                     receipts[i:i + SQS_BATCH_MESSAGES])

    def fold(agg, records, mode):
        if mode == "agg":
            for k, v in records:
                agg[k] = combine(agg[k], v) if k in agg else v
        elif mode in ("group", "join"):
            for k, v in records:
                agg.setdefault(k, []).append(v)
        else:  # repart
            agg.extend(records)
        if (mode in ("agg", "group", "join")
                and len(agg) > env.cfg.agg_memory_records):
            raise MemoryCapExceeded(
                f"aggregation state {len(agg)} records > cap "
                f"{env.cfg.agg_memory_records}")

    for sid, mode in read.parts:
        agg: Any = {} if mode in ("agg", "group", "join") else []
        seen: set = set()
        per_src: dict[str, int] = {}   # distinct data messages per producer
        eos_total: dict[str, int] = {}  # producer -> advertised seq count
        deadline = time.monotonic() + timeout  # inactivity deadline
        pipelined = n_producers is not None
        quorum = int(n_producers.get(str(sid), 0)) if pipelined else 0
        need = {} if pipelined else dict(expected.get(str(sid), {}))

        def done() -> bool:
            if pipelined:
                return (len(eos_total) >= quorum
                        and all(per_src.get(s, 0) >= t
                                for s, t in eos_total.items()))
            return len(seen) >= sum(need.values())

        if env.cfg.shuffle_backend == "s3":
            prefix = f"_shuffle/{sid}/p{read.partition}/"
            # S3 has no arrival notification — polling LIST is inherent to
            # an object-store shuffle (the paper's cost argument against
            # it); back off exponentially so an early pipelined consumer
            # doesn't spin while its producers compute
            backoff = 0.002
            while not done():
                progressed = False
                for key in env.store.list(prefix):
                    src, _, tail = key[len(prefix):].rpartition("-")
                    if tail == "eos":
                        if pipelined and src not in eos_total:
                            eos_total[src] = env.store.get_obj(key)
                            progressed = True
                        continue
                    kid = (src, int(tail))
                    if kid in seen:
                        continue
                    seen.add(kid)
                    per_src[src] = per_src.get(src, 0) + 1
                    stats["messages"] += 1
                    records = env.store.get_obj(key)
                    stats["records"] += len(records)
                    fold(agg, records, mode)
                    progressed = True
                if done():
                    break
                if env.sqs.closed:
                    raise AbortedError(f"s3 shuffle {prefix}: aborted")
                if progressed:
                    deadline = time.monotonic() + timeout
                    backoff = 0.002
                elif time.monotonic() > deadline:
                    raise TimeoutError(f"s3 shuffle {prefix} incomplete")
                time.sleep(backoff)
                backoff = min(backoff * 2, 0.1)
            if sort_groups and mode in ("group", "join"):
                for vals in agg.values():
                    vals.sort(key=_stable_order)
            out[(sid, mode)] = agg
            continue

        name = queue_name(sid, read.partition)
        vis = env.cfg.visibility_timeout_s
        hb_deadline = time.monotonic() + vis / 2
        # adaptive drain sizing: one scheduler step takes the whole visible
        # backlog (bounded), not a fixed 100. The backlog estimate is a
        # billable request (GetQueueAttributes), so it is re-queried only
        # while receives keep coming back full — a trickle or an idle wait
        # falls back to the minimum batch for free.
        want = None  # None => query the backlog estimate
        while not done():
            if want is None:
                want = min(1000, max(SQS_BATCH_MESSAGES,
                                     env.sqs.approx_len(name)))
            try:
                msgs = env.sqs.receive_many(name, want)
            except QueueGone:
                raise AbortedError(
                    f"queue {name} deleted — a competing attempt already "
                    f"completed this partition")
            now = time.monotonic()
            if not msgs:
                want = SQS_BATCH_MESSAGES
                if env.sqs.closed:
                    raise AbortedError(f"queue {name}: aborted")
                if now > deadline:
                    raise TimeoutError(
                        f"queue {name} incomplete: {len(seen)} data msgs, "
                        f"eos {len(eos_total)}/{quorum}" if pipelined else
                        f"queue {name} incomplete: {len(seen)}"
                        f"/{sum(need.values())} messages")
                # block on arrival instead of sleep-spinning. NOTE: held
                # claims are deliberately NOT heartbeated while idle: a
                # drain idles because it still needs messages, and when a
                # retry and a speculative twin race on one queue, each
                # needs the OTHER's claims to lapse — idle heartbeats on
                # both sides split the queue permanently and burn every
                # retry. A lone waiting consumer instead re-receives its
                # claimed backlog each visibility period (re-billed,
                # deduped) — the bounded price of livelock-freedom.
                env.sqs.wait_for_messages(name, 0.25)
                continue
            want = None if len(msgs) == want else SQS_BATCH_MESSAGES
            rcpts = held.setdefault(name, {})
            progressed = False
            for m in msgs:
                rcpts[(m.src, m.seq, m.kind)] = m.receipt
                if time.monotonic() > hb_deadline:
                    # actively folding: a long fold must not let held
                    # messages expire mid-task and redeliver to a rival
                    _heartbeat(env, held, vis)
                    hb_deadline = time.monotonic() + vis / 2
                if m.kind == "eos":
                    if pipelined and m.src not in eos_total:
                        eos_total[m.src] = m.seq  # duplicates: same total
                        progressed = True
                    continue
                kid = (m.src, m.seq)
                if kid in seen:
                    stats["duplicates"] += 1
                    continue
                seen.add(kid)
                progressed = True
                per_src[m.src] = per_src.get(m.src, 0) + 1
                stats["messages"] += 1
                records = unpack_records(m.body, env.store)
                stats["records"] += len(records)
                fold(agg, records, mode)
            if progressed:
                deadline = time.monotonic() + timeout
            elif time.monotonic() > deadline:
                # a batch of pure duplicates (e.g. this drain's own lapsed
                # claims redelivering while a producer is stuck) is not
                # progress — without this the inactivity timeout could
                # never fire once the drain held a single claim
                raise TimeoutError(
                    f"queue {name} stalled: {len(seen)} data msgs, "
                    f"eos {len(eos_total)}/{quorum}" if pipelined else
                    f"queue {name} stalled: {len(seen)}"
                    f"/{sum(need.values())} messages")
        if sort_groups and mode in ("group", "join"):
            for vals in agg.values():
                vals.sort(key=_stable_order)
        out[(sid, mode)] = agg
    return out, stats, ack


def _shuffle_input_iter(read: ShuffleRead, env: LambdaSim, expected: dict,
                        n_producers: dict | None = None, *,
                        sort_groups: bool = False):
    data, stats, ack = _drain_shuffle(read, env, expected, n_producers,
                                      sort_groups=sort_groups)
    if len(read.parts) == 2:  # join
        (sid_l, _), (sid_r, _) = read.parts
        left, right = data[read.parts[0]], data[read.parts[1]]
        def it():
            for k, lvals in left.items():
                rvals = right.get(k)
                if not rvals:
                    continue
                for lv in lvals:
                    for rv in rvals:
                        yield (k, (lv, rv))
        return it(), stats, ack
    (sid, mode) = read.parts[0]
    agg = data[(sid, mode)]
    if mode in ("agg", "group"):
        return iter(agg.items()), stats, ack
    return iter(agg), stats, ack


def _flatmap_iter(it, fn):  # immediate fn binding (no late closure capture)
    for x in it:
        yield from fn(x)


def _apply_ops(it, ops):
    for kind, blob in ops:
        fn = serde.loads_fn(blob) if isinstance(blob, bytes) else blob
        if kind == "map":
            it = map(fn, it)
        elif kind == "filter":
            it = filter(fn, it)
        elif kind == "flatmap":
            it = _flatmap_iter(it, fn)
        elif kind == "mappartitions":
            it = fn(it)
        else:
            raise ValueError(f"unknown op {kind}")
    return it


def _canonical_key(key):
    """Normalize keys that compare equal but pickle differently, so they
    route to the same partition: Python guarantees 1 == 1.0 == True (and
    dict folding merges them), so the partitioner must agree. Integral
    floats and bools collapse to int; tuples normalize recursively."""
    if isinstance(key, bool):
        return int(key)
    if isinstance(key, float) and key.is_integer():
        return int(key)
    if isinstance(key, tuple):
        return tuple(_canonical_key(k) for k in key)
    return key


class _ShuffleWriter:
    """Hash-partitioned buffered writer with overflow flush (§III-A)."""

    def __init__(self, write, env: LambdaSim, task_src: str,
                 seq_start: dict | None):
        self.write = write
        self.env = env
        self.src = task_src
        self.combine = (serde.loads_fn(write.combine_fn)
                        if isinstance(write.combine_fn, bytes)
                        else write.combine_fn)
        self.buffers: dict[int, Any] = {}
        self.buffered = 0
        self.seq = {int(k): v for k, v in (seq_start or {}).items()}
        self.message_counts: dict[int, int] = {}

    def _partition_of(self, key) -> int:
        # stable across interpreter runs / PYTHONHASHSEED — a retried or
        # speculated re-invocation MUST route every key to the same
        # partition with the same sequence ids, or dedup breaks
        blob = pickle.dumps(_canonical_key(key),
                            protocol=pickle.HIGHEST_PROTOCOL)
        return zlib.crc32(blob) % self.write.nparts

    def _spill(self, blob: bytes) -> str:
        """A single record pickle over the 256 KiB message cap rides the
        object store; the queue carries a SpillPointer. Content-addressed
        key, so a retry or speculative twin re-spilling the same record
        overwrites idempotently."""
        key = f"_spill/{hashlib.sha1(blob).hexdigest()}"
        self.env.store.put(key, blob)
        return key

    def add(self, record):
        w = self.write
        if w.mode == "repart":
            p = self.seq.get(-1, 0) % w.nparts  # round-robin
            self.seq[-1] = self.seq.get(-1, 0) + 1
            self.buffers.setdefault(p, []).append(record)
        else:
            k, v = record
            p = self._partition_of(k)
            if w.mode == "agg" and self.combine is not None:
                buf = self.buffers.setdefault(p, {})
                before = len(buf)
                buf[k] = self.combine(buf[k], v) if k in buf else v
                self.buffered += len(buf) - before
                if self.buffered >= self.env.cfg.flush_records:
                    self.flush()
                return
            self.buffers.setdefault(p, []).append(record)
        self.buffered += 1
        if self.buffered >= self.env.cfg.flush_records:
            self.flush()

    def flush(self):
        s3_mode = self.env.cfg.shuffle_backend == "s3"
        for p, buf in self.buffers.items():
            records = list(buf.items()) if isinstance(buf, dict) else buf
            if not records:
                continue
            if s3_mode:
                # Qubole-style object-store shuffle: one object per flush;
                # idempotent keys make retries/speculation free to dedup
                seq = self.seq.get(p, 0)
                self.seq[p] = seq + 1
                self.message_counts[p] = self.message_counts.get(p, 0) + 1
                key = (f"_shuffle/{self.write.shuffle_id}/p{p}/"
                       f"{self.src}-{seq}")
                self.env.store.put_obj(key, records)
                continue
            name = queue_name(self.write.shuffle_id, p)
            bodies = pack_records(records, spill=self._spill)
            batch: list[Message] = []
            for body in bodies:
                seq = self.seq.get(p, 0)
                self.seq[p] = seq + 1
                self.message_counts[p] = self.message_counts.get(p, 0) + 1
                batch.append(Message(body, seq, self.src))
                if len(batch) == 10:
                    self.env.sqs.send_batch(name, batch)
                    batch = []
            if batch:
                self.env.sqs.send_batch(name, batch)
        self.buffers = {}
        self.buffered = 0

    def finalize(self):
        """Emit one EOS control message per output partition — INCLUDING
        partitions this task never wrote to (total 0) — carrying the total
        sequence count, so consumers can count down a fixed producer quorum.
        Only the final (non-continuation) link of a chained task calls this;
        a retried/speculated duplicate re-emits identical EOS (partitioning
        and sequence assignment are deterministic), which consumers dedup
        by producer id."""
        w = self.write
        if self.env.cfg.shuffle_backend == "s3":
            for p in range(w.nparts):
                key = f"_shuffle/{w.shuffle_id}/p{p}/{self.src}-eos"
                self.env.store.put_obj(key, self.seq.get(p, 0))
            return
        for p in range(w.nparts):
            self.env.sqs.send_batch(
                queue_name(w.shuffle_id, p),
                [eos_message(self.src, self.seq.get(p, 0))])


def executor_main(payload: dict, env: LambdaSim) -> dict:
    """The Lambda function body: deserialize task, build input iterator,
    run the pipeline, sink outputs, chain if the lease runs out."""
    fail_after = payload.get("fail_after_records")
    inject = payload.get("inject_failure")
    if inject:
        raise InjectedFailure(f"injected failure for task "
                              f"{payload['stage']}/{payload['index']}")
    slow = payload.get("straggle_s", 0.0)
    if slow:
        time.sleep(slow)

    lease = _Lease(env.cfg)
    src_id = f"s{payload['stage']}t{payload['index']}"
    stats: dict[str, Any] = {"records_in": 0}
    inp = payload["input"]
    chainable = isinstance(inp, SourceInput)

    ack_shuffle = None
    if isinstance(inp, SourceInput):
        reader = _SourceReader(inp, env.store, env.cfg,
                               payload.get("resume_offset"))
        base_iter = iter(reader)
    elif isinstance(inp, CollectionInput):
        base_iter = iter(env.store.get_obj(f"{inp.key}/{inp.index}"))
        reader = None
    else:
        base_iter, drain_stats, ack_shuffle = _shuffle_input_iter(
            inp, env, payload.get("expected", {}),
            payload.get("n_producers"),
            sort_groups=payload["write"] is not None)
        stats.update(drain_stats)
        reader = None

    exhausted = {"flag": False}

    def metered():
        n = 0
        try:
            for rec in base_iter:
                n += 1
                if fail_after and n > fail_after:
                    raise InjectedFailure("injected mid-task failure")
                yield rec
                if lease.consumed() and chainable:
                    exhausted["flag"] = True
                    return
        finally:
            # also on the early (chaining) return — every link reports
            # what it actually ingested, not just the last one
            stats["records_in"] = n

    out_iter = _apply_ops(metered(), payload["ops"])

    write = payload["write"]
    if write is not None:
        writer = _ShuffleWriter(write, env, src_id, payload.get("seq_start"))
        if ack_shuffle is not None:
            # a shuffle-reading task's output follows its drain's arrival
            # order, which differs across attempts. Downstream dedup keys
            # on (src, seq), so a retry or speculative twin MUST re-emit
            # byte-identical messages: materialize and sort before
            # partitioning/packing (sorted input makes partition routing,
            # flush boundaries, and body framing all deterministic).
            out_iter = sorted(out_iter, key=_stable_order)
            if len(out_iter) > env.cfg.agg_memory_records:
                # the materialized output (e.g. a join cross-product) is
                # state too — answer overflow with elasticity, like the
                # drain aggregate
                raise MemoryCapExceeded(
                    f"materialized shuffle output {len(out_iter)} records "
                    f"> cap {env.cfg.agg_memory_records}")
        for rec in out_iter:
            writer.add(rec)
        writer.flush()
        if payload.get("emit_eos") and not exhausted["flag"]:
            # pipelined protocol: the LAST link of the (possibly chained)
            # task closes the stream for this producer
            writer.finalize()
        if ack_shuffle is not None:
            # input acked only now that the output is durable downstream;
            # dying any earlier leaves it all to redeliver for the retry
            ack_shuffle()
        resp = {"status": "ok", "message_counts": writer.message_counts,
                "stats": stats}
        if exhausted["flag"]:
            resp["continuation"] = {
                "resume_offset": reader.consumed_until,
                "seq_start": writer.seq,
            }
        return resp

    result = list(out_iter)
    resp = {"status": "ok", "stats": stats}
    if payload.get("save_prefix"):
        key = f"{payload['save_prefix']}/part-{payload['index']:05d}"
        env.store.put(key, "\n".join(str(r) for r in result).encode())
        resp["saved_key"] = key
    else:
        resp["result"] = result
    if ack_shuffle is not None:
        ack_shuffle()  # input acked only once the sink is durable
    if exhausted["flag"]:
        resp["continuation"] = {"resume_offset": reader.consumed_until,
                                "partial": True}
    return resp
