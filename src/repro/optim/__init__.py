from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm, lr_schedule)
from repro.optim.compression import (compress_int8_ef, decompress_int8,
                                     ef_state_init)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "lr_schedule", "compress_int8_ef", "decompress_int8", "ef_state_init",
]
