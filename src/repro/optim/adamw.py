"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule. Hand-rolled (no optax in this environment).

Optimizer state mirrors the param tree (m, v in f32) and inherits the
params' sharding — ZeRO-style partitioning falls out of the FSDP rules in
``runtime.sharding``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # f32 tree like params
    v: Any  # f32 tree like params


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(tc: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - tc.warmup_steps)
                 / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    """Global-norm clip WITHOUT materializing f32 copies of the gradients:
    the norm accumulates in f32 (scalar reductions are free), but each leaf
    keeps its storage dtype — upcasting first doubles the bytes the SPMD
    partitioner moves through the gradient all-reduce (measured 2x on
    command-r-plus train_4k)."""
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state: AdamWState, tc: TrainConfig):
    """Returns (new_params, new_state, metrics). grads may be any float dtype;
    moments and the update run in f32; params keep their storage dtype."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc, step)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
