"""int8 gradient compression with error feedback, for the DP all-reduce.

The paper's cost model charges per byte moved through the external shuffle
service; the training-plane analogue is the gradient all-reduce across the
'pod' (DCN) axis. Compressing to int8 with an error-feedback residual cuts
that traffic 4x (vs f32) / 2x (vs bf16) while keeping convergence — the
residual carries the quantization error into the next step.

Used inside train_step BEFORE the psum when cfg.grad_compression='int8_ef'
(simulated here by quantize->dequantize around the mean-reduce, which is
numerically identical to all-reducing the int8 payloads plus scales).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_one(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def compress_int8_ef(grads, ef_state):
    """Returns (q_tree of (int8, scale) pairs, new_ef_state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, err = _quant_one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(err)
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales)), \
        jax.tree.unflatten(tdef, errs)


def decompress_int8(q_tree):
    qs, scales = q_tree
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)
