"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088].
8 experts < 16-way model axis, so experts replicate and the expert-internal
width shards (TP-in-expert) — see sharding_overrides. SWA makes decode
memory O(window) -> runs long_500k with a rolling 4096-slot cache.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    moe_d_ff=16384,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    sharding_overrides=(("w_experts", None), ("w_expert_mlp", "model")),
    subquadratic=True,
)
