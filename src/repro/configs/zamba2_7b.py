"""zamba2-7b [hybrid] — Mamba2 backbone + one weight-shared attention block
applied every 6 layers (applied via lax.cond inside the layer scan).

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242]. Sub-quadratic (SSM state; the shared-attn KV cache is
the only seq-length-bound memory) -> runs long_500k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    block_pattern="mamba_shared_attn",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    shared_attn_every=6,
    subquadratic=True,
)
