"""qwen3-14b [dense] — per-head q/k RMSNorm, GQA kv=8.

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 [hf:Qwen/Qwen3-14B].
40 heads on a 16-way model axis shards unevenly (GSPMD pads to 48) — noted
in the roofline. Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
