"""command-r-plus-104b [dense] — GQA kv=8, no bias; largest dense config
(FSDP on the 'data' axis is essential to fit optimizer state).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-plus]. Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
)
