"""Architecture registry: one module per assigned architecture.

``get_config(name)`` accepts either the assignment id ("qwen3-14b") or the
module name ("qwen3_14b").
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

ARCHS = [
    "xlstm_350m",
    "pixtral_12b",
    "zamba2_7b",
    "codeqwen1_5_7b",
    "command_r_plus_104b",
    "qwen3_14b",
    "yi_9b",
    "seamless_m4t_large_v2",
    "deepseek_v2_236b",
    "mixtral_8x22b",
]


def canonical(name: str) -> str:
    mod = name.replace("-", "_").replace(".", "_")
    if mod not in ARCHS:
        raise KeyError(f"unknown architecture {name!r}; known: {ARCHS}")
    return mod


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "TrainConfig",
           "get_config", "all_configs", "canonical"]
