"""codeqwen1.5-7b [dense] — qwen1.5 arch: QKV bias, MHA (kv = heads).

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B].  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attn_bias=True,
    rope_theta=1_000_000.0,
)
