"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB: precomputed patch
embeddings per assignment) + mistral-nemo decoder backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409]. head_dim=128 (nemo uses explicit 128,
not d_model/n_heads). Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=256,
)
