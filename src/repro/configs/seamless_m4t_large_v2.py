"""seamless-m4t-large-v2 [audio] — encoder-decoder; the audio frontend is a
STUB per assignment (input_specs supplies precomputed frame embeddings that
feed the encoder directly).

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596].
Decode shapes lower the DECODER step (self-attn KV cache of seq_len +
fixed cross-attn KV over the encoder memory). Full attention -> long_500k
skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
)

# encoder memory length used by decode-shape cells (frames after the stub
# frontend's downsampling); train/prefill shapes drive enc len = seq_len.
DECODE_ENC_LEN = 4096
