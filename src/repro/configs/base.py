"""Architecture + run configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<arch>.py``; reduced variants for CPU smoke tests come from
``ModelConfig.reduced()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention flavour
    attn_type: str = "gqa"  # gqa | mla
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: int = 0  # 0 -> full attention
    rope_theta: float = 10_000.0

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0  # 0 -> head_dim

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"  # einsum (GShard dispatch) | gmm (grouped matmul)
    router_aux_coef: float = 0.01

    # SSM / hybrid / xlstm
    block_pattern: str = "attn"  # attn | xlstm_pair | mamba_shared_attn
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    shared_attn_every: int = 0  # zamba2: shared attn block cadence

    # enc-dec (seamless)
    encoder_layers: int = 0  # >0 -> encoder-decoder; n_layers = decoder depth

    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = ""  # "" | vision | audio
    frontend_len: int = 256  # patches/frames consumed per example (vision only)

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True
    # per-arch overrides of the logical->mesh sharding rules
    sharding_overrides: tuple[tuple[str, Any], ...] = ()
    # set for archs whose decode path is sub-quadratic (SSM state / SWA):
    # required to run the long_500k shape.
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/unembed rows padded so the vocab dim tiles any mesh
        axis (logits are sliced back to vocab_size)."""
        pad = 2048
        if self.vocab_size % pad == 0 or self.vocab_size < 4 * pad:
            return self.vocab_size if self.vocab_size % 16 == 0 else \
                -(-self.vocab_size // 16) * 16
        return -(-self.vocab_size // pad) * pad

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, **kw) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )
        if self.attn_type == "mla":
            small.update(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8, v_head_dim=16)
        if self.n_experts:
            small.update(n_experts=min(self.n_experts, 8), moe_d_ff=64,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         top_k=min(self.top_k, 2))
        if self.sliding_window:
            small.update(sliding_window=32)
        if self.encoder_layers:
            small.update(encoder_layers=2)
        if self.block_pattern == "mamba_shared_attn":
            small.update(shared_attn_every=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.block_pattern == "xlstm_pair":
            small.update(n_layers=4, ssm_chunk=16)
        if self.frontend:
            small.update(frontend_len=8)
        small.update(kw)
        return self.replace(**small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (seq_len x global_batch + which step it lowers)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / runtime knobs for the training driver."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    microbatches: int = 1  # grad accumulation
    grad_compression: str = "none"  # none | int8_ef
    checkpoint_every: int = 50
    lease_seconds: float = 0.0  # 0 -> unbounded (no chaining)
