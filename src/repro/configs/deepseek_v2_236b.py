"""deepseek-v2-236b [moe] — MLA (kv_lora=512, decoupled RoPE 64) + MoE with
2 shared + 160 routed experts, top-6; first layer dense (as released).

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400 [arXiv:2405.04434].
EP: experts shard on the 16-way model axis (10 experts/chip); the MoE
dispatch/combine einsums are the in-model analogue of the paper's SQS
shuffle (DESIGN.md §2). Decode caches the 512-d latent + 64-d rope key
per token — not per-head K/V. Full attention (over latent) -> long_500k
skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,       # nope head dim
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    d_ff=12288,          # the dense first layer (as released)
    moe_d_ff=1536,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    vocab_size=102400,
    capacity_factor=1.25,
)
