"""xlstm-350m [ssm] — sLSTM + mLSTM pairs, no separate FFN (d_ff=0).

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517].
Sub-quadratic (recurrent state) -> runs long_500k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    block_pattern="xlstm_pair",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
    subquadratic=True,
)
