"""train_step / serve_step builders.

These close over (ModelConfig, TrainConfig) and return pure functions
suitable for jax.jit with explicit in/out shardings — the same functions
are used by the CPU smoke tests, the training driver, and the multi-pod
dry-run (where they are lowered against ShapeDtypeStructs and never run).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import lm
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         compress_int8_ef, decompress_int8, ef_state_init)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any  # error-feedback residuals (grad compression) or None


def init_train_state(cfg: ModelConfig, tc: TrainConfig, key) -> TrainState:
    params = lm.init(cfg, key)
    ef = ef_state_init(params) if tc.grad_compression == "int8_ef" else None
    return TrainState(params, adamw_init(params), ef)


def abstract_train_state(cfg: ModelConfig, tc: TrainConfig) -> TrainState:
    return jax.eval_shape(
        lambda: init_train_state(cfg, tc, jax.random.PRNGKey(0)))


def _split_microbatches(batch, n):
    return [jax.tree.map(lambda x: x[i::n], batch) for i in range(n)]


def build_train_step(cfg: ModelConfig, tc: TrainConfig, attn_impl="auto"):
    """Returns step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        return lm.loss_fn(params, batch, cfg, attn_impl=attn_impl)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def step(state: TrainState, batch):
        if tc.microbatches > 1:
            # gradient accumulation: k sequential micro-steps; keeps the
            # activation working set 1/k and lets XLA overlap the reduce
            # of micro-grad i with the compute of micro-batch i+1.
            mbs = _split_microbatches(batch, tc.microbatches)
            (loss, metrics), grads = grad_fn(state.params, mbs[0])
            for mb in mbs[1:]:
                (l2, m2), g2 = grad_fn(state.params, mb)
                loss = loss + l2
                metrics = jax.tree.map(jnp.add, metrics, m2)
                grads = jax.tree.map(jnp.add, grads, g2)
            inv = 1.0 / tc.microbatches
            loss = loss * inv
            metrics = jax.tree.map(lambda x: x * inv, metrics)
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        ef = state.ef
        if tc.grad_compression == "int8_ef":
            # int8 + error feedback on the DP-reduced gradients: numerically
            # identical to all-reducing int8 payloads + scales (4x less DCN
            # traffic across the pod axis); the residual re-enters next step.
            q, ef = compress_int8_ef(grads, state.ef)
            grads = decompress_int8(q)

        params, opt, opt_metrics = adamw_update(state.params, grads,
                                                state.opt, tc)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params, opt, ef), metrics

    return step


def build_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        return lm.prefill(params, batch, cfg)
    return prefill


def build_decode_step(cfg: ModelConfig, kv_len: int):
    def decode(params, token, pos, caches):
        return lm.decode_step(params, token, pos, caches, cfg, kv_len=kv_len)
    return decode
