"""Lease-based fault-tolerant training driver — Flint's serverless
execution model applied to the training plane.

The driver never assumes it survives the run (paper C1/C3): it executes
inside a bounded LEASE; when the lease expires — or a (simulated)
preemption/node failure fires — state is already externalized (sharded
checkpoint, data cursor = the step index) and a fresh driver resumes
bit-exactly. ``train()`` returns a status so callers/chained invocations
know whether to re-enter, exactly like the scheduler re-invoking a warm
executor with the continuation cursor.

Determinism contract making replay exact:
  * batches are a pure function of (seed, step) (repro.data.synthetic);
  * the train step is a deterministic jit'd function;
  * checkpoints are atomic; a restart can only see a committed step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import lm_batch
from repro.runtime import steps as steps_mod


class Preempted(RuntimeError):
    """Simulated node failure / spot reclaim."""


@dataclasses.dataclass
class FailureInjector:
    at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise Preempted(f"injected preemption at step {step}")


@dataclasses.dataclass
class TrainReport:
    status: str  # "finished" | "lease_expired" | "preempted"
    start_step: int
    end_step: int
    metrics: list
    wall_s: float


def train(cfg: ModelConfig, tc: TrainConfig, *, workdir: str,
          batch_fn: Callable[[int], dict] | None = None,
          step_fn=None, injector: FailureInjector | None = None,
          log_every: int = 10, verbose: bool = False) -> TrainReport:
    """Run (or resume) training under one lease. Re-enterable."""
    t0 = time.monotonic()
    mgr = CheckpointManager(workdir)
    step_fn = step_fn or jax.jit(steps_mod.build_train_step(cfg, tc),
                                 donate_argnums=0)
    batch_fn = batch_fn or (lambda i: lm_batch(
        tc.seed, i, 8, 128, cfg.vocab_size))

    # ---- restore or init (elastic: works on any device count)
    abstract = steps_mod.abstract_train_state(cfg, tc)
    start = mgr.latest()
    if start is None:
        state = steps_mod.init_train_state(cfg, tc,
                                           jax.random.PRNGKey(tc.seed))
        start = 0
    else:
        state = mgr.restore(abstract, step=start)

    deadline = (time.monotonic() + tc.lease_seconds
                if tc.lease_seconds > 0 else None)
    metrics_log: list[dict] = []
    status = "finished"
    step = start
    try:
        for step in range(start, tc.total_steps):
            injector and injector.check(step)
            state, metrics = step_fn(state, batch_fn(step))
            if (step + 1) % log_every == 0 or step + 1 == tc.total_steps:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step + 1
                metrics_log.append(row)
                if verbose:
                    print(f"step {row['step']}: loss={row['loss']:.4f} "
                          f"lr={row['lr']:.2e} gnorm={row['grad_norm']:.3f}",
                          flush=True)
            if (step + 1) % tc.checkpoint_every == 0:
                mgr.save(step + 1, state)
            if deadline and time.monotonic() > deadline:
                status = "lease_expired"
                step += 1
                break
        else:
            step = tc.total_steps
    except Preempted:
        # state since last checkpoint is lost — exactly like a real failure
        status = "preempted"
    if status != "preempted":
        mgr.save(step, state, blocking=True)
    mgr.wait()
    return TrainReport(status, start, step, metrics_log,
                       time.monotonic() - t0)


def train_with_restarts(cfg: ModelConfig, tc: TrainConfig, *, workdir: str,
                        max_restarts: int = 10, **kw) -> list[TrainReport]:
    """Chain leases until training finishes — the scheduler loop that
    re-invokes 'executors' (driver runs) as they expire or die."""
    reports = []
    for _ in range(max_restarts + 1):
        rep = train(cfg, tc, workdir=workdir, **kw)
        reports.append(rep)
        if rep.status == "finished":
            break
    return reports
