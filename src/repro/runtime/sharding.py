"""Logical-axis -> mesh-axis resolution (MaxText-style sharding rules).

Parameters declare *logical* axes in their schemas (repro.common.param.P);
this module maps them onto the physical mesh:

  w_vocab / w_heads / w_kv_heads / w_mlp  -> 'model'   (tensor parallel)
  w_experts                               -> 'model'   (expert parallel)
  w_expert_mlp                            -> None      (see mixtral override)
  w_embed                                 -> 'data'    (FSDP / ZeRO-3: the
                                             SPMD partitioner inserts the
                                             per-layer all-gathers)
  everything else                         -> replicated

The 'pod' axis (multi-pod mesh) carries pure data parallelism: batch dims
shard on ('pod', 'data'); weights are replicated across pods so the only
cross-pod (DCN) traffic is the gradient all-reduce.

Per-arch overrides come from ModelConfig.sharding_overrides (e.g. mixtral
swaps EP for TP-in-expert because 8 experts < 16-way model axis).
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common import param as pm
from repro.configs.base import ModelConfig

DEFAULT_RULES: dict[str, Any] = {
    "w_vocab": "model",
    "w_heads": "model",
    "w_kv_heads": "model",
    "w_mlp": "model",
    "w_experts": "model",
    "w_expert_mlp": None,
    "w_embed": "data",
    "layers": None,
    # activation logical axes (used by constrain())
    "act_batch": ("pod", "data"),
    "act_group": ("pod", "data"),
    "act_experts": "model",
    "act_heads": "model",
    "act_mlp": "model",
    # sequence parallelism for the per-layer saved residual stream: without
    # this the remat-saved layer inputs replicate across 'model' and the
    # train shapes cannot fit HBM (Megatron-SP, applied at scan boundaries).
    "act_seq": "model",
}

_active_rules: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


def rules_for(cfg: ModelConfig) -> dict:
    rules = dict(DEFAULT_RULES)
    rules.update(dict(cfg.sharding_overrides))
    return rules


@contextlib.contextmanager
def use_rules(rules: dict):
    token = _active_rules.set(rules)
    try:
        yield
    finally:
        _active_rules.reset(token)


def _mesh_axis_names():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None:
            return ()
        return tuple(m.axis_names)
    except Exception:
        return ()


def _filter_axis(axis, names):
    """Drop mesh axes that don't exist on the active mesh (e.g. 'pod' on a
    single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in names else None
    sub = tuple(a for a in axis if a in names)
    return sub if len(sub) > 1 else (sub[0] if sub else None)


def resolve_spec(logical_axes: tuple, rules: dict, mesh_names: tuple,
                 shape: tuple | None = None, mesh: Mesh | None = None) -> P:
    """logical axis names -> PartitionSpec, dropping non-divisible shardings:
    jit input shardings must tile evenly, so a dim that doesn't divide the
    axis product (e.g. qwen3's 40 heads on a 16-way model axis) replicates
    instead — the 'uneven-head tax' called out in the roofline notes."""
    out = []
    for i, name in enumerate(logical_axes):
        axis = _filter_axis(rules.get(name), mesh_names)
        if axis is not None and shape is not None and mesh is not None:
            axes = (axis,) if isinstance(axis, str) else axis
            n = math.prod(mesh.shape[a] for a in axes)
            if shape[i] % n:
                axis = None  # not evenly shardable -> replicate
        out.append(axis)
    return P(*out)


def param_pspecs(cfg: ModelConfig, schema, mesh: Mesh):
    """PartitionSpec tree matching ``schema`` (a tree of P entries)."""
    rules = rules_for(cfg)
    names = tuple(mesh.axis_names)

    def one(p: pm.P):
        return resolve_spec(p.axes, rules, names, p.shape, mesh)

    return jax.tree.map(one, schema, is_leaf=pm.is_leaf)


def param_shardings(cfg: ModelConfig, schema, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, schema, mesh))


def constrain(x, *logical_axes):
    """In-model activation sharding hint; no-op outside a mesh context."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return x
        names = tuple(m.axis_names)
    except Exception:
        return x
    rules = _active_rules.get() or DEFAULT_RULES
    out = []
    for i, name in enumerate(tuple(logical_axes)):
        axis = _filter_axis(rules.get(name), names)
        if axis is not None:
            axes = (axis,) if isinstance(axis, str) else axis
            n = math.prod(m.shape[a] for a in axes)
            if x.shape[i] % n:
                axis = None  # don't force padded activation shards
        out.append(axis)
    try:
        return jax.lax.with_sharding_constraint(x, P(*out))
    except Exception:
        return x
