"""Rule-based logical-plan optimizer.

Rewrites, in order:

  1. PUSHDOWN FIXPOINT — local rules applied bottom-up until the plan
     stops changing:
       * combine adjacent Filters / Projects / Limits,
       * predicate pushdown through Project (rewriting the predicate in
         terms of the project's inputs), below Join (AND-conjuncts split
         and routed to the side(s) whose columns they mention — key-only
         conjuncts go to BOTH sides), and below Aggregate (conjuncts on
         grouping keys only).
     A NON-DETERMINISTIC expression blocks pushdown: filtering earlier
     changes which rows it is evaluated on, and substituting it into a
     predicate would re-evaluate it — either way results change.
  2. PROJECTION PRUNING — a top-down required-columns pass that narrows
     every Project, drops unused aggregates, pushes the needed column
     set INTO the Scan (only those fields are parsed), and inserts
     narrowing Projects directly below shuffle operators so shuffles
     ship only referenced columns.
  3. PARTIAL-AGGREGATION SELECTION — an Aggregate whose aggregates are
     all algebraic (sum/count/min/max/avg) lowers to map-side-combine
     reduceByKey; collect_list forces the groupByKey lowering.
  4. TRANSPORT CHOICE — when the engine default is "auto", each shuffle
     (Aggregate/Join) gets a cost-model SQS-vs-S3 choice from estimated
     input bytes (scan size x selectivity/width factors, or the RDD
     lineage estimator for toDF sources) and the ledger's prices.

Lowering with ``optimize=False`` skips all four — the benchmark's A/B
baseline.
"""

from __future__ import annotations

from repro.core import costs
from repro.core.dag import estimate_lineage_bytes
from repro.sql.expr import (Col, Lit, join_conjuncts, split_conjuncts)
from repro.sql.plan import (Aggregate, Cached, Filter, Join, Limit, Plan,
                            Project, RddScan, Scan, Sort, Window,
                            explain_str)

#: map-side combine ships partially-merged values; assume it halves bytes
PARTIAL_COMBINE_FACTOR = 0.5
#: rough per-value wire widths for the projection-ratio estimate
_DTYPE_WIDTH = {"int": 8, "float": 8, "bool": 1, "str": 16}


def optimize(plan: Plan, ctx=None) -> Plan:
    """Full rewrite. ``ctx`` supplies the store (scan sizes) and config
    (whether transport choice applies) — without it the size-dependent
    transport rule is skipped."""
    plan = _fixpoint(plan)
    plan = _prune(plan, list(plan.schema().names))
    plan = _fixpoint(plan)  # collapse projects pruning introduced
    plan = _choose_partial(plan)
    if ctx is not None and ctx.config.shuffle_backend == "auto":
        _choose_transport(plan, ctx)
    return plan


# ------------------------------------------------------ pushdown fixpoint


def _fixpoint(plan: Plan, max_rounds: int = 20) -> Plan:
    before = explain_str(plan)
    for _ in range(max_rounds):
        plan = _rewrite(plan)
        after = explain_str(plan)
        if after == before:
            return plan
        before = after
    return plan


def _col_counts(e, counts: dict) -> None:
    if isinstance(e, Col):
        counts[e.name] = counts.get(e.name, 0) + 1
    for c in e.children():
        _col_counts(c, counts)


def _inline_safe(outer_exprs, inner_cols) -> bool:
    """Substituting inner definitions into the outer expressions must not
    DUPLICATE non-trivial subtrees: a column referenced twice whose
    definition is itself a composite doubles the tree, and chained merges
    turn that into exponential growth (both in plan size and in what
    serde ships to every task). Trivial definitions (bare columns,
    literals) inline freely."""
    counts: dict = {}
    for e in outer_exprs:
        _col_counts(e, counts)
    for name, ie in inner_cols:
        if isinstance(ie, (Col, Lit)):
            continue
        if counts.get(name, 0) > 1:
            return False
    return True


def _rewrite(node: Plan) -> Plan:
    node = node.with_children([_rewrite(c) for c in node.children()])
    if isinstance(node, Filter):
        return _rewrite_filter(node)
    if (isinstance(node, Project) and isinstance(node.child, Project)
            and not isinstance(node, Window)
            and not isinstance(node.child, Window)):
        # Window is a Project structurally but keeps its identity —
        # merging would dissolve the window spec out of the plan
        inner = node.child
        if (all(e.deterministic for _, e in inner.cols)
                and _inline_safe([e for _, e in node.cols], inner.cols)):
            mapping = {n: e for n, e in inner.cols}
            return Project(inner.child,
                           [(n, e.substitute(mapping))
                            for n, e in node.cols])
    if isinstance(node, Limit) and isinstance(node.child, Limit):
        return Limit(node.child.child, min(node.n, node.child.n))
    return node


def _rewrite_filter(node: Filter) -> Plan:
    child = node.child
    if isinstance(child, Filter):
        return Filter(child.child,
                      join_conjuncts(split_conjuncts(child.pred)
                                     + split_conjuncts(node.pred)))
    if isinstance(child, Project):
        if not _inline_safe([node.pred], child.cols):
            return node
        mapping = {n: e for n, e in child.cols}
        sub = node.pred.substitute(mapping)
        if sub.deterministic:
            if isinstance(child, Window):
                # push below the window, keep the Window node on top
                # (the pane column substitutes to its defining arithmetic)
                return child.with_children([Filter(child.child, sub)])
            return Project(Filter(child.child, sub), child.cols)
        return node
    if isinstance(child, Join):
        return _push_filter_join(node, child)
    if isinstance(child, Aggregate):
        return _push_filter_aggregate(node, child)
    return node


def _push_filter_join(node: Filter, join: Join) -> Plan:
    if join.how != "inner":
        # an outer side resurrects filtered rows as None-padded output
        # (and key predicates pushed to the preserved side change which
        # rows pad vs match) — pushdown is only sound for inner joins
        return node
    lnames = set(join.left.schema().names)
    rnames = set(join.right.schema().names)
    on = set(join.on)
    to_left, to_right, kept = [], [], []
    for conj in split_conjuncts(node.pred):
        refs = conj.refs()
        if not conj.deterministic:
            kept.append(conj)
        elif refs <= on:
            # a key-only predicate holds on BOTH sides of an inner
            # equi-join: push two copies, shrink both shuffles
            to_left.append(conj)
            to_right.append(conj)
        elif refs <= lnames:
            to_left.append(conj)
        elif refs <= rnames:
            to_right.append(conj)
        else:
            kept.append(conj)
    if not to_left and not to_right:
        return node
    left = Filter(join.left, join_conjuncts(to_left)) if to_left \
        else join.left
    right = Filter(join.right, join_conjuncts(to_right)) if to_right \
        else join.right
    out: Plan = Join(left, right, join.on, join.nparts, join.how,
                     join.transport)
    if kept:
        out = Filter(out, join_conjuncts(kept))
    return out


def _push_filter_aggregate(node: Filter, agg: Aggregate) -> Plan:
    """Conjuncts referencing only the GROUPING KEYS filter the same
    groups whether applied before or after aggregation — push them below
    (rewritten in terms of the key expressions). Anything touching an
    aggregate output stays above."""
    key_names = {n for n, _ in agg.keys}
    mapping = {n: e for n, e in agg.keys}
    if not all(e.deterministic for e in mapping.values()):
        return node
    pushed, kept = [], []
    for conj in split_conjuncts(node.pred):
        sub = conj.substitute(mapping)
        if conj.refs() <= key_names and sub.deterministic:
            pushed.append(sub)
        else:
            kept.append(conj)
    if not pushed:
        return node
    out: Plan = Aggregate(Filter(agg.child, join_conjuncts(pushed)),
                          agg.keys, agg.aggs, agg.nparts, agg.partial,
                          agg.transport)
    if kept:
        out = Filter(out, join_conjuncts(kept))
    return out


# ----------------------------------------------------- projection pruning


def _ordered(names: set, schema) -> list:
    return [n for n in schema.names if n in names]


def _narrow(child: Plan, needed: set) -> Plan:
    """Insert a pass-through Project when ``child`` carries columns a
    shuffle above it does not need — shuffles ship only what is used."""
    names = child.schema().names
    if set(names) <= needed:
        return child
    keep = [n for n in names if n in needed]
    return Project(child, [(n, Col(n)) for n in keep])


def _prune(node: Plan, required: list) -> Plan:
    req = set(required)
    if isinstance(node, Scan):
        keep = _ordered(req, node.full_schema) or [node.full_schema.names[0]]
        return Scan(node.key, node.full_schema, node.nparts, keep)
    if isinstance(node, RddScan):
        # the source RDD's rows are fixed; narrow immediately above it
        return _narrow(node, req)
    if isinstance(node, Window):
        # a Window passes every child column through; pruning the CHILD
        # to what is needed above (plus the event-time column the pane
        # derives from) narrows it, and rebuilding re-derives the
        # passthrough list from the narrowed child schema
        child_req = (req - {node.name}) | {node.ts_col}
        child = _prune(node.child, _ordered(child_req,
                                            node.child.schema()))
        return Window(child, node.ts_col, node.size, node.slide,
                      node.name)
    if isinstance(node, Project):
        cols = [(n, e) for n, e in node.cols if n in req]
        if not cols:
            cols = [node.cols[0]]
        child_req = set()
        for _, e in cols:
            child_req |= e.refs()
        return Project(_prune(node.child, _ordered(child_req,
                                                   node.child.schema())),
                       cols)
    if isinstance(node, Filter):
        child_req = req | node.pred.refs()
        return Filter(_prune(node.child, _ordered(child_req,
                                                  node.child.schema())),
                      node.pred)
    if isinstance(node, Aggregate):
        aggs = [(n, a) for n, a in node.aggs if n in req]
        child_req = set()
        for _, e in node.keys:
            child_req |= e.refs()
        for _, a in aggs:
            child_req |= a.refs()
        child = _prune(node.child, _ordered(child_req,
                                            node.child.schema()))
        return Aggregate(_narrow(child, child_req), node.keys, aggs,
                         node.nparts, node.partial, node.transport)
    if isinstance(node, Join):
        on = set(node.on)
        lreq = (req | on) & set(node.left.schema().names)
        rreq = (req | on) & set(node.right.schema().names)
        left = _prune(node.left, _ordered(lreq, node.left.schema()))
        right = _prune(node.right, _ordered(rreq, node.right.schema()))
        return Join(_narrow(left, lreq), _narrow(right, rreq), node.on,
                    node.nparts, node.how, node.transport)
    if isinstance(node, Sort):
        child_req = set(req)
        for e, _ in node.keys:
            child_req |= e.refs()
        return Sort(_prune(node.child, _ordered(child_req,
                                                node.child.schema())),
                    node.keys)
    if isinstance(node, Limit):
        return Limit(_prune(node.child, required), node.n)
    if isinstance(node, Cached):
        # barrier: the materialization must stay query-independent, so
        # everything below it is required in full (derived queries with
        # different projections still share one cache token)
        return Cached(_prune(node.child,
                             list(node.child.schema().names)))
    raise TypeError(f"unknown plan node {type(node).__name__}")


# ------------------------------------------- partial-aggregate selection


def _choose_partial(node: Plan) -> Plan:
    node = node.with_children([_choose_partial(c)
                               for c in node.children()])
    if isinstance(node, Aggregate):
        partial = all(a.algebraic for _, a in node.aggs)
        return Aggregate(node.child, node.keys, node.aggs, node.nparts,
                         partial, node.transport)
    return node


# --------------------------------------------------- transport selection


def _row_width(schema) -> float:
    return sum(_DTYPE_WIDTH.get(t, 32) for _, t in schema) or 1.0


def _choose_transport(node: Plan, ctx) -> tuple:
    """Bottom-up (estimated bytes, partition count) walk; Aggregate/Join
    nodes get their SQS-vs-S3 choice from the cost model. Mutates the
    shuffle nodes' ``transport`` in place (the tree shape is final by
    now)."""
    if isinstance(node, Scan):
        try:
            total = float(ctx.store.size(node.key))
        except Exception:
            total = 0.0
        ratio = _row_width(node.schema()) / _row_width(node.full_schema)
        return total * ratio, node.nparts
    if isinstance(node, RddScan):
        try:
            est = estimate_lineage_bytes(node.rdd, ctx._cache_index)
        except Exception:
            est = 0.0
        return est, node.rdd.nparts
    if isinstance(node, Project):
        b, p = _choose_transport(node.child, ctx)
        ratio = (_row_width(node.schema())
                 / _row_width(node.child.schema()))
        return b * ratio, p
    if isinstance(node, Filter):
        b, p = _choose_transport(node.child, ctx)
        return b * costs.EST_FILTER_SELECTIVITY, p
    if isinstance(node, Aggregate):
        b, p = _choose_transport(node.child, ctx)
        shuffled = b * (PARTIAL_COMBINE_FACTOR if node.partial else 1.0)
        nparts = node.nparts or p
        if node.transport is None:
            node.transport = costs.pick_shuffle_transport(shuffled, p,
                                                          nparts)
        return b * costs.EST_AGG_OUTPUT_FACTOR, nparts
    if isinstance(node, Join):
        lb, lp = _choose_transport(node.left, ctx)
        rb, rp = _choose_transport(node.right, ctx)
        nparts = node.nparts or max(lp, rp)
        if node.transport is None:
            node.transport = costs.pick_shuffle_transport(
                lb + rb, max(lp, rp), nparts)
        return max(lb, rb), nparts
    if isinstance(node, (Sort, Limit, Cached)):
        return _choose_transport(node.child, ctx)
    raise TypeError(f"unknown plan node {type(node).__name__}")
