"""The user-facing DataFrame: a logical plan plus the context to run it.

    df = ctx.read_csv("taxi.csv", schema, 8)        # or rdd.toDF(schema)
    out = (df.where(col("payment_type") == lit("credit"))
             .withColumn("hour", col("pickup").substr(12, 2))
             .groupBy("hour")
             .agg(sum_(col("tip")).alias("tips"), count_().alias("n"))
             .orderBy("tips", ascending=False)
             .limit(5)
             .collect())                            # list of tuples
    print(df.explain())                             # optimized plan tree

Rows are plain tuples in schema order. ``collect``/``count``/``explain``
take ``optimize=False`` to run the naive lowering — the benchmark's A/B
baseline. ``limit`` is a FINAL operator: after it, only more
orderBy/limit/actions may follow (the lowering splits the root chain
between per-partition ops and a driver finish). ``orderBy`` keeps the
frame open: under ``FlintConfig.adaptive`` it executes as a distributed
range-partitioned sort wherever it sits in the plan
(docs/adaptive_execution.md); without adaptive, a root orderBy falls
back to the driver-side sort of the collected rows.
"""

from __future__ import annotations

from typing import Iterable

from repro.sql import plan as P
from repro.sql.expr import (AggExpr, Alias, Col, Expr, Schema, _as_expr)
from repro.sql.lower import apply_driver_ops, lower, vector_markers
from repro.sql.optimizer import optimize


def _as_schema(schema) -> Schema:
    return schema if isinstance(schema, Schema) else Schema(schema)


def _named(c, what: str):
    """Resolve a select/groupBy argument to a (name, Expr) pair."""
    if isinstance(c, str):
        return (c, Col(c))
    if isinstance(c, Alias):
        return (c.name, c.child)
    if isinstance(c, Col):
        return (c.name, c)
    if isinstance(c, Expr):
        raise ValueError(f"{what} expression {c.sql()} needs "
                         f".alias(name)")
    raise TypeError(f"bad {what} argument {c!r}")


class GroupedData:
    def __init__(self, df: "DataFrame", keys: tuple):
        self._df = df
        self._keys = keys

    def agg(self, *aggs: AggExpr, numPartitions: int | None = None,
            transport: str | None = None) -> "DataFrame":
        if not aggs:
            raise ValueError("agg() needs at least one aggregate")
        named = []
        for a in aggs:
            if not isinstance(a, AggExpr):
                raise TypeError(f"agg() takes sum_/count_/min_/max_/avg_/"
                                f"collect_list expressions, got {a!r}")
            named.append((a.name, a))
        node = P.Aggregate(self._df.plan, self._keys, named,
                           nparts=numPartitions, transport=transport)
        node.schema()  # validate eagerly: unknown columns, bad dtypes
        return DataFrame(self._df.ctx, node)


class DataFrame:
    def __init__(self, ctx, plan: P.Plan, *, final: bool = False):
        self.ctx = ctx
        self.plan = plan
        self._final = final  # a limit is in place

    # ------------------------------------------------------ constructors
    @classmethod
    def from_csv(cls, ctx, key: str, schema, numPartitions: int = 8
                 ) -> "DataFrame":
        return cls(ctx, P.Scan(key, _as_schema(schema), numPartitions))

    @classmethod
    def from_rdd(cls, rdd, schema) -> "DataFrame":
        return cls(rdd.ctx, P.RddScan(rdd, _as_schema(schema)))

    # ----------------------------------------------------------- schema
    @property
    def schema(self) -> Schema:
        return self.plan.schema()

    @property
    def columns(self) -> tuple:
        return self.schema.names

    # ------------------------------------------------- transformations
    def _require_open(self, what: str):
        if self._final:
            raise ValueError(f"{what} after limit is not supported — "
                             f"limit is a final operator")

    def _derive(self, plan: P.Plan, final: bool = False) -> "DataFrame":
        plan.schema()  # eager validation at call site
        return DataFrame(self.ctx, plan, final=final or self._final)

    def select(self, *cols) -> "DataFrame":
        self._require_open("select")
        named = [_named(c, "select") for c in cols]
        return self._derive(P.Project(self.plan, named))

    def withColumn(self, name: str, e) -> "DataFrame":
        self._require_open("withColumn")
        e = _as_expr(e)
        if name in self.columns:
            # replace IN PLACE — positional row access keeps working
            cols = [(n, e if n == name else Col(n))
                    for n in self.columns]
        else:
            cols = [(n, Col(n)) for n in self.columns] + [(name, e)]
        return self._derive(P.Project(self.plan, cols))

    def where(self, pred: Expr) -> "DataFrame":
        self._require_open("where")
        return self._derive(P.Filter(self.plan, pred))

    filter = where

    def withWindow(self, ts_col: str, size: int, slide: int | None = None,
                   name: str = "window_start") -> "DataFrame":
        """Assign each row an event-time window PANE start column
        (``ts - ts % slide``; tumbling when slide is omitted). The same
        node drives the streaming engine's windowed aggregation
        (repro.streaming, docs/streaming.md) — a batch
        ``withWindow(...).groupBy(name, ...)`` over the full data is the
        reference query a streamed run must reproduce."""
        self._require_open("withWindow")
        return self._derive(P.Window(self.plan, ts_col, size, slide,
                                     name))

    def groupBy(self, *keys) -> GroupedData:
        self._require_open("groupBy")
        if not keys:
            raise ValueError("groupBy() needs at least one key")
        named = tuple(_named(k, "groupBy") for k in keys)
        return GroupedData(self, named)

    def join(self, other: "DataFrame", on, numPartitions: int | None = None,
             how: str = "inner", transport: str | None = None
             ) -> "DataFrame":
        self._require_open("join")
        other._require_open("join")
        on = [on] if isinstance(on, str) else list(on)
        return self._derive(P.Join(self.plan, other.plan, on,
                                   nparts=numPartitions, how=how,
                                   transport=transport))

    def orderBy(self, *keys, ascending=True) -> "DataFrame":
        if not keys:
            raise ValueError("orderBy() needs at least one key")
        if isinstance(ascending, bool):
            ascending = [ascending] * len(keys)
        elif len(ascending) != len(keys):
            raise ValueError(f"orderBy: {len(keys)} keys but "
                             f"{len(ascending)} ascending flags")

        def sort_key(k) -> Expr:
            if isinstance(k, str):
                return Col(k)
            if isinstance(k, Alias):
                return k.child
            if isinstance(k, Expr):
                return k
            raise TypeError(f"bad orderBy key {k!r}")

        named = tuple((sort_key(k), bool(asc))
                      for k, asc in zip(keys, ascending))
        # orderBy is no longer a FINAL operator: a root Sort lowers as a
        # distributed range-partitioned sort under FlintConfig.adaptive
        # (driver-side sort of the collected rows otherwise), and a Sort
        # below the root lowers the same distributed way — so the frame
        # stays open for further transforms
        return self._derive(P.Sort(self.plan, named))

    def limit(self, n: int) -> "DataFrame":
        if n < 0:
            raise ValueError("limit() needs n >= 0")
        return self._derive(P.Limit(self.plan, n), final=True)

    def cache(self) -> "DataFrame":
        """Materialize THIS frame's lowered lineage on first evaluation
        (RDD.cache underneath). Every query derived from the returned
        frame replans from the one shared materialization — the cache
        point is an optimizer barrier, so derived filters/projections do
        not specialize (and thereby miss) it."""
        self._require_open("cache")
        return self._derive(P.Cached(self.plan))

    def uncache(self, optimize: bool = True) -> int:
        """Drop the materializations behind every cache() point in this
        frame's lineage (``ctx.uncache`` per token — a shared byte-capped
        cache index honors its pins); returns the number of store keys
        removed, 0 when nothing was materialized."""
        rdd, _, _ = lower(self._planned(optimize), self.ctx)
        removed = 0
        stack, seen = [rdd], set()
        while stack:
            node = stack.pop()
            if id(node) in seen:
                continue
            seen.add(id(node))
            if getattr(node, "cached", False):
                removed += node.uncache()
            for attr in ("parent", "left", "right", "a", "b"):
                child = getattr(node, attr, None)
                if child is not None:
                    stack.append(child)
        return removed

    # ------------------------------------------------------------ actions
    def _planned(self, optimize_flag: bool) -> P.Plan:
        return optimize(self.plan, self.ctx) if optimize_flag else self.plan

    def collect(self, optimize: bool = True) -> list:
        rdd, merge_limit, driver_ops = lower(self._planned(optimize),
                                             self.ctx)
        rows = self.ctx.run_action(rdd, "collect", limit=merge_limit)
        return apply_driver_ops(rows, driver_ops)

    def take(self, n: int, optimize: bool = True) -> list:
        return self.limit(n).collect(optimize=optimize)

    def count(self, optimize: bool = True) -> int:
        plan = self._planned(optimize)
        # Sort never changes cardinality — strip the root chain down to
        # its limits and count the cheapest equivalent plan (no driver
        # sort, no second optimizer pass)
        node, limits = plan, []
        while isinstance(node, (P.Sort, P.Limit)):
            if isinstance(node, P.Limit):
                limits.append(node.n)
            node = node.child
        if limits:
            rdd, merge_limit, driver_ops = lower(P.Limit(node,
                                                         min(limits)),
                                                 self.ctx)
            rows = self.ctx.run_action(rdd, "collect", limit=merge_limit)
            return len(apply_driver_ops(rows, driver_ops))
        rdd, _, _ = lower(node, self.ctx)
        return rdd.count()

    def explain(self, optimize: bool = True) -> str:
        """The logical plan as an indented tree (optimized by default) —
        what the golden plan-shape tests pin. With vectorization enabled
        each operator carries its execution mode: ``[vectorized]`` when
        its expressions compile to array kernels, ``[row-fallback: udf]``
        (etc.) when the lowering keeps the row closures."""
        plan = self._planned(optimize)
        markers = vector_markers(plan, getattr(self.ctx, "config", None))
        return P.explain_str(plan, markers)

    def __repr__(self):
        cols = ", ".join(f"{n}:{t}" for n, t in self.schema)
        return f"DataFrame[{cols}]"
