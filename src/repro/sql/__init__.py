"""Structured DataFrame surface over the Flint RDD engine.

Modern "PySpark exactly as before" means DataFrames, not raw RDDs: a
schema-carrying API whose queries become a LOGICAL PLAN, get rewritten by
a rule-based optimizer (projection pruning into the scan, predicate and
limit pushdown, map-side-combine selection, cost-model SQS-vs-S3
transport choice per shuffle), and lower onto the existing RDD lineage —
scheduler, EOS shuffle protocol, transports, CSE and cache() all apply
unchanged. See docs/dataframe.md.

    from repro.core import FlintContext
    from repro.sql import Schema, col, lit, sum_, count_

    ctx = FlintContext()
    df = ctx.read_csv("taxi.csv", Schema([("pickup", "str"), ...]), 8)
    (df.where(col("payment_type") == lit("credit"))
       .withColumn("hour", col("pickup").substr(12, 2))
       .groupBy("hour")
       .agg(sum_(col("tip")).alias("tips"), count_().alias("n"))
       .collect())
"""

from repro.sql.dataframe import DataFrame, GroupedData
from repro.sql.expr import (AggExpr, Alias, BinOp, Col, Expr, Lit, Schema,
                            avg_, col, collect_list, count_, lit, max_,
                            min_, sum_, udf)
from repro.sql.optimizer import optimize
from repro.sql.plan import explain_str

__all__ = ["DataFrame", "GroupedData", "Schema", "col", "lit", "udf",
           "sum_", "count_", "min_", "max_", "avg_", "collect_list",
           "optimize", "explain_str", "Expr", "Col", "Lit", "Alias",
           "BinOp", "AggExpr"]
