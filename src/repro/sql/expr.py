"""Schemas and the expression language for the DataFrame surface.

A ``Schema`` is an ordered list of (name, dtype) fields; dtypes are the
four scalar types the columnar wire format speaks natively ("int",
"float", "str", "bool") plus "list:<dtype>" for collect_list outputs.
Because the schema is declared, every lowered shuffle ships
schema-declared typed columnar batches (core.shuffle.batch) instead of
sniffing types per batch.

Expressions are small trees (``col``, ``lit``, arithmetic / comparison /
boolean operators, ``substr``, ``cast``, ``udf``) that know three things:

  * their output dtype given an input schema (schema propagation),
  * the column names they reference (drives projection pruning), and
  * whether they are DETERMINISTIC (a non-deterministic expression blocks
    predicate pushdown — re-evaluating it below a project or join would
    change results).

``bind(schema)`` compiles an expression to a plain row -> value closure;
the lowering maps those over RDD partitions, and core.serde ships them to
executors like any other task function (closures over lists of compiled
sub-expressions are why serde walks containers).

NULL semantics (SQL three-valued logic): outer joins pad unmatched rows
with None, so every operator here treats None as SQL NULL — arithmetic,
comparisons, substr and cast return None when an operand is None, and
``and``/``or`` follow the three-valued truth tables (False AND x is
False, True OR x is True, anything else involving NULL is NULL). A
Filter drops rows whose predicate evaluates to NULL, same as False. The
vectorized kernels keep row/vector parity by falling back to these row
closures whenever a batch carries None (repro.sql.vectorized). Udf is
the exception: user functions see the raw None and apply their own
semantics.
"""

from __future__ import annotations

import operator
from typing import Callable, Iterable

SCALAR_DTYPES = ("int", "float", "str", "bool")
_SERDE_CHAR = {"int": "i", "float": "f", "str": "s", "bool": "b"}


def dtype_serde_char(dtype: str) -> str:
    """Map a DataFrame dtype to the serde column-schema grammar."""
    if dtype.startswith("list:"):
        return "l(%s)" % dtype_serde_char(dtype[5:])
    return _SERDE_CHAR[dtype]


def _parse_bool(s: str) -> bool:
    return s.strip().lower() in ("true", "1", "t", "yes")


#: CSV field parsers (scan-time) — bools parse from text
CASTS: dict = {"int": int, "float": float, "str": str, "bool": _parse_bool}
#: cast() expression semantics on live values — bools follow Python truth
_RUNTIME_CASTS: dict = {"int": int, "float": float, "str": str,
                        "bool": bool}


class Schema:
    """Ordered, uniquely named, typed columns."""

    __slots__ = ("fields",)

    def __init__(self, fields: Iterable):
        fields = tuple((str(n), str(t)) for n, t in fields)
        seen = set()
        for name, dtype in fields:
            if name in seen:
                raise ValueError(f"duplicate column name {name!r} "
                                 f"(alias aggregate/select outputs)")
            seen.add(name)
            base = dtype[5:] if dtype.startswith("list:") else dtype
            if base not in SCALAR_DTYPES:
                raise ValueError(f"unknown dtype {dtype!r} for column "
                                 f"{name!r} (have {SCALAR_DTYPES})")
        self.fields = fields

    @property
    def names(self) -> tuple:
        return tuple(n for n, _ in self.fields)

    def index(self, name: str) -> int:
        for i, (n, _) in enumerate(self.fields):
            if n == name:
                return i
        raise KeyError(f"no column {name!r} in schema "
                       f"[{', '.join(self.names)}]")

    def dtype_of(self, name: str) -> str:
        return self.fields[self.index(name)][1]

    def select(self, names: Iterable[str]) -> "Schema":
        return Schema((n, self.dtype_of(n)) for n in names)

    def serde_tuple(self, names: Iterable[str] | None = None) -> str | None:
        """Declared key/value batch schema ("t(i,s,...)") for a tuple of
        these columns, or None for zero columns (nothing to declare)."""
        names = self.names if names is None else tuple(names)
        if not names:
            return None
        return "t(%s)" % ",".join(
            dtype_serde_char(self.dtype_of(n)) for n in names)

    def __len__(self):
        return len(self.fields)

    def __iter__(self):
        return iter(self.fields)

    def __eq__(self, other):
        return isinstance(other, Schema) and self.fields == other.fields

    def __hash__(self):
        return hash(self.fields)

    def __repr__(self):
        return "Schema([%s])" % ", ".join(f"{n}:{t}"
                                          for n, t in self.fields)


# ------------------------------------------------------------ expressions


class Expr:
    def children(self) -> list:
        return []

    def refs(self) -> set:
        out: set = set()
        for c in self.children():
            out |= c.refs()
        return out

    @property
    def deterministic(self) -> bool:
        return all(c.deterministic for c in self.children())

    def dtype(self, schema: Schema) -> str:
        raise NotImplementedError

    def bind(self, schema: Schema) -> Callable:
        raise NotImplementedError

    def bind_vec(self, schema: Schema) -> Callable:
        """Vectorized sibling of ``bind()``: compile to a whole-batch
        closure ``fn(cols, n) -> column`` evaluating numpy arrays /
        Python lists over a column batch (repro.sql.vectorized,
        docs/vectorized_execution.md). Raises
        ``vectorized.VectorizeUnsupported`` for expressions with no
        array form (udf, non-scalar operands) — the lowering then keeps
        the per-row closures for that operator."""
        from repro.sql.vectorized import compile_expr
        return compile_expr(self, schema)

    def substitute(self, mapping: dict) -> "Expr":
        """Replace column references per ``mapping`` (name -> Expr) —
        predicate pushdown through a Project rewrites in terms of the
        project's inputs."""
        raise NotImplementedError

    def sql(self) -> str:
        raise NotImplementedError

    # ------------------------------------------------- operator building
    def _bin(self, op: str, other) -> "BinOp":
        return BinOp(op, self, _as_expr(other))

    def __add__(self, other):
        return self._bin("+", other)

    def __sub__(self, other):
        return self._bin("-", other)

    def __mul__(self, other):
        return self._bin("*", other)

    def __truediv__(self, other):
        return self._bin("/", other)

    def __mod__(self, other):
        return self._bin("%", other)

    def __eq__(self, other):  # noqa: builds an expression, not a bool
        return self._bin("=", other)

    def __ne__(self, other):
        return self._bin("!=", other)

    def __lt__(self, other):
        return self._bin("<", other)

    def __le__(self, other):
        return self._bin("<=", other)

    def __gt__(self, other):
        return self._bin(">", other)

    def __ge__(self, other):
        return self._bin(">=", other)

    def __and__(self, other):
        return self._bin("and", other)

    def __or__(self, other):
        return self._bin("or", other)

    def __invert__(self):
        return Not(self)

    __hash__ = object.__hash__  # __eq__ builds exprs; identity hash is fine

    def alias(self, name: str) -> "Alias":
        return Alias(self, name)

    def substr(self, start: int, length: int) -> "Substr":
        """1-based substring, SQL-style."""
        return Substr(self, start, length)

    def cast(self, dtype: str) -> "Cast":
        return Cast(self, dtype)

    def __repr__(self):
        return f"<expr {self.sql()}>"


def _as_expr(v) -> Expr:
    return v if isinstance(v, Expr) else Lit(v)


class Col(Expr):
    def __init__(self, name: str):
        self.name = name

    def refs(self):
        return {self.name}

    def dtype(self, schema):
        return schema.dtype_of(self.name)

    def bind(self, schema):
        return operator.itemgetter(schema.index(self.name))

    def substitute(self, mapping):
        return mapping.get(self.name, self)

    def sql(self):
        return self.name


_LIT_DTYPE = {bool: "bool", int: "int", float: "float", str: "str"}


class Lit(Expr):
    def __init__(self, value):
        if type(value) not in _LIT_DTYPE:
            raise TypeError(f"unsupported literal {value!r} "
                            f"(int/float/str/bool)")
        self.value = value

    def dtype(self, schema):
        return _LIT_DTYPE[type(self.value)]

    def bind(self, schema):
        v = self.value
        return lambda row: v

    def substitute(self, mapping):
        return self

    def sql(self):
        return repr(self.value)


def _div(a, b):
    return a / b


_OPS: dict = {
    "+": operator.add, "-": operator.sub, "*": operator.mul,
    "/": _div, "%": operator.mod,
    "=": operator.eq, "!=": operator.ne, "<": operator.lt,
    "<=": operator.le, ">": operator.gt, ">=": operator.ge,
    "and": operator.and_, "or": operator.or_,
}
_ARITH = ("+", "-", "*", "%")
_NUMERIC = ("int", "float")


class BinOp(Expr):
    def __init__(self, op: str, left: Expr, right: Expr):
        if op not in _OPS:
            raise ValueError(f"unknown operator {op!r}")
        self.op = op
        self.left = left
        self.right = right

    def children(self):
        return [self.left, self.right]

    def dtype(self, schema):
        lt, rt = self.left.dtype(schema), self.right.dtype(schema)
        if self.op in _ARITH:
            if lt not in _NUMERIC or rt not in _NUMERIC:
                if self.op == "+" and lt == rt == "str":
                    return "str"  # concatenation
                raise TypeError(f"{self.sql()}: arithmetic needs numeric "
                                f"operands, got {lt}/{rt}")
            return "float" if "float" in (lt, rt) else "int"
        if self.op == "/":
            if lt not in _NUMERIC or rt not in _NUMERIC:
                raise TypeError(f"{self.sql()}: division needs numeric "
                                f"operands, got {lt}/{rt}")
            return "float"
        if self.op in ("and", "or"):
            if not (lt == rt == "bool"):
                raise TypeError(f"{self.sql()}: boolean operands "
                                f"required, got {lt}/{rt}")
            return "bool"
        # comparisons: mismatched operand dtypes fail at PLAN time like
        # every other type error, not mid-execution on a billed task
        if lt != rt and not (lt in _NUMERIC and rt in _NUMERIC):
            raise TypeError(f"{self.sql()}: cannot compare {lt} with "
                            f"{rt}")
        return "bool"

    def bind(self, schema):
        lf, rf = self.left.bind(schema), self.right.bind(schema)
        if self.op == "and":
            # SHORT-CIRCUIT, not operator.and_: the optimizer merges
            # sequential filters into one conjunction, and the later
            # guard must never evaluate on rows the earlier one excludes
            # (e.g. `n != 0` guarding `100 / n`). Three-valued: a False
            # side wins without looking at the other; NULL otherwise
            # taints the result unless the other side is False.
            def and_(row):
                a = lf(row)
                if a is not None and not a:
                    return False
                b = rf(row)
                if b is not None and not b:
                    return False
                return None if a is None or b is None else True
            return and_
        if self.op == "or":
            def or_(row):
                a = lf(row)
                if a is not None and a:
                    return True
                b = rf(row)
                if b is not None and b:
                    return True
                return None if a is None or b is None else False
            return or_
        fn = _OPS[self.op]

        def apply(row):
            a = lf(row)
            if a is None:
                return None
            b = rf(row)
            return None if b is None else fn(a, b)
        return apply

    def substitute(self, mapping):
        return BinOp(self.op, self.left.substitute(mapping),
                     self.right.substitute(mapping))

    def sql(self):
        return f"({self.left.sql()} {self.op} {self.right.sql()})"


class Not(Expr):
    def __init__(self, child: Expr):
        self.child = child

    def children(self):
        return [self.child]

    def dtype(self, schema):
        if self.child.dtype(schema) != "bool":
            raise TypeError(f"{self.sql()}: boolean operand required")
        return "bool"

    def bind(self, schema):
        f = self.child.bind(schema)

        def not_(row):
            v = f(row)
            return None if v is None else not v
        return not_

    def substitute(self, mapping):
        return Not(self.child.substitute(mapping))

    def sql(self):
        return f"(not {self.child.sql()})"


class Substr(Expr):
    """1-based fixed-length substring (SQL SUBSTR)."""

    def __init__(self, child: Expr, start: int, length: int):
        if start < 1 or length < 0:
            # a 0-based habit would silently slice s[-1:...] to ""
            raise ValueError(f"substr is 1-based: start >= 1 and "
                             f"length >= 0 (got {start}, {length})")
        self.child = child
        self.start = start
        self.length = length

    def children(self):
        return [self.child]

    def dtype(self, schema):
        if self.child.dtype(schema) != "str":
            raise TypeError(f"{self.sql()}: substr needs a str operand")
        return "str"

    def bind(self, schema):
        f = self.child.bind(schema)
        lo = self.start - 1
        hi = lo + self.length

        def substr(row):
            s = f(row)
            return None if s is None else s[lo:hi]
        return substr

    def substitute(self, mapping):
        return Substr(self.child.substitute(mapping), self.start,
                      self.length)

    def sql(self):
        return f"substr({self.child.sql()}, {self.start}, {self.length})"


class Cast(Expr):
    def __init__(self, child: Expr, to: str):
        if to not in SCALAR_DTYPES:
            raise ValueError(f"cannot cast to {to!r}")
        self.child = child
        self.to = to

    def children(self):
        return [self.child]

    def dtype(self, schema):
        self.child.dtype(schema)  # validate the subtree
        return self.to

    def bind(self, schema):
        f = self.child.bind(schema)
        caster = _RUNTIME_CASTS[self.to]

        def cast(row):
            v = f(row)
            return None if v is None else caster(v)
        return cast

    def substitute(self, mapping):
        return Cast(self.child.substitute(mapping), self.to)

    def sql(self):
        return f"cast({self.child.sql()} as {self.to})"


class Udf(Expr):
    """A user function lifted to an expression. ``deterministic=False``
    marks it as a pushdown barrier (see optimizer)."""

    def __init__(self, fn: Callable, dtype: str, args: list,
                 name: str | None = None, deterministic: bool = True):
        self.fn = fn
        self._dtype = dtype
        self.args = [_as_expr(a) for a in args]
        self.name = name or getattr(fn, "__name__", "udf")
        self._deterministic = deterministic

    def children(self):
        return list(self.args)

    @property
    def deterministic(self):
        return self._deterministic and super().deterministic

    def dtype(self, schema):
        for a in self.args:
            a.dtype(schema)
        return self._dtype

    def bind(self, schema):
        fn = self.fn
        bound = [a.bind(schema) for a in self.args]
        return lambda row: fn(*[b(row) for b in bound])

    def substitute(self, mapping):
        return Udf(self.fn, self._dtype,
                   [a.substitute(mapping) for a in self.args],
                   name=self.name, deterministic=self._deterministic)

    def sql(self):
        tag = "" if self._deterministic else "!"
        return f"{self.name}{tag}({', '.join(a.sql() for a in self.args)})"


class Alias(Expr):
    """Names an expression for select/agg output; transparent otherwise."""

    def __init__(self, child: Expr, name: str):
        self.child = child
        self.name = name

    def children(self):
        return [self.child]

    def dtype(self, schema):
        return self.child.dtype(schema)

    def bind(self, schema):
        return self.child.bind(schema)

    def substitute(self, mapping):
        return Alias(self.child.substitute(mapping), self.name)

    def sql(self):
        return self.child.sql()


# -------------------------------------------------------------- aggregates

AGG_OPS = ("sum", "count", "min", "max", "avg", "collect_list")


class AggExpr:
    """An aggregate over a group. All ops except collect_list are
    ALGEBRAIC — they decompose into per-partition partials merged by an
    associative combiner, which is what lets the optimizer select the
    map-side-combine (reduceByKey) lowering."""

    def __init__(self, op: str, child: Expr | None = None,
                 name: str | None = None):
        if op not in AGG_OPS:
            raise ValueError(f"unknown aggregate {op!r}")
        if child is None and op != "count":
            raise ValueError(f"{op} needs an argument expression")
        self.op = op
        self.child = child
        self.name = name or self.sql()

    @property
    def algebraic(self) -> bool:
        return self.op != "collect_list"

    def refs(self) -> set:
        return self.child.refs() if self.child is not None else set()

    def dtype(self, schema: Schema) -> str:
        ct = self.child.dtype(schema) if self.child is not None else None
        if self.op == "count":
            return "int"
        if self.op == "avg":
            if ct not in _NUMERIC:
                raise TypeError(f"{self.sql()}: avg needs a numeric arg")
            return "float"
        if self.op == "sum" and ct not in _NUMERIC:
            raise TypeError(f"{self.sql()}: sum needs a numeric arg")
        if self.op == "collect_list":
            return f"list:{ct}"
        return ct  # sum/min/max keep the argument dtype

    def alias(self, name: str) -> "AggExpr":
        return AggExpr(self.op, self.child, name=name)

    def substitute(self, mapping) -> "AggExpr":
        child = (self.child.substitute(mapping)
                 if self.child is not None else None)
        return AggExpr(self.op, child, name=self.name)

    def sql(self) -> str:
        arg = self.child.sql() if self.child is not None else "*"
        return f"{self.op}({arg})"

    def __repr__(self):
        return f"<agg {self.name}:={self.sql()}>"


# ------------------------------------------------------------- public API


def col(name: str) -> Col:
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


def udf(fn: Callable, dtype: str, *, name: str | None = None,
        deterministic: bool = True) -> Callable:
    """Lift ``fn`` into the expression language:
    ``hour = udf(int, "int"); hour(col("h"))``."""
    def build(*args) -> Udf:
        return Udf(fn, dtype, list(args), name=name,
                   deterministic=deterministic)
    return build


def sum_(e) -> AggExpr:
    return AggExpr("sum", _as_expr(e))


def count_(e=None) -> AggExpr:
    return AggExpr("count", _as_expr(e) if e is not None else None)


def min_(e) -> AggExpr:
    return AggExpr("min", _as_expr(e))


def max_(e) -> AggExpr:
    return AggExpr("max", _as_expr(e))


def avg_(e) -> AggExpr:
    return AggExpr("avg", _as_expr(e))


def collect_list(e) -> AggExpr:
    return AggExpr("collect_list", _as_expr(e))


def split_conjuncts(pred: Expr) -> list:
    """Flatten an AND tree into its conjuncts (predicate pushdown splits
    a filter and pushes each conjunct as far down as it can go)."""
    if isinstance(pred, BinOp) and pred.op == "and":
        return split_conjuncts(pred.left) + split_conjuncts(pred.right)
    if isinstance(pred, Alias):
        return split_conjuncts(pred.child)
    return [pred]


def join_conjuncts(preds: list) -> Expr:
    out = preds[0]
    for p in preds[1:]:
        out = BinOp("and", out, p)
    return out
