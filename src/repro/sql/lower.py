"""Lower a logical plan onto the RDD lineage.

Everything below the root Sort/Limit chain becomes plain RDD operators —
so the DAG planner, CSE, cache(), the EOS shuffle protocol and both
transports apply to DataFrame queries unchanged. Because the plan carries
schemas, every emitted wide op declares its (key, value) columnar batch
schema (rdd.batch_schema) — executors pack typed columns without per-batch
type sniffing.

Node -> lineage:

    Scan       textFile(key).map(parse-and-cast of the PRUNED columns)
    RddScan    the RDD itself (rows are tuples matching the schema)
    Project    map(compiled row function)
    Filter     filter(compiled predicate)
    Aggregate  partial (map-side combine): map(row -> (keys, partials))
                 .reduceByKey(slot-wise merge) .map(finalize)
               full: map(row -> (keys, row)).groupByKey().map(aggregate)
    Join       map both sides to (key-tuple, rest-tuple), rdd.join,
               map to key + left-rest + right-rest
    Sort/Limit root-only FINAL operators: Limit directly above the engine
               plan becomes a per-partition "limit" op plus the action-
               merge short-circuit (RDD.take's machinery); Limit(Sort(X))
               adds a per-partition top-n; the driver applies the total
               order / final truncation to the collected rows.
"""

from __future__ import annotations

import operator

from repro.core import rdd as R
from repro.sql import plan as P
from repro.sql.expr import CASTS, Schema, dtype_serde_char

_SLOT_MERGE = {"sum": operator.add, "min": min, "max": max}


def _one(row):
    return 1


def _identity_partition(it):
    return it


def sort_rows(rows: list, bound_keys: list) -> None:
    """In-place multi-key sort: stable passes applied innermost-last."""
    for fn, asc in reversed(bound_keys):
        rows.sort(key=fn, reverse=not asc)


def _topn_fn(n: int, bound_keys: list):
    def topn(it):
        rows = list(it)
        sort_rows(rows, bound_keys)
        return iter(rows[:n])
    return topn


def _tuple_schema(schema: Schema, names) -> str | None:
    return schema.serde_tuple(names)


# ----------------------------------------------------------- entry point


def lower(plan: P.Plan, ctx):
    """Returns (rdd, merge_limit, driver_ops): run the rdd through
    ``ctx.run_action(..., limit=merge_limit)``, then apply ``driver_ops``
    (("sort", bound_keys) / ("limit", n), in order) to the rows."""
    steps = []
    node = plan
    while isinstance(node, (P.Sort, P.Limit)):
        steps.append(node)
        node = node.child
    rdd = _lower_engine(node, ctx)
    inner_schema = node.schema()
    merge_limit = None
    if steps and isinstance(steps[-1], P.Limit):
        # the INNERMOST step caps the engine result: per-partition limit
        # op + action-merge short-circuit (same machinery as RDD.take)
        merge_limit = steps[-1].n
        rdd = R.Narrow(rdd, "limit", merge_limit)
    if (len(steps) == 2 and isinstance(steps[0], P.Limit)
            and isinstance(steps[1], P.Sort)):
        # Limit(Sort(X)) — top-n: each partition forwards only its n best
        bound = [(e.bind(inner_schema), asc) for e, asc in steps[1].keys]
        rdd = rdd.mapPartitions(_topn_fn(steps[0].n, bound))
    driver_ops = []
    for s in reversed(steps):  # innermost first
        if isinstance(s, P.Limit):
            driver_ops.append(("limit", s.n))
        else:
            driver_ops.append(("sort",
                               [(e.bind(inner_schema), asc)
                                for e, asc in s.keys]))
    return rdd, merge_limit, driver_ops


def apply_driver_ops(rows: list, driver_ops: list) -> list:
    for op in driver_ops:
        if op[0] == "limit":
            rows = rows[:op[1]]
        else:
            sort_rows(rows, op[1])
    return rows


# ------------------------------------------------------- engine lowering


def _lower_engine(node: P.Plan, ctx) -> R.RDD:
    if isinstance(node, P.Scan):
        return _lower_scan(node, ctx)
    if isinstance(node, P.RddScan):
        return node.rdd
    if isinstance(node, P.Project):
        base = node.child.schema()
        fns = [e.bind(base) for _, e in node.cols]
        child = _lower_engine(node.child, ctx)
        return child.map(lambda row: tuple(f(row) for f in fns))
    if isinstance(node, P.Filter):
        pred = node.pred.bind(node.child.schema())
        return _lower_engine(node.child, ctx).filter(pred)
    if isinstance(node, P.Aggregate):
        return _lower_aggregate(node, ctx)
    if isinstance(node, P.Join):
        return _lower_join(node, ctx)
    if isinstance(node, P.Cached):
        inner = _lower_engine(node.child, ctx)
        if isinstance(node.child, P.RddScan):
            # never flip the cached flag on the USER'S RDD object — wrap
            # it so the mark lives on lineage this lowering owns
            inner = inner.mapPartitions(_identity_partition)
        return inner.cache()
    if isinstance(node, (P.Sort, P.Limit)):
        raise ValueError("Sort/Limit are final operators; they can only "
                         "appear at the plan root (orderBy/limit last)")
    raise TypeError(f"unknown plan node {type(node).__name__}")


def _lower_scan(node: P.Scan, ctx) -> R.RDD:
    full = node.full_schema
    sel = node.schema().names
    idx = [full.index(n) for n in sel]
    casters = [CASTS[full.dtype_of(n)] for n in sel]

    def parse(line):
        parts = line.split(",")
        return tuple(c(parts[i]) for c, i in zip(casters, idx))

    return ctx.textFile(node.key, node.nparts).map(parse)


def _key_value_fn(key_idx: list, rest_idx: list):
    def fn(row):
        return (tuple(row[i] for i in key_idx),
                tuple(row[j] for j in rest_idx))
    return fn


def _lower_join(node: P.Join, ctx) -> R.RDD:
    ls, rs = node.left.schema(), node.right.schema()
    lrest, rrest = node.rest_names(node.left), node.rest_names(node.right)
    lmap = _key_value_fn([ls.index(n) for n in node.on],
                         [ls.index(n) for n in lrest])
    rmap = _key_value_fn([rs.index(n) for n in node.on],
                         [rs.index(n) for n in rrest])
    left = _lower_engine(node.left, ctx).map(lmap)
    right = _lower_engine(node.right, ctx).map(rmap)
    schemas = (_tuple_schema(ls, node.on),
               _tuple_schema(ls, lrest), _tuple_schema(rs, rrest))
    joined = left.join(right, node.nparts, transport=node.transport,
                       batch_schemas=schemas)
    return joined.map(lambda kv: kv[0] + kv[1][0] + kv[1][1])


def _lower_aggregate(node: P.Aggregate, ctx) -> R.RDD:
    base = node.child.schema()
    out_schema = node.schema()
    child = _lower_engine(node.child, ctx)
    kfs = [e.bind(base) for _, e in node.keys]
    kschema = _tuple_schema(out_schema, [n for n, _ in node.keys])

    def keyer(row):
        return tuple(k(row) for k in kfs)

    if node.partial:
        return _lower_partial(node, child, base, keyer, kschema)
    return _lower_full(node, child, base, keyer, kschema)


def _lower_partial(node: P.Aggregate, child: R.RDD, base: Schema,
                   keyer, kschema: str | None) -> R.RDD:
    """Map-side-combine lowering: rows fold into per-key PARTIAL tuples
    before they ever reach the wire; reduceByKey merges slot-wise with
    associative ops (sum/min/max — avg rides as (sum, count))."""
    slot_ops: list = []
    inits: list = []
    layout: list = []  # (op, first slot, slot count) per aggregate
    vchars: list = []
    for name, a in node.aggs:
        off = len(slot_ops)
        arg = a.child.bind(base) if a.child is not None else None
        argc = (dtype_serde_char(a.child.dtype(base))
                if a.child is not None else "i")
        if a.op == "count":
            slot_ops.append("sum")
            inits.append(_one)
            vchars.append("i")
        elif a.op == "avg":
            slot_ops += ["sum", "sum"]
            inits += [arg, _one]
            vchars += [argc, "i"]
        else:  # sum / min / max
            slot_ops.append(a.op)
            inits.append(arg)
            vchars.append(argc)
        layout.append((a.op, off, len(slot_ops) - off))

    def mapper(row):
        return (keyer(row), tuple(f(row) for f in inits))

    def merge(a, b):
        return tuple(_SLOT_MERGE[op](x, y)
                     for op, x, y in zip(slot_ops, a, b))

    def finalize(kv):
        key, vals = kv
        out = []
        for op, off, _width in layout:
            if op == "avg":
                out.append(vals[off] / vals[off + 1])
            else:
                out.append(vals[off])
        return key + tuple(out)

    vschema = "t(%s)" % ",".join(vchars) if vchars else None
    agged = child.map(mapper).reduceByKey(
        merge, node.nparts or child.nparts, transport=node.transport,
        batch_schema=(kschema, vschema) if kschema else None)
    return agged.map(finalize)


def _lower_full(node: P.Aggregate, child: R.RDD, base: Schema,
                keyer, kschema: str | None) -> R.RDD:
    """groupByKey lowering (collect_list, or optimize=False): full rows
    ship to the reducers; aggregates evaluate over each group."""
    aggfns = []
    for name, a in node.aggs:
        arg = a.child.bind(base) if a.child is not None else None
        aggfns.append(_group_agg_fn(a.op, arg))

    def mapper(row):
        return (keyer(row), row)

    def finalize(kv):
        key, rows = kv
        return key + tuple(f(rows) for f in aggfns)

    vschema = _tuple_schema(base, base.names)
    grouped = child.map(mapper).groupByKey(
        node.nparts or child.nparts, transport=node.transport,
        batch_schema=(kschema, vschema) if kschema else None)
    return grouped.map(finalize)


def _group_agg_fn(op: str, arg):
    if op == "count":
        return len
    if op == "sum":
        return lambda rows: sum(arg(r) for r in rows)
    if op == "avg":
        return lambda rows: sum(arg(r) for r in rows) / len(rows)
    if op == "min":
        return lambda rows: min(arg(r) for r in rows)
    if op == "max":
        return lambda rows: max(arg(r) for r in rows)
    if op == "collect_list":
        return lambda rows: [arg(r) for r in rows]
    raise ValueError(f"unknown aggregate {op}")
