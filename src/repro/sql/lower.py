"""Lower a logical plan onto the RDD lineage.

Everything below the root Sort/Limit chain becomes plain RDD operators —
so the DAG planner, CSE, cache(), the EOS shuffle protocol and both
transports apply to DataFrame queries unchanged. Because the plan carries
schemas, every emitted wide op declares its (key, value) columnar batch
schema (rdd.batch_schema) — executors pack typed columns without per-batch
type sniffing.

Node -> lineage (row path, FlintConfig.vectorize=False):

    Scan       textFile(key).map(parse-and-cast of the PRUNED columns)
    RddScan    the RDD itself (rows are tuples matching the schema)
    Project    map(compiled row function)
    Filter     filter(compiled predicate)
    Aggregate  partial (map-side combine): map(row -> (keys, partials))
                 .reduceByKey(slot-wise merge) .map(finalize)
               full: map(row -> (keys, row)).groupByKey().map(aggregate)
    Join       map both sides to (key-tuple, rest-tuple), rdd.join,
               map to key + left-rest + right-rest
    Sort       root, >1 partition, FlintConfig.adaptive: DISTRIBUTED
               range-partitioned sort — a sampling job picks quantile
               splitters, repartition(partition_fn=...) range-routes each
               row, partitions sort locally, and the index-ordered merge
               is the total order (docs/adaptive_execution.md). The same
               lowering serves Sort below the root (orderBy mid-query);
               without adaptive a root Sort falls back to the driver-side
               sort of the collected rows.
    Limit      root-only FINAL operator: a per-partition "limit" op plus
               the action-merge short-circuit (RDD.take's machinery);
               Limit(Sort(X)) becomes a per-partition top-n with the
               driver applying the total order / final truncation.

With ``FlintConfig.vectorize`` (the default) every maximal
scan/Project/Filter chain — plus the map side of a partial aggregate,
groupByKey, or join directly above one — fuses into a SINGLE
``mapBatches`` operator compiled by repro.sql.vectorized: one batch-in /
batch-out closure running ingest -> masks/slices -> grouped fold over
whole column arrays, with a per-chunk fallback to the bound row closures
(docs/vectorized_execution.md). Expressions with no vectorized form
(udfs) stop the fusion at the longest compilable prefix; the remaining
steps lower as row operators exactly as above.
"""

from __future__ import annotations

import bisect
import operator

from repro.core import rdd as R
from repro.sql import plan as P
from repro.sql import vectorized as V
from repro.sql.expr import CASTS, Schema, dtype_serde_char

_SLOT_MERGE = {"sum": operator.add, "min": min, "max": max}


def _one(row):
    return 1


def _identity_partition(it):
    return it


def sort_rows(rows: list, bound_keys: list) -> None:
    """In-place multi-key sort: stable passes applied innermost-last."""
    for fn, asc in reversed(bound_keys):
        rows.sort(key=fn, reverse=not asc)


def _topn_fn(n: int, bound_keys: list):
    def topn(it):
        rows = list(it)
        sort_rows(rows, bound_keys)
        return iter(rows[:n])
    return topn


# ------------------------------------------- distributed (range) sort


class _Rev:
    """Order-reversing wrapper: lets a DESCENDING sort key ride inside
    an ascending composite tuple (bisect and tuple comparison only need
    ``<``/``==``). None sorts like any other value its ``<`` admits."""

    __slots__ = ("v",)

    def __init__(self, v):
        self.v = v

    def __lt__(self, other):
        return other.v < self.v

    def __eq__(self, other):
        return other.v == self.v


def _composite_key_fn(bound_keys: list):
    def key(row):
        return tuple(f(row) if asc else _Rev(f(row))
                     for f, asc in bound_keys)
    return key


_SAMPLES_PER_PARTITION = 64


def _sampler_fn(bound_keys: list):
    """Per-partition sampler for the range partitioner: sort the
    partition by the composite key and emit ~64 evenly spaced key
    tuples. Deterministic (no RNG) so retried/speculated attempts of
    the sampling job return identical rows."""
    key = _composite_key_fn(bound_keys)

    def sample(it):
        keys = sorted(key(row) for row in it)
        if not keys:
            return iter(())
        step = max(1, len(keys) // _SAMPLES_PER_PARTITION)
        return iter(keys[::step])
    return sample


def _range_partition_fn(splitters: list, bound_keys: list):
    key = _composite_key_fn(bound_keys)

    def pf(row):
        return bisect.bisect_right(splitters, key(row))
    return pf


def _sorted_parts_fn(bound_keys: list):
    def sort_part(it):
        rows = list(it)
        sort_rows(rows, bound_keys)
        return iter(rows)
    return sort_part


def _range_sorted(rdd: R.RDD, bound_keys: list, ctx) -> R.RDD:
    """Distributed range-partitioned sort: a sampling job estimates the
    key distribution, the driver picks quantile splitters, and a
    repartition with a range partition_fn sends each row to the
    partition owning its key range. Partition i then holds only keys <=
    partition i+1's (equal keys never straddle a boundary —
    bisect_right sends them all right), so after a per-partition sort
    the index-ordered concatenation of results IS the total order and
    no driver-side sort remains. Skewed or duplicate-heavy keys just
    yield duplicate splitters (several ranges collapse onto one
    partition); empty partitions contribute no samples and no rows."""
    nparts = rdd.nparts
    samples = ctx.run_action(rdd.mapPartitions(_sampler_fn(bound_keys)),
                             "collect")
    samples.sort()
    splitters = []
    if samples:
        stride = len(samples) / nparts
        splitters = [samples[min(len(samples) - 1,
                                 int(stride * (i + 1)))]
                     for i in range(nparts - 1)]
    pf = _range_partition_fn(splitters, bound_keys)
    return (rdd.repartition(nparts, partition_fn=pf)
            .mapPartitions(_sorted_parts_fn(bound_keys)))


def _tuple_schema(schema: Schema, names) -> str | None:
    return schema.serde_tuple(names)


# ----------------------------------------------------------- entry point


def lower(plan: P.Plan, ctx):
    """Returns (rdd, merge_limit, driver_ops): run the rdd through
    ``ctx.run_action(..., limit=merge_limit)``, then apply ``driver_ops``
    (("sort", bound_keys) / ("limit", n), in order) to the rows."""
    steps = []
    node = plan
    while isinstance(node, (P.Sort, P.Limit)):
        steps.append(node)
        node = node.child
    rdd = _lower_engine(node, ctx)
    inner_schema = node.schema()
    if (len(steps) == 1 and isinstance(steps[0], P.Sort)
            and rdd.nparts > 1
            and getattr(getattr(ctx, "config", None), "adaptive", False)):
        # root orderBy over >1 partition: distributed range-partitioned
        # sort — the index-ordered merge of partition results IS the
        # total order, so the driver applies no ops at all
        bound = [(e.bind(inner_schema), asc) for e, asc in steps[0].keys]
        return _range_sorted(rdd, bound, ctx), None, []
    merge_limit = None
    if steps and isinstance(steps[-1], P.Limit):
        # the INNERMOST step caps the engine result: per-partition limit
        # op + action-merge short-circuit (same machinery as RDD.take)
        merge_limit = steps[-1].n
        rdd = R.Narrow(rdd, "limit", merge_limit)
    if (len(steps) == 2 and isinstance(steps[0], P.Limit)
            and isinstance(steps[1], P.Sort)):
        # Limit(Sort(X)) — top-n: each partition forwards only its n best
        bound = [(e.bind(inner_schema), asc) for e, asc in steps[1].keys]
        rdd = rdd.mapPartitions(_topn_fn(steps[0].n, bound))
    driver_ops = []
    for s in reversed(steps):  # innermost first
        if isinstance(s, P.Limit):
            driver_ops.append(("limit", s.n))
        else:
            driver_ops.append(("sort",
                               [(e.bind(inner_schema), asc)
                                for e, asc in s.keys]))
    return rdd, merge_limit, driver_ops


def apply_driver_ops(rows: list, driver_ops: list) -> list:
    for op in driver_ops:
        if op[0] == "limit":
            rows = rows[:op[1]]
        else:
            sort_rows(rows, op[1])
    return rows


# ------------------------------------------------------- engine lowering


def _lower_engine(node: P.Plan, ctx) -> R.RDD:
    if isinstance(node, (P.Scan, P.Project, P.Filter)):
        fused = _lower_chain(node, ctx)
        if fused is not None:
            return fused
    if isinstance(node, P.Scan):
        return _lower_scan(node, ctx)
    if isinstance(node, P.RddScan):
        return node.rdd
    if isinstance(node, P.Project):
        base = node.child.schema()
        fns = [e.bind(base) for _, e in node.cols]
        child = _lower_engine(node.child, ctx)
        return child.map(_tuple_map(fns))
    if isinstance(node, P.Filter):
        pred = node.pred.bind(node.child.schema())
        return _lower_engine(node.child, ctx).filter(pred)
    if isinstance(node, P.Aggregate):
        return _lower_aggregate(node, ctx)
    if isinstance(node, P.Join):
        return _lower_join(node, ctx)
    if isinstance(node, P.Cached):
        inner = _lower_engine(node.child, ctx)
        if isinstance(node.child, P.RddScan):
            # never flip the cached flag on the USER'S RDD object — wrap
            # it so the mark lives on lineage this lowering owns
            inner = inner.mapPartitions(_identity_partition)
        return inner.cache()
    if isinstance(node, P.Sort):
        # orderBy is no longer driver-final: below the root it lowers as
        # a range-partitioned distributed sort (adaptive) or a plain
        # per-partition sort when there is nothing to distribute
        child = _lower_engine(node.child, ctx)
        bound = [(e.bind(node.child.schema()), asc)
                 for e, asc in node.keys]
        if child.nparts <= 1:
            return child.mapPartitions(_sorted_parts_fn(bound))
        if getattr(getattr(ctx, "config", None), "adaptive", False):
            return _range_sorted(child, bound, ctx)
        raise ValueError(
            "Sort below the plan root requires FlintConfig.adaptive "
            "(distributed range-partitioned sort) or a single-partition "
            "input; move orderBy last or enable adaptive execution")
    if isinstance(node, P.Limit):
        raise ValueError("Limit is a final operator; it can only "
                         "appear at the plan root (limit last)")
    raise TypeError(f"unknown plan node {type(node).__name__}")


def _lower_scan(node: P.Scan, ctx) -> R.RDD:
    full = node.full_schema
    sel = node.schema().names
    idx = [full.index(n) for n in sel]
    casters = [CASTS[full.dtype_of(n)] for n in sel]
    return ctx.textFile(node.key, node.nparts).map(_parse_fn(idx, casters))


def _parse_fn(idx: list, casters: list):
    def parse(line):
        parts = line.split(",")
        return tuple(c(parts[i]) for c, i in zip(casters, idx))
    return parse


# ------------------------------------------------- vectorized chain fusion


def _tuple_map(fns):
    def project(row):
        return tuple(f(row) for f in fns)
    return project


def _vec_one(cols, n):
    return 1


def _row_chain(fn_specs):
    """The exact row-semantics pipeline for a fused segment — chunks the
    vectorized path rejects re-run through this (see make_fused)."""
    def chain(it):
        for kind, fn in fn_specs:
            it = map(fn, it) if kind == "map" else filter(fn, it)
        return it
    return chain


def _split_chain(node: P.Plan):
    """Peel the Project/Filter chain off ``node`` (inclusive). Returns
    (base, steps) with steps ordered base-first."""
    steps = []
    while isinstance(node, (P.Project, P.Filter)):
        steps.append(node)
        node = node.child
    steps.reverse()
    return node, steps


def _vector_segment(base: P.Plan, steps: list, ctx):
    """Compile the vectorizable PREFIX of a chain over ``base``. Returns
    (base_rdd, ingest, stages, row_fns, schema_after_prefix, n_compiled)
    or None when vectorization is off — or when a non-Scan base compiles
    zero steps (nothing to gain over the plain row operators)."""
    cfg = getattr(ctx, "config", None)
    if cfg is None or not getattr(cfg, "vectorize", False):
        return None
    if isinstance(base, P.Scan):
        full = base.full_schema
        sel = base.schema().names
        idx = [full.index(n) for n in sel]
        casters = [CASTS[full.dtype_of(n)] for n in sel]
        ingest = V.scan_ingest(
            [(i, full.dtype_of(n), c)
             for i, n, c in zip(idx, sel, casters)])
        row_fns = [("map", _parse_fn(idx, casters))]
        base_rdd = ctx.textFile(base.key, base.nparts)
    else:
        ingest = V.rows_ingest([t for _, t in base.schema().fields])
        row_fns = []
        base_rdd = _lower_engine(base, ctx)
    schema = base.schema()
    stages: list = []
    compiled = 0
    for st in steps:
        try:
            if isinstance(st, P.Filter):
                stages.append(V.filter_stage(st.pred.bind_vec(schema)))
                row_fns.append(("filter", st.pred.bind(schema)))
            else:
                stages.append(V.project_stage(
                    [e.bind_vec(schema) for _, e in st.cols]))
                row_fns.append(("map", _tuple_map(
                    [e.bind(schema) for _, e in st.cols])))
        except V.VectorizeUnsupported:
            break
        schema = st.schema()
        compiled += 1
    if not isinstance(base, P.Scan) and compiled == 0:
        return None
    return base_rdd, ingest, stages, row_fns, schema, compiled


def _lower_chain(node: P.Plan, ctx) -> R.RDD | None:
    """Fuse ``node``'s Project/Filter chain into one rows-emitting
    mapBatches operator; steps past the compilable prefix stay row ops."""
    base, steps = _split_chain(node)
    seg = _vector_segment(base, steps, ctx)
    if seg is None:
        return None
    base_rdd, ingest, stages, row_fns, _schema, compiled = seg
    fused = V.make_fused(ingest, stages, V.rows_emit, _row_chain(row_fns),
                         ctx.config.vector_batch_rows)
    rdd = base_rdd.mapBatches(fused)
    for st in steps[compiled:]:
        sch = st.child.schema()
        if isinstance(st, P.Filter):
            rdd = rdd.filter(st.pred.bind(sch))
        else:
            rdd = rdd.map(_tuple_map([e.bind(sch) for _, e in st.cols]))
    return rdd


def _fused_kv(child_plan: P.Plan, ctx, row_mapper, emit_builder):
    """Fuse a FULLY-vectorizable chain plus a key/value emission into one
    operator (the map side of an aggregate/group/join). ``emit_builder``
    compiles the emission over the chain's output schema and may raise
    VectorizeUnsupported; any miss returns None and the caller falls back
    to ``_lower_engine(child).map(row_mapper)`` — which still fuses the
    chain itself, just with row-tuple emission."""
    base, steps = _split_chain(child_plan)
    seg = _vector_segment(base, steps, ctx)
    if seg is None:
        return None
    base_rdd, ingest, stages, row_fns, schema, compiled = seg
    if compiled < len(steps):
        return None
    try:
        emit = emit_builder(schema)
    except V.VectorizeUnsupported:
        return None
    row_fns.append(("map", row_mapper))
    fused = V.make_fused(ingest, stages, emit, _row_chain(row_fns),
                         ctx.config.vector_batch_rows)
    return base_rdd.mapBatches(fused)


def _key_value_fn(key_idx: list, rest_idx: list):
    def fn(row):
        return (tuple(row[i] for i in key_idx),
                tuple(row[j] for j in rest_idx))
    return fn


def _lower_join(node: P.Join, ctx) -> R.RDD:
    ls, rs = node.left.schema(), node.right.schema()
    lrest, rrest = node.rest_names(node.left), node.rest_names(node.right)
    kschema = _tuple_schema(ls, node.on)
    left = _lower_join_side(node.left, ctx, ls, node.on, lrest,
                            kschema, _tuple_schema(ls, lrest))
    right = _lower_join_side(node.right, ctx, rs, node.on, rrest,
                             kschema, _tuple_schema(rs, rrest))
    schemas = (kschema, _tuple_schema(ls, lrest), _tuple_schema(rs, rrest))
    joined = left.join(right, node.nparts, transport=node.transport,
                       batch_schemas=schemas, how=node.how)
    return joined.map(_join_row_fn(len(lrest), len(rrest)))


def _join_row_fn(lwidth: int, rwidth: int):
    """(key, (lrest|None, rrest|None)) -> output row; an absent side
    (the unmatched half of an outer join) pads with None columns."""
    lpad, rpad = (None,) * lwidth, (None,) * rwidth

    def to_row(kv):
        lv, rv = kv[1]
        return (kv[0] + (lpad if lv is None else lv)
                + (rpad if rv is None else rv))
    return to_row


def _lower_join_side(side: P.Plan, ctx, schema: Schema, on, rest,
                     kschema: str | None, vschema: str | None) -> R.RDD:
    key_idx = [schema.index(n) for n in on]
    rest_idx = [schema.index(n) for n in rest]
    mapper = _key_value_fn(key_idx, rest_idx)
    if kschema and vschema and key_idx and rest_idx:
        def vec_emit(sch):
            return V.make_kv_plain_emit(
                [V.col_selector(i) for i in key_idx], rest_idx,
                kschema, vschema)
        fused = _fused_kv(side, ctx, mapper, vec_emit)
        if fused is not None:
            return fused
    return _lower_engine(side, ctx).map(mapper)


def _lower_aggregate(node: P.Aggregate, ctx) -> R.RDD:
    base = node.child.schema()
    out_schema = node.schema()
    kfs = [e.bind(base) for _, e in node.keys]
    kschema = _tuple_schema(out_schema, [n for n, _ in node.keys])

    def keyer(row):
        return tuple(k(row) for k in kfs)

    if node.partial:
        return _lower_partial(node, ctx, base, keyer, kschema)
    return _lower_full(node, ctx, base, keyer, kschema)


def _lower_partial(node: P.Aggregate, ctx, base: Schema,
                   keyer, kschema: str | None) -> R.RDD:
    """Map-side-combine lowering: rows fold into per-key PARTIAL tuples
    before they ever reach the wire; reduceByKey merges slot-wise with
    associative ops (sum/min/max — avg rides as (sum, count)). Under
    vectorize=True the whole map side (chain + keyer + per-key slot fold)
    fuses into one batch operator emitting pre-combined partials."""
    slot_ops: list = []
    inits: list = []
    layout: list = []  # (op, first slot, slot count) per aggregate
    vchars: list = []
    for name, a in node.aggs:
        off = len(slot_ops)
        arg = a.child.bind(base) if a.child is not None else None
        argc = (dtype_serde_char(a.child.dtype(base))
                if a.child is not None else "i")
        if a.op == "count":
            slot_ops.append("sum")
            inits.append(_one)
            vchars.append("i")
        elif a.op == "avg":
            slot_ops += ["sum", "sum"]
            inits += [arg, _one]
            vchars += [argc, "i"]
        else:  # sum / min / max
            slot_ops.append(a.op)
            inits.append(arg)
            vchars.append(argc)
        layout.append((a.op, off, len(slot_ops) - off))

    def mapper(row):
        return (keyer(row), tuple(f(row) for f in inits))

    def merge(a, b):
        return tuple(_SLOT_MERGE[op](x, y)
                     for op, x, y in zip(slot_ops, a, b))

    def finalize(kv):
        key, vals = kv
        out = []
        for op, off, _width in layout:
            if op == "avg":
                out.append(vals[off] / vals[off + 1])
            else:
                out.append(vals[off])
        return key + tuple(out)

    def vec_emit(schema):
        key_fns = [e.bind_vec(schema) for _, e in node.keys]
        slot_fns: list = []
        for _name, a in node.aggs:
            argf = (a.child.bind_vec(schema)
                    if a.child is not None else None)
            if a.op == "count":
                slot_fns.append(_vec_one)
            elif a.op == "avg":
                slot_fns += [argf, _vec_one]
            else:
                slot_fns.append(argf)
        return V.make_kv_agg_emit(key_fns, slot_fns, slot_ops,
                                  ctx.config.vector_backend)

    mapped = _fused_kv(node.child, ctx, mapper, vec_emit)
    if mapped is None:
        mapped = _lower_engine(node.child, ctx).map(mapper)
    vschema = "t(%s)" % ",".join(vchars) if vchars else None
    agged = mapped.reduceByKey(
        merge, node.nparts or mapped.nparts, transport=node.transport,
        batch_schema=(kschema, vschema) if kschema else None)
    return agged.map(finalize)


def _lower_full(node: P.Aggregate, ctx, base: Schema,
                keyer, kschema: str | None) -> R.RDD:
    """groupByKey lowering (collect_list, or optimize=False): full rows
    ship to the reducers; aggregates evaluate over each group. The map
    side (chain + key computation + columnar (key, row) emission) still
    fuses under vectorize=True; the per-group fold stays row-level."""
    aggfns = []
    for name, a in node.aggs:
        arg = a.child.bind(base) if a.child is not None else None
        aggfns.append(_group_agg_fn(a.op, arg))

    def mapper(row):
        return (keyer(row), row)

    def finalize(kv):
        key, rows = kv
        return key + tuple(f(rows) for f in aggfns)

    vschema = _tuple_schema(base, base.names)

    def vec_emit(schema):
        key_fns = [e.bind_vec(schema) for _, e in node.keys]
        return V.make_kv_plain_emit(key_fns,
                                    list(range(len(schema.names))),
                                    kschema, vschema)

    mapped = None
    if kschema and vschema:
        mapped = _fused_kv(node.child, ctx, mapper, vec_emit)
    if mapped is None:
        mapped = _lower_engine(node.child, ctx).map(mapper)
    grouped = mapped.groupByKey(
        node.nparts or mapped.nparts, transport=node.transport,
        batch_schema=(kschema, vschema) if kschema else None)
    return grouped.map(finalize)


# ---------------------------------------------------------- explain marks


def vector_markers(plan: P.Plan, config) -> dict:
    """id(node) -> ``" [vectorized]"`` / ``" [row-fallback: <reason>]"``
    suffixes for explain(): a dry-run of the same bind_vec compilation the
    lowering performs, so the rendered plan shows which operators will run
    on the array path. Empty when vectorization is off."""
    if config is None or not getattr(config, "vectorize", False):
        return {}
    marks: dict = {}

    def mark(node, exprs, schema):
        try:
            for e in exprs:
                e.bind_vec(schema)
            marks[id(node)] = " [vectorized]"
        except V.VectorizeUnsupported as ex:
            marks[id(node)] = f" [row-fallback: {ex.reason}]"

    def walk(node):
        if isinstance(node, P.Scan):
            marks[id(node)] = " [vectorized]"
        elif isinstance(node, P.Project):
            mark(node, [e for _, e in node.cols], node.child.schema())
        elif isinstance(node, P.Filter):
            mark(node, [node.pred], node.child.schema())
        elif isinstance(node, P.Aggregate):
            base = node.child.schema()
            exprs = [e for _, e in node.keys]
            exprs += [a.child for _, a in node.aggs if a.child is not None]
            if any(a.op == "collect_list" for _, a in node.aggs):
                marks[id(node)] = " [row-fallback: collect_list]"
            else:
                mark(node, exprs, base)
        elif isinstance(node, P.Join):
            marks[id(node)] = " [vectorized]"
        for c in node.children():
            walk(c)

    walk(plan)
    return marks


def _group_agg_fn(op: str, arg):
    if op == "count":
        return len
    if op == "sum":
        return lambda rows: sum(arg(r) for r in rows)
    if op == "avg":
        return lambda rows: sum(arg(r) for r in rows) / len(rows)
    if op == "min":
        return lambda rows: min(arg(r) for r in rows)
    if op == "max":
        return lambda rows: max(arg(r) for r in rows)
    if op == "collect_list":
        return lambda rows: [arg(r) for r in rows]
    raise ValueError(f"unknown aggregate {op}")
