"""Logical plan for the DataFrame surface.

A query is a tree of relational operators over a schema-carrying source
(CSV scan or an RDD of tuples). ``explain_str`` renders the tree the way
the golden plan-shape tests pin it — one node per line, two-space
indents, child after parent:

    Limit[5]
      Sort[tips desc]
        Aggregate[keys=[hour], aggs=[tips:=sum(tip)], combine=map_side]
          Project[hour:=substr(pickup, 12, 2), tip]
            Filter[(payment_type = 'credit')]
              Scan[taxi.csv, cols=[pickup, payment_type, tip], parts=8]

The optimizer (repro.sql.optimizer) rewrites this tree; the lowering
(repro.sql.lower) turns it into the existing RDD lineage. ``orderBy`` and
``limit`` are FINAL operators: the engine is unordered, so Sort/Limit
live only at the plan root where the lowering can split them between a
per-partition op and a driver-side finish.
"""

from __future__ import annotations

from typing import Iterable

from repro.sql.expr import BinOp, Col, Expr, Lit, Schema


class Plan:
    _schema: Schema | None = None

    def children(self) -> list:
        raise NotImplementedError

    def with_children(self, kids: list) -> "Plan":
        raise NotImplementedError

    def _compute_schema(self) -> Schema:
        raise NotImplementedError

    def schema(self) -> Schema:
        if self._schema is None:
            self._schema = self._compute_schema()
        return self._schema

    def describe(self) -> str:
        raise NotImplementedError

    def __repr__(self):
        return explain_str(self)


def explain_str(plan: Plan, markers: dict | None = None) -> str:
    """Render the tree; ``markers`` (id(node) -> suffix, from
    repro.sql.lower.vector_markers) annotates operators with their
    execution mode, e.g. ``[vectorized]`` / ``[row-fallback: udf]``."""
    lines: list[str] = []
    marks = markers or {}

    def walk(node: Plan, depth: int):
        lines.append("  " * depth + node.describe() + marks.get(id(node), ""))
        for c in node.children():
            walk(c, depth + 1)

    walk(plan, 0)
    return "\n".join(lines)


def _fmt_named(pairs: Iterable) -> str:
    """name for a plain column passthrough, name:=expr otherwise."""
    out = []
    for name, e in pairs:
        if isinstance(e, Col) and e.name == name:
            out.append(name)
        else:  # computed column or aggregate — both print name:=sql
            out.append(f"{name}:={e.sql()}")
    return ", ".join(out)


class Scan(Plan):
    """CSV object in the store. ``columns`` is the pruned projection the
    optimizer pushes into the scan — only these fields are parsed/cast."""

    def __init__(self, key: str, full_schema: Schema, nparts: int,
                 columns: tuple | None = None):
        self.key = key
        self.full_schema = full_schema
        self.nparts = nparts
        self.columns = tuple(columns) if columns is not None else None

    def children(self):
        return []

    def with_children(self, kids):
        return self

    def _compute_schema(self):
        if self.columns is None:
            return self.full_schema
        return self.full_schema.select(self.columns)

    def describe(self):
        return (f"Scan[{self.key}, "
                f"cols=[{', '.join(self.schema().names)}], "
                f"parts={self.nparts}]")


class RddScan(Plan):
    """An RDD of tuples lifted by ``rdd.toDF(schema)``."""

    def __init__(self, rdd, schema: Schema):
        self.rdd = rdd
        self.rdd_schema = schema

    def children(self):
        return []

    def with_children(self, kids):
        return self

    def _compute_schema(self):
        return self.rdd_schema

    def describe(self):
        return (f"RddScan[cols=[{', '.join(self.rdd_schema.names)}], "
                f"parts={self.rdd.nparts}]")


class Project(Plan):
    def __init__(self, child: Plan, cols: Iterable):
        self.child = child
        self.cols = tuple(cols)  # ((name, Expr), ...)

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Project(kids[0], self.cols)

    def _compute_schema(self):
        base = self.child.schema()
        return Schema((n, e.dtype(base)) for n, e in self.cols)

    def describe(self):
        return f"Project[{_fmt_named(self.cols)}]"


class Window(Project):
    """Event-time window (pane) assignment for tumbling/sliding windows
    (docs/streaming.md). Structurally a Project — every input column
    passes through plus one computed column ``name`` holding the PANE
    start ``ts - ts % slide`` (plain expression arithmetic, so it
    vectorizes and lowers like any Project). A tumbling window
    (slide == size) is its own pane; a sliding window decomposes into
    ``size/slide`` panes that the consumer (the streaming driver, or a
    batch reference reduction) recombines per window — which is why
    ``size % slide == 0`` is required. The optimizer treats it as a
    Project for pushdown/pruning but preserves the node identity so
    explain() shows the window spec."""

    def __init__(self, child: Plan, ts_col: str, size: int,
                 slide: int | None = None, name: str = "window_start"):
        size = int(size)
        slide = size if slide is None else int(slide)
        if size <= 0 or slide <= 0:
            raise ValueError(f"window size/slide must be positive "
                             f"(got {size}/{slide})")
        if size % slide != 0:
            raise ValueError(f"window size {size} must be a multiple of "
                             f"slide {slide} (panes recombine exactly)")
        base = child.schema()
        if base.dtype_of(ts_col) != "int":
            raise TypeError(f"window over {ts_col!r} needs an int "
                            f"event-time column, got "
                            f"{base.dtype_of(ts_col)!r}")
        pane = BinOp("-", Col(ts_col), BinOp("%", Col(ts_col), Lit(slide)))
        cols = [(n, Col(n)) for n in base.names] + [(name, pane)]
        super().__init__(child, cols)
        self.ts_col = ts_col
        self.size = size
        self.slide = slide
        self.name = name

    def with_children(self, kids):
        return Window(kids[0], self.ts_col, self.size, self.slide,
                      self.name)

    def describe(self):
        return (f"Window[{self.name}:=pane({self.ts_col}), "
                f"size={self.size}, slide={self.slide}]")


class Filter(Plan):
    def __init__(self, child: Plan, pred: Expr):
        self.child = child
        self.pred = pred

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Filter(kids[0], self.pred)

    def _compute_schema(self):
        base = self.child.schema()
        if self.pred.dtype(base) != "bool":
            raise TypeError(f"filter predicate {self.pred.sql()} is not "
                            f"boolean")
        return base

    def describe(self):
        return f"Filter[{self.pred.sql()}]"


class Aggregate(Plan):
    """groupBy().agg(). ``partial`` (map-side combine, the reduceByKey
    lowering) and ``transport`` are chosen by the optimizer."""

    def __init__(self, child: Plan, keys: Iterable, aggs: Iterable,
                 nparts: int | None = None, partial: bool = False,
                 transport: str | None = None):
        self.child = child
        self.keys = tuple(keys)  # ((name, Expr), ...)
        self.aggs = tuple(aggs)  # ((name, AggExpr), ...)
        self.nparts = nparts
        self.partial = partial
        self.transport = transport

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Aggregate(kids[0], self.keys, self.aggs, self.nparts,
                         self.partial, self.transport)

    def _compute_schema(self):
        base = self.child.schema()
        fields = [(n, e.dtype(base)) for n, e in self.keys]
        fields += [(n, a.dtype(base)) for n, a in self.aggs]
        return Schema(fields)

    def describe(self):
        parts = [f"keys=[{_fmt_named(self.keys)}]",
                 f"aggs=[{_fmt_named(self.aggs)}]",
                 f"combine={'map_side' if self.partial else 'none'}"]
        if self.transport:
            parts.append(f"transport={self.transport}")
        return f"Aggregate[{', '.join(parts)}]"


class Join(Plan):
    """Equi-join on shared column names (how: inner/left/right/outer).
    Output: the key columns, then the left side's remaining columns,
    then the right side's; the unmatched half of an outer row carries
    None in the absent side's columns."""

    JOIN_HOWS = ("inner", "left", "right", "outer")

    def __init__(self, left: Plan, right: Plan, on: Iterable[str],
                 nparts: int | None = None, how: str = "inner",
                 transport: str | None = None):
        if how not in self.JOIN_HOWS:
            raise ValueError(f"unsupported join how={how!r}; expected "
                             f"one of {'/'.join(self.JOIN_HOWS)}")
        self.left = left
        self.right = right
        self.on = tuple(on)
        self.nparts = nparts
        self.how = how
        self.transport = transport

    def children(self):
        return [self.left, self.right]

    def with_children(self, kids):
        return Join(kids[0], kids[1], self.on, self.nparts, self.how,
                    self.transport)

    def rest_names(self, side: Plan) -> tuple:
        return tuple(n for n in side.schema().names if n not in self.on)

    def _compute_schema(self):
        ls, rs = self.left.schema(), self.right.schema()
        for n in self.on:
            if ls.dtype_of(n) != rs.dtype_of(n):
                raise TypeError(
                    f"join key {n!r} dtypes differ: "
                    f"{ls.dtype_of(n)} vs {rs.dtype_of(n)}")
        lrest = self.rest_names(self.left)
        rrest = self.rest_names(self.right)
        clash = set(lrest) & set(rrest)
        if clash:
            raise ValueError(f"join sides share non-key columns "
                             f"{sorted(clash)}; rename before joining")
        fields = [(n, ls.dtype_of(n)) for n in self.on]
        fields += [(n, ls.dtype_of(n)) for n in lrest]
        fields += [(n, rs.dtype_of(n)) for n in rrest]
        return Schema(fields)

    def describe(self):
        parts = [f"on=[{', '.join(self.on)}]", f"how={self.how}"]
        if self.transport:
            parts.append(f"transport={self.transport}")
        return f"Join[{', '.join(parts)}]"


class Cached(Plan):
    """DataFrame.cache(): materialize THIS subtree's lowered lineage
    (RDD.cache underneath) on first evaluation; every query derived from
    the cached frame replans from the one materialization. The node is an
    OPTIMIZER BARRIER — pushing filters/pruning below it would specialize
    the materialization per derived query and no two queries would ever
    share it."""

    def __init__(self, child: Plan):
        self.child = child

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Cached(kids[0])

    def _compute_schema(self):
        return self.child.schema()

    def describe(self):
        return "Cached[]"


class Sort(Plan):
    """Total order over the full result — a FINAL operator; the engine
    stays unordered and the driver applies the order (with a
    per-partition top-n when a Limit sits directly above)."""

    def __init__(self, child: Plan, keys: Iterable):
        self.child = child
        self.keys = tuple(keys)  # ((Expr, ascending), ...)

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Sort(kids[0], self.keys)

    def _compute_schema(self):
        base = self.child.schema()
        for e, _ in self.keys:
            e.dtype(base)  # validate references
        return base

    def describe(self):
        keys = ", ".join(f"{e.sql()} {'asc' if asc else 'desc'}"
                         for e, asc in self.keys)
        return f"Sort[{keys}]"


class Limit(Plan):
    def __init__(self, child: Plan, n: int):
        self.child = child
        self.n = n

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Limit(kids[0], self.n)

    def _compute_schema(self):
        return self.child.schema()

    def describe(self):
        return f"Limit[{self.n}]"
