"""Vectorized columnar execution: compile the expression language to
array kernels over whole column batches (docs/vectorized_execution.md).

``compile_expr(expr, schema)`` is the vectorized sibling of
``Expr.bind()``: instead of a row -> value closure it produces a
``fn(cols, n) -> column`` closure evaluating a whole batch at once. A
column is one of three shapes, fixed by dtype:

  * "int" / "float" / "bool"  -> a numpy int64 / float64 / bool array
  * "str" and "list:..."      -> a plain Python list
  * a literal                 -> a bare Python scalar (broadcasts)

The contract with the row path is BIT-IDENTICAL RESULTS. Wherever a
numpy shortcut could diverge from the Python semantics of the bound row
closures, the compiled code either takes an exact path or raises
``VectorFallback`` so the fused operator re-runs the chunk through the
original row closures:

  * int64 arithmetic wraps silently in numpy (and ``np.errstate`` does
    NOT trap it) — every int +/-/* is shadowed in float64 and any result
    magnitude past 2**62 falls back (Python ints are unbounded);
  * division/modulo by zero raises in Python but yields inf/nan/0 in
    numpy — numeric stages run under ``errstate(divide="raise",
    invalid="raise")`` and the FloatingPointError falls back, which also
    preserves the short-circuit guarantee of ``a and b`` filters (the
    row path never evaluates ``b`` on rows ``a`` excluded);
  * mixed int/float comparisons promote int64 -> float64 in numpy but
    compare exactly in Python — ints past 2**53 fall back;
  * float group sums fold with first-occurrence initialization
    (``acc = vals[first]`` then ordered ``np.add.at``) so -0.0 and the
    fold order match the row path's left fold; float min/max fall back
    per-slot when NaN is present (Python's min/max keep the FIRST value
    on NaN, numpy propagates or ignores it).

In fact the fused operator treats ANY exception from a vectorized chunk
as a fallback signal and re-runs the chunk through the row closures, so
a divergence can only ever cost speed, never correctness.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core.shuffle import KVBatch

_NP_DTYPE = {"int": np.int64, "float": np.float64, "bool": np.bool_}
_NUMERIC = ("int", "float")
#: int results whose float64 shadow exceeds this may be near the int64
#: wrap point (float error cannot bridge the 2**62..2**63 gap)
_INT_GUARD = float(2**62)
#: ints beyond 2**53 lose precision as float64 — exact mixed comparison
#: requires falling back to Python's exact int/float comparison
_EXACT_F64 = float(2**53)


class VectorizeUnsupported(Exception):
    """Raised at COMPILE time: this expression has no vectorized form
    (udf, non-scalar operand) — the lowering keeps the row closures and
    explain() marks the operator ``[row-fallback: ...]``."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class VectorFallback(Exception):
    """Raised at RUN time, per chunk: the data hit a case where the array
    path would diverge from row semantics (int64 overflow risk, ints past
    2**53 in a float comparison, non-conforming input rows). The fused
    operator re-runs just that chunk through the bound row closures."""


# ---------------------------------------------------------- column helpers


def to_list(col, n: int) -> list:
    """Materialize a column as a list of exact Python values."""
    if isinstance(col, np.ndarray):
        return col.tolist()  # yields Python int/float/bool
    if isinstance(col, list):
        return col
    return [col] * n  # broadcast scalar


def _elems(col, n: int):
    """Iterable view for elementwise Python loops (str ops)."""
    if isinstance(col, np.ndarray):
        return col.tolist()
    if isinstance(col, list):
        return col
    return itertools.repeat(col, n)


def _is_scalar(col) -> bool:
    return not isinstance(col, (np.ndarray, list))


def _as_float(col):
    if isinstance(col, np.ndarray):
        return col if col.dtype == np.float64 else col.astype(np.float64)
    return float(col)


# --------------------------------------------------------------- compiler


def compile_expr(expr, schema):
    """Vectorized sibling of ``Expr.bind``: expr -> fn(cols, n) -> column.
    Raises VectorizeUnsupported for udfs and non-scalar operands."""
    from repro.sql import expr as E  # local import: expr imports us lazily

    if isinstance(expr, E.Alias):
        return compile_expr(expr.child, schema)
    if isinstance(expr, E.Col):
        i = schema.index(expr.name)
        return lambda cols, n: cols[i]
    if isinstance(expr, E.Lit):
        v = expr.value
        return lambda cols, n: v
    if isinstance(expr, E.BinOp):
        return _compile_binop(expr, schema)
    if isinstance(expr, E.Not):
        f = compile_expr(expr.child, schema)
        return lambda cols, n: _not(f(cols, n))
    if isinstance(expr, E.Substr):
        f = compile_expr(expr.child, schema)
        lo = expr.start - 1
        hi = lo + expr.length

        def f_substr(cols, n):
            v = f(cols, n)
            if _is_scalar(v):
                return v[lo:hi]
            return [s[lo:hi] for s in _elems(v, n)]
        return f_substr
    if isinstance(expr, E.Cast):
        return _compile_cast(expr, schema)
    if isinstance(expr, E.Udf):
        raise VectorizeUnsupported("udf")
    raise VectorizeUnsupported(type(expr).__name__)


def _not(v):
    if _is_scalar(v):
        return not v
    return ~np.asarray(v)


def _compile_binop(expr, schema):
    from repro.sql import expr as E

    lt, rt = expr.left.dtype(schema), expr.right.dtype(schema)
    lf = compile_expr(expr.left, schema)
    rf = compile_expr(expr.right, schema)
    op = expr.op

    if op in ("and", "or"):
        # both operands evaluate EAGERLY here; the row path short-circuits.
        # Any case where the unguarded operand would misbehave (divide by
        # zero, overflow) raises out of the array op and the chunk falls
        # back to the short-circuiting row closures — so eager evaluation
        # is only ever a fast path, never a semantic change.
        def f_bool(cols, n, _and=(op == "and")):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return (a and b) if _and else (a or b)
            return (a & b) if _and else (a | b)
        return f_bool

    if op == "+" and lt == rt == "str":
        def f_concat(cols, n):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return a + b
            return [x + y for x, y in zip(_elems(a, n), _elems(b, n))]
        return f_concat

    if op in ("+", "-", "*", "/", "%"):
        both_int = lt == rt == "int" and op != "/"
        npop = {"+": np.add, "-": np.subtract, "*": np.multiply,
                "/": np.divide, "%": np.mod}[op]
        pyop = {"+": lambda a, b: a + b, "-": lambda a, b: a - b,
                "*": lambda a, b: a * b, "/": lambda a, b: a / b,
                "%": lambda a, b: a % b}[op]

        def f_arith(cols, n):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return pyop(a, b)  # exact Python semantics
            if both_int:
                r = npop(a, b)  # int64 — may have wrapped silently
                if op in ("+", "-", "*"):
                    shadow = npop(_as_float(a), _as_float(b))
                    if np.any(np.abs(shadow) > _INT_GUARD):
                        raise VectorFallback("int64 overflow risk")
                return r
            # float result: int operands promote via exact int64->float64
            return npop(_as_float(a) if lt == "int" else a,
                        _as_float(b) if rt == "int" else b)
        return f_arith

    # comparisons
    npop = {"=": np.equal, "!=": np.not_equal, "<": np.less,
            "<=": np.less_equal, ">": np.greater, ">=": np.greater_equal}
    pyop = {"=": lambda a, b: a == b, "!=": lambda a, b: a != b,
            "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
            ">": lambda a, b: a > b, ">=": lambda a, b: a >= b}
    if lt in _NUMERIC and rt in _NUMERIC:
        mixed = lt != rt
        cmp_np, cmp_py = npop[expr.op], pyop[expr.op]

        def f_numcmp(cols, n):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return cmp_py(a, b)
            if mixed:
                # int64 -> float64 promotion is lossy past 2**53; Python
                # compares int vs float EXACTLY
                iv = a if lt == "int" else b
                if np.any(np.abs(np.asarray(iv, dtype=np.float64))
                          > _EXACT_F64):
                    raise VectorFallback("int past 2**53 in float compare")
            return cmp_np(a, b)
        return f_numcmp
    if lt == rt == "bool":
        cmp_np, cmp_py = npop[expr.op], pyop[expr.op]

        def f_boolcmp(cols, n):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return cmp_py(a, b)
            return cmp_np(a, b)
        return f_boolcmp
    if lt == rt == "str":
        cmp_py = pyop[expr.op]

        def cmp_nn(x, y):
            # None (NULL from outer-join padding) must yield NULL, but
            # Python's ==/!= on None return a bool — punt to row closures
            if x is None or y is None:
                raise VectorFallback("NULL in str comparison")
            return cmp_py(x, y)

        def f_strcmp(cols, n):
            a, b = lf(cols, n), rf(cols, n)
            if _is_scalar(a) and _is_scalar(b):
                return cmp_py(a, b)
            return np.fromiter((cmp_nn(x, y) for x, y in
                                zip(_elems(a, n), _elems(b, n))),
                               dtype=np.bool_, count=n)
        return f_strcmp
    raise VectorizeUnsupported(f"compare {lt}/{rt}")


def _compile_cast(expr, schema):
    f = compile_expr(expr.child, schema)
    src = expr.child.dtype(schema)
    to = expr.to
    if src.startswith("list:"):
        raise VectorizeUnsupported("cast from list")

    def g(cols, n):
        v = f(cols, n)
        if _is_scalar(v):
            return {"int": int, "float": float, "str": str, "bool": bool}[to](v)
        if to == src:
            return v  # passthrough keeps None as NULL, same as the row path
        if src == "str" and any(x is None for x in v):
            # str(None)/bool(None) would produce a value where the row
            # path now yields NULL — only arrays-free columns carry None
            raise VectorFallback("NULL in str column cast")
        if to == "int":
            if src == "float":
                arr = np.asarray(v)
                # Python int(f) is exact and unbounded; astype(int64) is
                # only exact for finite values inside the int64 range
                if (not np.all(np.isfinite(arr))
                        or np.any(arr >= float(2**63))
                        or np.any(arr < -float(2**63))):
                    raise VectorFallback("float->int out of int64 range")
                return arr.astype(np.int64)
            if src == "bool":
                return np.asarray(v).astype(np.int64)
            # str: Python parse (may exceed int64 -> numpy refuses -> the
            # chunk falls back and the row path returns the big int)
            return np.array([int(s) for s in v], dtype=np.int64)
        if to == "float":
            if src in ("int", "bool"):
                return np.asarray(v).astype(np.float64)
            return np.fromiter(map(float, v), dtype=np.float64, count=n)
        if to == "str":
            return [str(x) for x in to_list(v, n)]
        # to bool: Python truth — nonzero numbers / nonempty strings
        if src in ("int", "float"):
            return np.asarray(v) != 0  # NaN != 0 is True, matching bool(nan)
        return np.fromiter(map(bool, v), dtype=np.bool_, count=n)
    return g


# ------------------------------------------------------------- ingestion


def scan_ingest(specs):
    """Vectorized CSV parse: ``specs`` is [(field_idx, dtype, cast_fn)]
    per pruned output column. Parsing itself is the exact Python cast
    (int()/float()/bool-parse per field) collected straight into arrays —
    C-speed collection, Python-identical values."""
    def ingest(lines):
        parts = [ln.split(",") for ln in lines]
        n = len(parts)
        cols = []
        for idx, dtype, cast in specs:
            raw = [p[idx] for p in parts]
            if dtype == "str":
                cols.append([cast(r) for r in raw])
            else:
                cols.append(np.fromiter(map(cast, raw),
                                        dtype=_NP_DTYPE[dtype], count=n))
        return cols, n
    return ingest


def rows_ingest(dtypes):
    """Columnize a chunk of already-materialized rows, checking exact
    concrete types (bool is not int, 1.0 is not 1 — same conformance rule
    as the wire format). Non-conforming chunks fall back to row closures."""
    def ingest(rows):
        n = len(rows)
        cols = []
        for j, dtype in enumerate(dtypes):
            vals = [r[j] for r in rows]
            if dtype == "int":
                if not all(type(v) is int for v in vals):
                    raise VectorFallback("non-int value in int column")
                cols.append(np.array(vals, dtype=np.int64))  # may overflow
            elif dtype == "float":
                if not all(type(v) is float for v in vals):
                    raise VectorFallback("non-float value in float column")
                cols.append(np.array(vals, dtype=np.float64))
            elif dtype == "bool":
                if not all(type(v) is bool for v in vals):
                    raise VectorFallback("non-bool value in bool column")
                cols.append(np.array(vals, dtype=np.bool_))
            else:  # str / list:* stay Python lists (ragged-safe)
                cols.append(vals)
        return cols, n
    return ingest


# ----------------------------------------------------------- fused stages


def filter_stage(pred_fn):
    def stage(cols, n):
        mask = pred_fn(cols, n)
        if _is_scalar(mask):
            if mask:
                return cols, n
            return [c[:0] if isinstance(c, (np.ndarray, list)) else c
                    for c in cols], 0
        kept = int(mask.sum())
        ml = None
        out = []
        for c in cols:
            if isinstance(c, np.ndarray):
                out.append(c[mask])
            elif isinstance(c, list):
                if ml is None:
                    ml = mask.tolist()
                out.append([v for v, m in zip(c, ml) if m])
            else:
                out.append(c)
        return out, kept
    return stage


def project_stage(fns):
    def stage(cols, n):
        return [f(cols, n) for f in fns], n
    return stage


# ------------------------------------------------------------- emissions


def rows_emit(cols, n):
    lists = [to_list(c, n) for c in cols]
    return list(zip(*lists)) if lists else []


def col_selector(i):
    """Vectorized sibling of ``operator.itemgetter(i)`` over columns."""
    return lambda cols, n: cols[i]


def make_kv_plain_emit(key_fns, rest_idx, kschema, vschema):
    """Join/groupByKey map side: (key-tuple, rest-tuple) records carried
    column-major so the shuffle writer packs without transposing.
    ``key_fns`` are compiled column closures (keys may be computed)."""
    def emit(cols, n):
        if n == 0:
            return []
        kcols = [to_list(f(cols, n), n) for f in key_fns]
        vcols = [to_list(cols[i], n) for i in rest_idx]
        return [KVBatch(kcols, vcols, kschema, vschema)]
    return emit


def make_kv_agg_emit(key_fns, slot_fns, slot_ops, backend):
    """Partial aggregation: group the batch by key and fold each slot
    column, emitting one (key, partials) record per distinct key in
    FIRST-OCCURRENCE order — the same order the row path's combine dict
    discovers keys, so writer flush boundaries and wire bodies match."""
    def emit(cols, n):
        key_cols = [f(cols, n) for f in key_fns]
        slot_cols = [f(cols, n) for f in slot_fns]
        return grouped_records(key_cols, slot_cols, slot_ops, n, backend)
    return emit


def grouped_records(key_cols, slot_cols, slot_ops, n, backend="numpy"):
    if n == 0:
        return []
    keys = list(zip(*[to_list(c, n) for c in key_cols]))
    index: dict = {}
    gids = np.empty(n, dtype=np.int64)
    first = []
    for i, k in enumerate(keys):
        g = index.get(k)
        if g is None:
            g = len(index)
            index[k] = g
            first.append(i)
        gids[i] = g
    ng = len(index)
    first_arr = np.array(first, dtype=np.int64)
    out_slots = [to_list(_fold_slot(op, c, gids, ng, first_arr, n, backend),
                         ng)
                 for op, c in zip(slot_ops, slot_cols)]
    uniq = list(index)  # insertion order == first occurrence
    return [(k, tuple(s[g] for s in out_slots))
            for g, k in enumerate(uniq)]


def _fold_slot(op, col, gids, ng, first, n, backend):
    """Fold one slot column per group, reproducing the row path's left
    fold exactly: init from the group's FIRST value, accumulate the rest
    in row order (np.<op>.at applies sequentially)."""
    if _is_scalar(col):
        if op == "sum" and type(col) is int:
            counts = np.bincount(gids, minlength=ng)
            if abs(col) * n <= 2**62:
                return counts * col  # exact: repeated int addition
            return _py_fold(op, [col] * n, gids, ng)
        col = np.array([col] * n) if type(col) is not str else [col] * n
    if isinstance(col, list):  # str / list: columns — Python fold
        return _py_fold(op, col, gids, ng)
    if op == "sum":
        if col.dtype == np.int64:
            if backend == "jax":
                folded = _jax_int_sum(col, gids, ng)
                if folded is not None:
                    return folded
            # bound the worst-case partial: if even the sum of |v| stays
            # far from the wrap point, int64 accumulation is exact
            if float(np.abs(col).astype(np.float64).sum()) > _INT_GUARD:
                return _py_fold(op, col.tolist(), gids, ng)
            acc = np.zeros(ng, dtype=np.int64)
            np.add.at(acc, gids, col)
            return acc
        if col.dtype == np.bool_:
            raise VectorFallback("sum over bool column")
        acc = col[first].copy()  # float: -0.0-exact first-value init
        rest = np.ones(n, dtype=np.bool_)
        rest[first] = False
        np.add.at(acc, gids[rest], col[rest])
        return acc
    if op in ("min", "max"):
        if col.dtype == np.float64 and np.isnan(col).any():
            # Python's min/max keep the FIRST operand on NaN; numpy
            # either propagates (minimum) or ignores (fmin) it
            return _py_fold(op, col.tolist(), gids, ng)
        acc = col[first].copy()
        rest = np.ones(n, dtype=np.bool_)
        rest[first] = False
        ufunc = np.minimum if op == "min" else np.maximum
        ufunc.at(acc, gids[rest], col[rest])
        return acc
    raise VectorFallback(f"slot op {op!r}")


def _py_fold(op, vals, gids, ng):
    import operator as _op
    fold = {"sum": _op.add, "min": min, "max": max}[op]
    acc = [None] * ng
    seen = [False] * ng
    for g, v in zip(gids.tolist() if isinstance(gids, np.ndarray) else gids,
                    vals):
        if seen[g]:
            acc[g] = fold(acc[g], v)
        else:
            acc[g] = v
            seen[g] = True
    return acc


def _jax_int_sum(col, gids, ng):
    """Route an int64 group sum through the kernels/ backend
    (FLINT_VECTOR_BACKEND=jax). Integer addition is associative, so an
    order-free segment sum is exact as long as it cannot overflow — the
    same magnitude bound as the numpy path. Returns None to defer to the
    numpy path when jax is unavailable or the bound fails."""
    try:
        from repro.kernels.ops import grouped_reduce
    except Exception:
        return None
    try:
        out = grouped_reduce(col, gids, ng)
    except Exception:
        return None
    return None if out is None else np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------- fused operator


def _chunks(it, size):
    it = iter(it)
    while True:
        chunk = list(itertools.islice(it, size))
        if not chunk:
            return
        yield chunk


def make_fused(ingest, stages, emit, row_chain, batch_rows):
    """Build the batch-in/batch-out fused operator for RDD.mapBatches:
    chunk the partition iterator, run ingest -> stages -> emit per chunk
    under strict float error traps, and re-run any chunk that raises
    through ``row_chain`` (the exact per-row closure pipeline for the
    same plan segment). Emissions are materialized per chunk BEFORE
    yielding so a mid-chunk fallback never double-emits."""
    def fused(it):
        for chunk in _chunks(it, batch_rows):
            try:
                with np.errstate(divide="raise", invalid="raise",
                                 over="ignore", under="ignore"):
                    cols, n = ingest(chunk)
                    for stage in stages:
                        cols, n = stage(cols, n)
                    out = emit(cols, n)
            except Exception:
                out = list(row_chain(iter(chunk)))
            yield from out
    return fused
