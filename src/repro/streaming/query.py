"""The streaming DataFrame surface and the micro-batch driver loop.

    src = EventGenerator(seed=7, total=2000)
    q = (read_stream(ctx, src)
         .where(col("val") >= lit(10))
         .window("ts", size=20, slide=10)
         .groupBy("key")
         .agg(sum_(col("val")).alias("total"), count_().alias("n"))
         .start("demo", allowed_lateness=5, batch_size=300))
    rows = q.run()        # finalized (w_start, w_end, key, total, n)
    q.cleanup()

Each micro-batch is an ORDINARY job: the driver snapshots source
offsets, replays the recorded transforms over ``ctx.parallelize`` of the
batch rows, appends the ``Window`` pane assignment and a pane-keyed
aggregation, and runs it through the stock optimize/lower/run_action
path — CSE, adaptive execution, vectorization and chaos recovery all
compose for free. Aggregates are decomposed into ALGEBRAIC SLOTS
(avg -> sum+count; count merges by addition) so per-batch, per-pane
partials merge associatively on the driver across batches, exactly like
the map-side combine merges partials across partitions. Two hidden
slots ride along: the pane's max event time (folded into a
``core.queues.watermark_message`` that advances the window frontier)
and its row count (late-data drop accounting).

Offsets + window state + emitted rows checkpoint atomically to one
content-addressed ``_stream/<name>/ckpt/<batch>`` object after every
batch (last two retained). Starting a query whose name has checkpoints
RESUMES from the newest readable one; with replayable sources that makes
a kill/restart exactly-once — the interrupted batch re-reads the same
offset range against the same pre-batch state. ``sink_to_prefix`` writes
one object per finalized window under deterministic keys, so replayed
emissions overwrite themselves idempotently; ``for_each_batch``
callbacks are at-least-once across a crash.

The per-batch shuffle transport is the cost model's SQS-vs-S3 call
(core.costs.pick_shuffle_transport) over an EWMA of observed window
volume — small hot windows ride the queue, large cold ones the object
store — unless pinned with ``transport=``. Under a service session
(repro.svc) the query admits ONCE as a long-running job
(``stream_begin``) and re-checks the tenant quota between batches.

See docs/streaming.md for the protocol write-up.
"""

from __future__ import annotations

import operator
import time

from repro.core import costs
from repro.core.retry import TransientServiceError
from repro.core.queues import watermark_message, watermark_ts
from repro.core.scheduler import STREAM_PREFIX
from repro.sql import plan as P
from repro.sql.dataframe import DataFrame, _named
from repro.sql.expr import AggExpr, Alias, Col, col, count_, max_
from repro.sql.optimizer import PARTIAL_COMBINE_FACTOR, _row_width
from repro.streaming.sources import ride_faults
from repro.streaming.windows import WindowSpec, WindowState

#: reserved output columns of the per-batch plan
PANE_COL = "__pane"
_WM_COL = "__wm"
_N_COL = "__n"

_SLOT_MERGE = {"sum": operator.add, "count": operator.add,
               "min": min, "max": max}


def read_stream(ctx, source) -> "StreamFrame":
    """Open a streaming frame over an unbounded source (repro.streaming.
    sources contract). The same transforms as a batch DataFrame apply;
    ``window().groupBy().agg()`` then defines the windowed aggregation a
    ``start()`` call turns into a running ``StreamingQuery``."""
    return StreamFrame(ctx, source)


class _ProtoRdd:
    """Placeholder lineage node for schema validation only — the proto
    plan is never lowered; each batch builds a real ParallelCollection."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.nparts = 1


def _decompose(named_aggs):
    """Split user aggregates into algebraic slots that merge across
    batches: returns (slot AggExprs, per-slot combiners, finalize fn).
    ``collect_list`` is holistic — it cannot merge as a fixed-width slot
    and is rejected for streams."""
    slots, merges, layout = [], [], []
    for name, a in named_aggs:
        off = len(slots)
        if a.op == "collect_list":
            raise ValueError(f"{name}: collect_list is not algebraic — "
                             f"unsupported in streaming aggregations")
        if a.op == "avg":
            slots.append(AggExpr("sum", a.child, name=f"__s{off}"))
            slots.append(AggExpr("count", None, name=f"__s{off + 1}"))
            merges += [_SLOT_MERGE["sum"], _SLOT_MERGE["count"]]
            layout.append(("avg", off))
        else:  # sum/count/min/max merge with their own combiner
            slots.append(AggExpr(a.op, a.child, name=f"__s{off}"))
            merges.append(_SLOT_MERGE[a.op])
            layout.append(("id", off))

    def finalize(vals):
        out = []
        for kind, off in layout:
            if kind == "avg":
                out.append(vals[off] / vals[off + 1])
            else:
                out.append(vals[off])
        return out
    return slots, merges, finalize


class StreamFrame:
    """Pre-window transforms over the stream, validated eagerly against
    a proto plan (same plan nodes, never executed)."""

    def __init__(self, ctx, source, ops: tuple = (), proto: P.Plan = None):
        self.ctx = ctx
        self.source = source
        self.ops = tuple(ops)
        self.proto = proto if proto is not None else \
            P.RddScan(_ProtoRdd(ctx), source.schema)

    def _derive(self, op, proto: P.Plan) -> "StreamFrame":
        proto.schema()  # eager validation, like DataFrame._derive
        return StreamFrame(self.ctx, self.source, self.ops + (op,), proto)

    @property
    def schema(self):
        return self.proto.schema()

    def where(self, pred) -> "StreamFrame":
        return self._derive(("where", pred), P.Filter(self.proto, pred))

    filter = where

    def withColumn(self, name: str, e) -> "StreamFrame":
        from repro.sql.expr import _as_expr
        e = _as_expr(e)
        if name in self.schema.names:
            cols = [(n, e if n == name else Col(n))
                    for n in self.schema.names]
        else:
            cols = [(n, Col(n)) for n in self.schema.names] + [(name, e)]
        return self._derive(("withColumn", name, e),
                            P.Project(self.proto, cols))

    def select(self, *cols) -> "StreamFrame":
        named = [_named(c, "select") for c in cols]
        return self._derive(("select", cols), P.Project(self.proto, named))

    def join(self, static: DataFrame, on, how: str = "inner"
             ) -> "StreamFrame":
        """STREAM-STATIC join: the static side is a bounded DataFrame
        from the same context, re-planned inside every micro-batch (CSE
        and cache() make repeats cheap). Only stream-preserving shapes
        are allowed — a right/outer join would re-emit unmatched static
        rows once per batch."""
        if how not in ("inner", "left"):
            raise ValueError(f"stream-static join supports how="
                             f"'inner'/'left', not {how!r}")
        on = [on] if isinstance(on, str) else list(on)
        return self._derive(("join", static, tuple(on), how),
                            P.Join(self.proto, static.plan, on, how=how))

    def window(self, ts_col: str, size: int, slide: int | None = None
               ) -> "WindowedStream":
        spec = WindowSpec(ts_col, size, slide)
        if PANE_COL in self.schema.names:
            raise ValueError(f"{PANE_COL!r} is reserved for the window "
                             f"pane column")
        proto = P.Window(self.proto, ts_col, spec.size, spec.slide,
                         name=PANE_COL)
        return WindowedStream(self, spec, proto)

    def for_each_batch(self, fn) -> "StreamingQuery":
        raise ValueError("for_each_batch attaches at start(); define the "
                         "windowed aggregation first: "
                         ".window(...).groupBy(...).agg(...)"
                         ".start(name, for_each_batch=fn)")


class WindowedStream:
    def __init__(self, frame: StreamFrame, spec: WindowSpec,
                 proto: P.Plan):
        self.frame = frame
        self.spec = spec
        self.proto = proto

    def groupBy(self, *keys) -> "WindowedGrouped":
        named = tuple(_named(k, "groupBy") for k in keys)
        return WindowedGrouped(self, named)


class WindowedGrouped:
    def __init__(self, ws: WindowedStream, keys: tuple):
        self.ws = ws
        self.keys = keys

    def agg(self, *aggs: AggExpr, numPartitions: int | None = None
            ) -> "StreamDef":
        if not aggs:
            raise ValueError("agg() needs at least one aggregate")
        named = []
        for a in aggs:
            if not isinstance(a, AggExpr):
                raise TypeError(f"agg() takes aggregate expressions, "
                                f"got {a!r}")
            named.append((a.name, a))
        slots, merges, finalize = _decompose(named)
        spec = self.ws.spec
        ts = spec.ts_col
        batch_aggs = ([a.alias(a.name) for a in slots]
                      + [max_(col(ts)).alias(_WM_COL),
                         count_().alias(_N_COL)])
        # validate the full per-batch plan shape once, eagerly
        keys = ((PANE_COL, Col(PANE_COL)),) + self.keys
        P.Aggregate(self.ws.proto, keys,
                    [(a.name, a) for a in batch_aggs],
                    nparts=numPartitions).schema()
        return StreamDef(self.ws.frame, spec, self.keys, named, slots,
                         merges, finalize, numPartitions)


class StreamDef:
    """A fully-defined windowed streaming aggregation; ``start`` runs
    it (resuming from checkpoints under the same name, if any)."""

    def __init__(self, frame, spec, keys, named_aggs, slots, merges,
                 finalize, nparts):
        self.frame = frame
        self.spec = spec
        self.keys = keys
        self.named_aggs = named_aggs
        self.slots = slots
        self.merges = merges
        self.finalize = finalize
        self.nparts = nparts

    def start(self, name: str, *, allowed_lateness: int = 0,
              batch_size: int = 500, transport: str = "auto",
              sink_prefix: str | None = None, for_each_batch=None,
              checkpoint: bool = True) -> "StreamingQuery":
        return StreamingQuery(self, name,
                              allowed_lateness=allowed_lateness,
                              batch_size=batch_size, transport=transport,
                              sink_prefix=sink_prefix,
                              for_each_batch=for_each_batch,
                              checkpoint=checkpoint)


class StreamingQuery:
    """The micro-batch driver loop (see module docstring)."""

    def __init__(self, sdef: StreamDef, name: str, *,
                 allowed_lateness: int = 0, batch_size: int = 500,
                 transport: str = "auto", sink_prefix: str | None = None,
                 for_each_batch=None, checkpoint: bool = True):
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        frame = sdef.frame
        self.ctx = frame.ctx
        self.store = frame.ctx.store
        self.source = frame.source
        self.ops = frame.ops
        self.spec = sdef.spec
        self.name = name
        self.batch_size = batch_size
        self.transport = transport
        self.sink_prefix = sink_prefix
        self.for_each_batch = for_each_batch
        self.checkpointing = checkpoint

        self._key_names = tuple(n for n, _ in sdef.keys)
        self._key_args = tuple(
            n if isinstance(e, Col) and e.name == n else Alias(e, n)
            for n, e in sdef.keys)
        self._slot_aggs = ([a.alias(a.name) for a in sdef.slots]
                           + [max_(col(self.spec.ts_col)).alias(_WM_COL),
                              count_().alias(_N_COL)])
        self._nslots = len(sdef.slots)
        self.nparts = sdef.nparts or 4
        # observed-volume estimate for the per-window transport choice:
        # post-transform row width (plus the pane int) x batch rows
        self._row_bytes = _row_width(frame.proto.schema()) + 8.0

        self.state = WindowState(self.spec, sdef.merges, sdef.finalize,
                                 allowed_lateness)
        self.offset = self.source.initial()
        self.batch = 0
        self.emitted: list = []
        self.wmarks: list = []      # (src, batch, event-time) per message
        self.transports: list = []  # cost-model choice per batch
        self._volume: float | None = None
        self._drained = False
        self._stopped = False

        # service integration: admit ONCE as a long-running job; the
        # slot is held until stop()/cleanup()
        self._svc = hasattr(self.ctx, "stream_begin")
        if self._svc:
            self.ctx.stream_begin()
        if self.checkpointing:
            self._resume()

    # ------------------------------------------------------------ plumbing
    @property
    def _ckpt_prefix(self) -> str:
        return f"{STREAM_PREFIX}{self.name}/ckpt/"

    def _resume(self) -> bool:
        """Restore from the newest READABLE checkpoint: a checkpoint the
        chaos plan ate (acknowledged write, lost object) simply does not
        list, so recovery falls back to its predecessor and the
        replayable source re-reads the lost batch — exactly-once."""
        for key in sorted(ride_faults(self.store.list, self._ckpt_prefix),
                          reverse=True):
            try:
                snap = ride_faults(self.store.get_obj, key)
            except Exception:  # unreadable checkpoint -> try the older one
                continue
            self.offset = snap["offset"]
            self.batch = snap["batch"]
            self.state.restore(snap["state"])
            self.emitted = list(snap["emitted"])
            self.wmarks = list(snap["wmarks"])
            self.transports = list(snap["transports"])
            self._volume = snap["volume"]
            self._drained = snap["drained"]
            return True
        return False

    def _checkpoint(self) -> None:
        if not self.checkpointing:
            return
        snap = {"version": 1, "batch": self.batch, "offset": self.offset,
                "state": self.state.snapshot(),
                "emitted": list(self.emitted),
                "wmarks": list(self.wmarks),
                "transports": list(self.transports),
                "volume": self._volume, "drained": self._drained}
        ride_faults(self.store.put_obj,
                    f"{self._ckpt_prefix}{self.batch:08d}", snap)
        old = self.batch - 2  # retain the last two checkpoints
        if old >= 0:
            self.store.delete(f"{self._ckpt_prefix}{old:08d}")

    def _choose_transport(self, nrows: int) -> str:
        if self.transport != "auto":
            choice = self.transport
        else:
            obs = nrows * self._row_bytes
            self._volume = obs if self._volume is None else \
                0.5 * self._volume + 0.5 * obs
            choice = costs.pick_shuffle_transport(
                self._volume * PARTIAL_COMBINE_FACTOR,
                self.nparts, self.nparts)
        self.transports.append(choice)
        return choice

    def _stage(self, rows: list):
        """``ctx.parallelize`` with per-attempt retry: a transient fault
        mid-staging abandons a PARTIAL collection (each attempt takes a
        fresh counter), so failed attempts are swept before retrying."""
        for i in range(8):
            key = f"_collections/{self.ctx._collection_counter}"
            try:
                return self.ctx.parallelize(rows, self.nparts)
            except TransientServiceError:
                self.store.delete_prefix(key + "/")
                time.sleep(min(0.25, 0.002 * (2 ** i)))
        return self.ctx.parallelize(rows, self.nparts)

    def _run_batch(self, rows: list) -> list:
        choice = self._choose_transport(len(rows))
        rdd = self._stage(rows)
        try:
            df = DataFrame.from_rdd(rdd, self.source.schema)
            for op in self.ops:
                df = self._apply(df, op)
            df = df.withWindow(self.spec.ts_col, self.spec.size,
                               self.spec.slide, name=PANE_COL)
            gd = df.groupBy(PANE_COL, *self._key_args)
            return gd.agg(*self._slot_aggs, numPartitions=self.nparts,
                          transport=choice).collect()
        finally:
            # batch staging data is job input, not engine state — drop it
            # as soon as the batch's job is done
            self.store.delete_prefix(rdd.key + "/")

    def _apply(self, df: DataFrame, op: tuple) -> DataFrame:
        kind = op[0]
        if kind == "where":
            return df.where(op[1])
        if kind == "withColumn":
            return df.withColumn(op[1], op[2])
        if kind == "select":
            return df.select(*op[1])
        if kind == "join":
            static, on, how = op[1], op[2], op[3]
            return df.join(DataFrame(df.ctx, static.plan), on=list(on),
                           how=how)
        raise ValueError(f"unknown stream op {kind!r}")

    def _deliver(self, finalized: list, batch_id: int) -> None:
        if not finalized:
            return
        self.emitted.extend(finalized)
        if self.sink_prefix is not None:
            by_window: dict = {}
            for r in finalized:
                by_window.setdefault((r[0], r[1]), []).append(r)
            for (ws, we), rows in by_window.items():
                # deterministic per-window keys: a post-crash replay
                # overwrites the same objects with the same bytes
                ride_faults(self.store.put_obj,
                            f"{self.sink_prefix.rstrip('/')}/"
                            f"w{ws}_{we}", rows)
        if self.for_each_batch is not None:
            self.for_each_batch(batch_id, list(finalized))

    # ------------------------------------------------------------ the loop
    def step(self) -> bool:
        """One micro-batch: snapshot offsets, run the batch job, merge
        pane partials, advance the watermark, deliver what closed,
        checkpoint. Returns True if the batch carried any rows."""
        if self._stopped:
            raise RuntimeError(f"streaming query {self.name!r} is stopped")
        if self._svc:
            self.ctx.stream_quota_check()
        start = self.offset
        end = self.source.next_offset(start, self.batch_size)
        rows = self.source.read(start, end) if end != start else []
        batch_id = self.batch
        wm = None
        if rows:
            nuser = len(self._key_names)
            for r in self._run_batch(rows):
                pane = r[0]
                key = tuple(r[1:1 + nuser])
                slots = list(r[1 + nuser:1 + nuser + self._nslots])
                bwm, nrows = r[-2], r[-1]
                self.state.merge(pane, key, slots, nrows)
                wm = bwm if wm is None else max(wm, bwm)
        if wm is not None:
            # fold this batch's max event time into the watermark
            # protocol — the streaming generalization of per-producer EOS
            msg = watermark_message(f"{self.name}/b{batch_id}", wm,
                                    batch_id)
            self.wmarks.append((msg.src, batch_id, watermark_ts(msg)))
            finalized = self.state.advance(watermark_ts(msg))
        else:
            finalized = self.state.advance(None)
        self.offset = end
        self.batch = batch_id + 1
        self._deliver(finalized, batch_id)
        self._checkpoint()
        return bool(rows)

    def drain(self) -> None:
        """Close EVERY remaining window — the infinite watermark that
        degenerates to the batch engine's EOS. Called by run() when a
        finite source reports exhaustion."""
        if self._drained:
            return
        src = f"{self.name}/drain"
        msg = watermark_message(src, float("inf"), self.batch)
        self.wmarks.append((src, self.batch, watermark_ts(msg)))
        finalized = self.state.advance(watermark_ts(msg))
        self._drained = True
        self._deliver(finalized, self.batch)
        self._checkpoint()

    def run(self, max_batches: int | None = None, drain: bool = True
            ) -> list:
        """Drive the loop until the source is exhausted (or max_batches
        ran), then optionally drain; returns finalized rows so far."""
        steps = 0
        while max_batches is None or steps < max_batches:
            if self._drained:
                break
            self.step()
            steps += 1
            if self.source.exhausted(self.offset):
                break
            if max_batches is None and steps > 1_000_000:
                raise RuntimeError("unbounded run(): pass max_batches")
        if drain and self.source.exhausted(self.offset):
            self.drain()
        return self.results()

    # ---------------------------------------------------------- inspection
    def results(self) -> list:
        return list(self.emitted)

    @property
    def watermark(self) -> float:
        return self.state.watermark

    @property
    def late_dropped(self) -> int:
        return self.state.late_dropped

    def stats(self) -> dict:
        return {"batches": self.batch, "watermark": self.state.watermark,
                "late_dropped": self.state.late_dropped,
                "transports": list(self.transports),
                "wmarks": list(self.wmarks),
                "emitted": len(self.emitted)}

    # ------------------------------------------------------------ lifecycle
    def stop(self) -> None:
        """Stop driving the query (the service admission slot releases);
        checkpoints REMAIN so a same-name start() resumes."""
        if not self._stopped:
            self._stopped = True
            if self._svc:
                self.ctx.stream_end()

    def cleanup(self) -> int:
        """Stop and delete the query's ``_stream/`` state; returns the
        number of checkpoint objects removed."""
        self.stop()
        return self.store.delete_prefix(f"{STREAM_PREFIX}{self.name}/")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
