"""Micro-batch streaming on the Flint shuffle substrate.

``read_stream(ctx, source)`` opens a streaming DataFrame over an
unbounded source; ``window().groupBy().agg().start()`` runs it as a
``StreamingQuery`` — each micro-batch an ordinary optimized job, with
driver-side watermarks, exactly-once ``_stream/`` checkpoints, and a
per-window SQS-vs-S3 transport choice. See docs/streaming.md.
"""

from repro.streaming.query import (PANE_COL, StreamFrame, StreamingQuery,
                                   read_stream)
from repro.streaming.sources import (EventGenerator, S3PrefixTailer,
                                     ride_faults)
from repro.streaming.windows import WindowSpec, WindowState

__all__ = ["read_stream", "StreamFrame", "StreamingQuery", "PANE_COL",
           "EventGenerator", "S3PrefixTailer", "ride_faults",
           "WindowSpec", "WindowState"]
