"""Unbounded sources for the micro-batch streaming engine.

A source is anything with the four-method contract the driver loop
speaks (docs/streaming.md):

  * ``initial()`` — the offset before any event was consumed;
  * ``next_offset(offset, limit)`` — snapshot up to ``limit`` more
    source units past ``offset`` (events for the generator, objects for
    the tailer) and return the new offset. Pure bookkeeping: no event
    data moves yet;
  * ``read(start, end)`` — the rows between two offsets. REPLAYABLE:
    the same offset pair must return the same rows forever, because
    exactly-once recovery re-reads the batch a crashed driver was
    processing (the checkpoint stores offsets, never rows);
  * ``exhausted(offset)`` — True when the stream has ended at
    ``offset`` (finite generator drained, sealed prefix fully
    consumed). An unbounded source simply always returns False.

Offsets are opaque to the driver but must pickle (they land in
``_stream/`` checkpoints) and compare equal across process restarts.
"""

from __future__ import annotations

import random
import time

from repro.core.retry import TransientServiceError
from repro.sql.expr import CASTS, Schema


def ride_faults(fn, *args):
    """Call a store operation the way a driver SDK would, riding out the
    service-wide chaos injector's transient 5xxs with capped backoff (the
    last attempt surfaces the error)."""
    for i in range(8):
        try:
            return fn(*args)
        except TransientServiceError:
            time.sleep(min(0.25, 0.002 * (2 ** i)))
    return fn(*args)


class EventGenerator:
    """Seeded in-memory event stream: rows ``(ts, key, val)`` with
    integer event time, bounded out-of-orderness, and fully deterministic
    replay — event ``i`` is a pure function of ``(seed, i)``, so
    ``read(start, end)`` returns identical rows no matter how batches
    were cut before a crash.

    ``rate`` events share each event-time tick; with probability
    ``late_prob`` an event's ts lags its arrival position by up to
    ``max_delay`` ticks (the watermark/late-data surface under test).
    ``total`` bounds the stream (None = unbounded)."""

    schema = Schema([("ts", "int"), ("key", "str"), ("val", "int")])

    def __init__(self, *, seed: int = 0, n_keys: int = 4, rate: int = 10,
                 late_prob: float = 0.2, max_delay: int = 5,
                 total: int | None = None):
        if rate <= 0 or n_keys <= 0 or max_delay < 0:
            raise ValueError("rate/n_keys must be positive, max_delay >= 0")
        self.seed = seed
        self.n_keys = n_keys
        self.rate = rate
        self.late_prob = late_prob
        self.max_delay = max_delay
        self.total = total

    def _event(self, i: int) -> tuple:
        rng = random.Random((self.seed << 24) ^ i)
        ts = i // self.rate
        if self.max_delay and rng.random() < self.late_prob:
            ts = max(0, ts - rng.randint(1, self.max_delay))
        return (ts, f"k{rng.randrange(self.n_keys)}", rng.randrange(1000))

    # ------------------------------------------------------ source contract
    def initial(self) -> int:
        return 0

    def next_offset(self, offset: int, limit: int) -> int:
        end = offset + limit
        return end if self.total is None else min(end, self.total)

    def read(self, start: int, end: int) -> list:
        return [self._event(i) for i in range(start, end)]

    def exhausted(self, offset: int) -> bool:
        return self.total is not None and offset >= self.total


class S3PrefixTailer:
    """Tail an object-store prefix as an unbounded CSV stream: every new
    object under ``prefix`` becomes part of some micro-batch, rows parsed
    with the schema's CSV casts. The offset is the tuple of consumed
    object keys IN CONSUMPTION ORDER — ``next_offset`` appends newly
    listed keys (sorted, capped at ``limit``), and ``read`` re-fetches
    exactly the keys one offset added over the other, which makes replay
    exact as long as objects are immutable once written (the S3 model).

    ``seal()`` declares that no further objects will arrive, letting a
    finite stream drain (close every window) instead of idling."""

    def __init__(self, store, prefix: str, schema):
        self.store = store
        self.prefix = prefix
        self.schema = schema if isinstance(schema, Schema) else Schema(schema)
        self._casts = [CASTS[t] for _, t in self.schema]
        self._sealed = False

    def seal(self) -> None:
        self._sealed = True

    def _parse(self, data: bytes) -> list:
        rows = []
        for line in data.decode("utf-8").splitlines():
            if line:
                rows.append(tuple(cast(f) for cast, f in
                                  zip(self._casts, line.split(","))))
        return rows

    # ------------------------------------------------------ source contract
    def initial(self) -> tuple:
        return ()

    def next_offset(self, offset: tuple, limit: int) -> tuple:
        consumed = set(offset)
        listed = ride_faults(self.store.list, self.prefix)
        new = [k for k in listed if k not in consumed]
        return tuple(offset) + tuple(new[:limit])

    def read(self, start: tuple, end: tuple) -> list:
        if tuple(end[:len(start)]) != tuple(start):
            raise ValueError("tailer offsets diverged: end does not "
                             "extend start")
        rows = []
        for key in end[len(start):]:
            rows.extend(self._parse(ride_faults(self.store.get, key)))
        return rows

    def exhausted(self, offset: tuple) -> bool:
        if not self._sealed:
            return False
        consumed = set(offset)
        listed = ride_faults(self.store.list, self.prefix)
        return all(k in consumed for k in listed)
