"""Event-time windows, panes, and the driver-side watermark state.

The engine assigns every row a PANE — the ``slide``-wide bucket
``ts - ts % slide`` computed by the ``Window`` plan node inside each
micro-batch's distributed aggregation. A tumbling window (slide == size)
IS its pane; a sliding window of ``size = k * slide`` is the
recombination of ``k`` consecutive panes, so per-batch shuffles only
ever aggregate by pane and the cheap cross-pane merge happens here, on
the driver, over already-reduced slot partials.

``WindowState`` is that merge plus the watermark protocol
(docs/streaming.md):

  * ``merge`` folds one batch's (pane, key) slot partials into the
    running pane state — slot-wise, with the same associative combiners
    the map-side combine uses (sum/min/max; count and avg decompose
    into sums, see repro.streaming.query);
  * ``advance`` folds a watermark (the max event time any batch has
    observed, carried by ``core.queues.watermark_message``) and closes
    every window whose ``end + allowed_lateness`` the watermark has
    passed, emitting finalized rows in (window, key) order. Closing is
    strictly left-to-right (``frontier``), so allowed-lateness UPDATES
    land in still-open panes while contributions arriving after their
    last covering window closed are DROPPED AND COUNTED
    (``late_dropped``);
  * a drained finite stream advances with ``float("inf")`` — the
    degenerate watermark that, like the batch engine's plan-time EOS
    quorum, closes everything that remains.

The whole object snapshots/restores through the ``_stream/`` checkpoint
(plain picklable dicts), which is what makes kill-and-resume
exactly-once: state and source offsets commit atomically.
"""

from __future__ import annotations


class WindowSpec:
    """Validated tumbling/sliding window definition over an int
    event-time column. Mirrors the checks of the ``Window`` plan node
    (repro.sql.plan) — the two always travel together."""

    __slots__ = ("ts_col", "size", "slide")

    def __init__(self, ts_col: str, size: int, slide: int | None = None):
        size = int(size)
        slide = size if slide is None else int(slide)
        if size <= 0 or slide <= 0:
            raise ValueError(f"window size/slide must be positive "
                             f"(got {size}/{slide})")
        if size % slide != 0:
            raise ValueError(f"window size {size} must be a multiple of "
                             f"slide {slide}")
        self.ts_col = ts_col
        self.size = size
        self.slide = slide

    def windows_of(self, pane: int) -> range:
        """Window starts covering a pane, earliest first."""
        return range(pane - self.size + self.slide, pane + 1, self.slide)


class WindowState:
    """Cross-batch pane partials + watermark frontier (driver-side)."""

    def __init__(self, spec: WindowSpec, merges: list,
                 finalize, allowed_lateness: int = 0):
        if allowed_lateness < 0:
            raise ValueError("allowed_lateness must be >= 0")
        self.spec = spec
        self.merges = merges        # one binary combiner per slot
        self.finalize = finalize    # slot values -> output agg tuple
        self.lateness = allowed_lateness
        self.panes: dict = {}       # pane start -> {key tuple -> [slots]}
        self.watermark = float("-inf")
        self.frontier: int | None = None  # earliest still-open window
        self.late_dropped = 0       # events past their last open window

    # ------------------------------------------------------------- updates
    def merge(self, pane: int, key: tuple, slots: list, nrows: int) -> bool:
        """Fold one batch's partial for (pane, key); returns False when
        the contribution arrived after every window covering the pane was
        finalized (drop-and-count late data)."""
        if self.frontier is not None and pane < self.frontier:
            self.late_dropped += nrows
            return False
        groups = self.panes.setdefault(pane, {})
        cur = groups.get(key)
        if cur is None:
            groups[key] = list(slots)
        else:
            groups[key] = [m(a, b) for m, a, b in
                           zip(self.merges, cur, slots)]
        return True

    def advance(self, watermark: float | None = None) -> list:
        """Fold a watermark and emit every window it closes."""
        if watermark is not None:
            self.watermark = max(self.watermark, watermark)
        cutoff = self.watermark - self.lateness
        out: list = []
        while self.panes:
            lo = min(self.panes)
            start = lo - self.spec.size + self.spec.slide
            if self.frontier is not None:
                start = max(start, self.frontier)
            if start + self.spec.size > cutoff:
                break
            out.extend(self._close(start))
            self.frontier = start + self.spec.slide
            # a pane's LAST covering window starts at the pane itself —
            # panes behind the frontier can never be read again
            for p in [p for p in self.panes if p < self.frontier]:
                del self.panes[p]
        return out

    def _close(self, start: int) -> list:
        groups: dict = {}
        for p in range(start, start + self.spec.size, self.spec.slide):
            for key, slots in self.panes.get(p, {}).items():
                cur = groups.get(key)
                if cur is None:
                    groups[key] = list(slots)
                else:
                    groups[key] = [m(a, b) for m, a, b in
                                   zip(self.merges, cur, slots)]
        end = start + self.spec.size
        return [(start, end) + key + tuple(self.finalize(slots))
                for key, slots in sorted(groups.items())]

    # --------------------------------------------------------- checkpoints
    def snapshot(self) -> dict:
        return {"panes": {p: dict(g) for p, g in self.panes.items()},
                "watermark": self.watermark, "frontier": self.frontier,
                "late_dropped": self.late_dropped}

    def restore(self, snap: dict) -> None:
        self.panes = {p: dict(g) for p, g in snap["panes"].items()}
        self.watermark = snap["watermark"]
        self.frontier = snap["frontier"]
        self.late_dropped = snap["late_dropped"]
