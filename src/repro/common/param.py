"""Declarative parameter schemas.

Every model module declares its parameters once, as a nested dict of
:class:`P` entries (shape + logical axis names + init rule).  From that single
schema we derive:

* ``init_params``     — materialized arrays (for real runs / smoke tests),
* ``abstract_params`` — ShapeDtypeStructs (for the allocation-free dry-run),
* ``axes_tree``       — logical-axis tuples (resolved to mesh PartitionSpecs
                        by :mod:`repro.runtime.sharding`).

Keeping shapes, sharding and init in one table is what keeps 10 architectures
x 4 input shapes coherent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter: shape, logical axes (same arity), init rule."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | scaled | embed
    scale: float | None = None  # override init stddev

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} arity mismatch")


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last axis is the output axis for 2D+; fan-in is the product
    # of the remaining axes.
    if len(shape) <= 1:
        return max(1, shape[0] if shape else 1)
    return max(1, math.prod(shape[:-1]))


def _init_one(p: P, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    if p.init in ("normal", "scaled"):
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(_fan_in(p.shape))
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def is_leaf(x: Any) -> bool:
    return isinstance(x, P)


def init_params(schema: PyTree, key: jax.Array, dtype: jnp.dtype) -> PyTree:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(p, k, dtype) for p, k in zip(leaves, keys)]
    )


def abstract_params(schema: PyTree, dtype: jnp.dtype) -> PyTree:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema, is_leaf=is_leaf
    )


def axes_tree(schema: PyTree) -> PyTree:
    return jax.tree.map(lambda p: p.axes, schema, is_leaf=is_leaf)


def stack_schema(schema: PyTree, n: int, axis_name: str | None = "layers") -> PyTree:
    """Prefix every parameter with a stacked (scan) leading dim of size ``n``."""

    def stack_one(p: P) -> P:
        return P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale)

    return jax.tree.map(stack_one, schema, is_leaf=is_leaf)


def param_count(schema: PyTree) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_leaf)
    return sum(math.prod(p.shape) for p in leaves)


def map_with_path(fn: Callable[[tuple, P], Any], schema: PyTree) -> PyTree:
    return jax.tree_util.tree_map_with_path(fn, schema, is_leaf=is_leaf)
