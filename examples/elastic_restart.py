"""Fault tolerance demo: preemption mid-run, lease chaining, and bit-exact
resume — Flint's executor-chaining model applied to training.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.runtime import driver
from repro.runtime.steps import abstract_train_state


def main():
    cfg = get_config("yi-9b").reduced(n_layers=2, d_model=64, n_heads=4,
                                      n_kv_heads=2, head_dim=16, d_ff=128,
                                      vocab_size=512)
    tc = TrainConfig(total_steps=30, checkpoint_every=5, warmup_steps=3)

    with tempfile.TemporaryDirectory() as ref_dir, \
            tempfile.TemporaryDirectory() as chaos_dir:
        print("== uninterrupted run (reference)")
        ref = driver.train(cfg, tc, workdir=ref_dir, verbose=True)

        print("\n== chaos run: injected preemptions at steps 7 and 18")
        inj = driver.FailureInjector(at_steps=(7, 18))
        reports = driver.train_with_restarts(cfg, tc, workdir=chaos_dir,
                                             injector=inj, verbose=True)
        print("lease chain:", [(r.status, r.start_step, r.end_step)
                               for r in reports])

        ab = abstract_train_state(cfg, tc)
        s_ref = restore_checkpoint(ref_dir, latest_step(ref_dir), ab)
        s_chaos = restore_checkpoint(chaos_dir, latest_step(chaos_dir), ab)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.abs(np.asarray(a, np.float64)
                                      - np.asarray(b, np.float64)).max()),
            s_ref.params, s_chaos.params)))
        print(f"\nmax |param difference| after crash+resume: {diff}")
        assert diff == 0.0, "resume must be bit-exact"
        print("bit-exact recovery confirmed.")


if __name__ == "__main__":
    main()
