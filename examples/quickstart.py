"""Quickstart: the paper's user experience — PySpark-style analytics with
zero idle cost, on the serverless Flint engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import operator

from repro.core import FlintConfig, FlintContext
from repro.data.synthetic import GOLDMAN, taxi_csv


def main():
    # "S3 bucket" with ~10k taxi trips
    ctx = FlintContext("flint", FlintConfig(concurrency=16), verbose=True)
    ctx.upload("taxi.csv", taxi_csv(10_000, seed=42))

    # the paper's Q1: taxi drop-offs at Goldman Sachs HQ, by hour —
    # exactly the PySpark the user would write, UDFs and all
    def inside(row, box=GOLDMAN):
        try:
            lon, lat = float(row[2]), float(row[3])
        except ValueError:
            return False
        return box[0] <= lon <= box[2] and box[1] <= lat <= box[3]

    def get_hour(ts):
        return int(ts[11:13])

    arr = (ctx.textFile("taxi.csv", 8)
           .map(lambda x: x.split(","))
           .filter(inside)
           .map(lambda x: (get_hour(x[1]), 1))
           .reduceByKey(operator.add, 8)
           .collect())

    print("\ndrop-offs at Goldman Sachs by hour:")
    for hour, n in sorted(arr):
        print(f"  {hour:02d}:00  {'#' * n} {n}")

    print("\npay-as-you-go bill for this query:")
    for k, v in ctx.cost_report().items():
        print(f"  {k:20s} {v}")

    # the same engine, on the structured surface (docs/dataframe.md):
    # schemas in, optimizer on — watch explain() prune the scan to 3 of
    # 10 columns and pick map-side combine + a transport per shuffle
    from repro.sql import Schema, col, count_, lit, sum_

    schema = Schema([
        ("pickup", "str"), ("dropoff", "str"), ("dropoff_lon", "float"),
        ("dropoff_lat", "float"), ("trip_miles", "float"),
        ("payment_type", "str"), ("tip", "float"), ("total", "float"),
        ("precip", "float"), ("color", "str"),
    ])
    df = ctx.read_csv("taxi.csv", schema, 8)
    top = (df.where(col("payment_type") == lit("credit"))
             .withColumn("hour", col("pickup").substr(12, 2))
             .groupBy("hour")
             .agg(sum_(col("tip")).alias("tips"), count_().alias("trips"))
             .orderBy("tips", ascending=False)
             .limit(5))
    print("\noptimized logical plan:")
    print(top.explain())
    print("\ntop tipping hours (credit cards):")
    for hour, tips, trips in top.collect():
        print(f"  {hour}:00  ${tips:8.2f} over {trips} trips")


if __name__ == "__main__":
    main()
