"""Batched serving: prefill a batch of prompts, then greedy-decode with the
KV/state caches — the serve-side end-to-end driver.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x22b
    (reduced config on CPU; same code path the decode_32k dry-run lowers)
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (args.batch, cfg.frontend_len, cfg.d_model), cfg.cdtype)
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, 64, cfg.d_model), cfg.cdtype)

    print(f"arch={cfg.name} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    t0 = time.time()
    logits, caches = jax.jit(lambda p, b: lm.prefill(p, b, cfg))(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    start = args.prompt_len + (cfg.frontend_len if cfg.frontend == "vision"
                               else 0)
    kv_len = start + args.new_tokens
    caches = lm._grow_caches(caches, cfg, kv_len)
    step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, t, pos, c, cfg,
                                                       kv_len=kv_len))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, caches = step(params, tok[:, None], start + i, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    toks = jnp.stack(out, 1)
    print(f"decode: {dt*1e3:.1f} ms total, "
          f"{(args.new_tokens-1)*args.batch/dt:.0f} tok/s, "
          f"{dt/(args.new_tokens-1)*1e3:.2f} ms/step")
    print("sample row:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
