"""End-to-end training driver: train an LM with the fault-tolerant lease
driver (checkpoint/restart, deterministic data, metrics log).

    PYTHONPATH=src python examples/train_lm.py --preset small --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch yi-9b --preset smoke

Presets: smoke (~2M params), small (~20M), 100m (~124M — the "train a
~100M model" configuration; a few hundred steps is hours on this CPU
container but the same command runs unchanged on a TPU slice).
"""

import argparse
import time

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.runtime import driver


def preset_cfg(arch: str, preset: str):
    base = get_config(arch)
    if preset == "smoke":
        return base.reduced(), dict(batch=8, seq=64)
    if preset == "small":
        return base.reduced(
            n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
            d_ff=1024, vocab_size=8192), dict(batch=8, seq=128)
    if preset == "100m":
        return base.reduced(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
            d_ff=2048, vocab_size=32768), dict(batch=8, seq=256)
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--preset", default="small",
                    choices=["smoke", "small", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default="/tmp/flintjax_train")
    ap.add_argument("--lease-seconds", type=float, default=0.0)
    args = ap.parse_args()

    cfg, data = preset_cfg(args.arch, args.preset)
    from repro.models import lm as lm_mod
    print(f"arch={cfg.name} preset={args.preset} "
          f"params={lm_mod.n_params(cfg)/1e6:.1f}M")
    tc = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                     warmup_steps=max(10, args.steps // 20),
                     checkpoint_every=max(10, args.steps // 10),
                     lease_seconds=args.lease_seconds)
    from repro.data.synthetic import lm_batch
    t0 = time.time()
    reports = driver.train_with_restarts(
        cfg, tc, workdir=args.workdir,
        batch_fn=lambda i: lm_batch(tc.seed, i, data["batch"], data["seq"],
                                    cfg.vocab_size),
        verbose=True, max_restarts=100)
    r = reports[-1]
    print(f"\nstatus={r.status} steps={r.end_step} leases={len(reports)} "
          f"wall={time.time()-t0:.1f}s")
    if r.metrics:
        print(f"first loss={r.metrics[0]['loss']:.4f} "
              f"last loss={r.metrics[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
