"""Chaos harness (docs/fault_tolerance.md): seeded randomized fault
schedules across both transports and both scheduler modes must be
INVISIBLE in the results — every run produces exactly the fault-free
answer with zero leaked queues/objects — plus targeted scenarios for each
recovery layer (call retry, task retry + 429 backoff, lineage-based stage
resubmission, cache re-materialization) and for every exhaustion path.

``FLINT_CHAOS_SEED`` re-bases the randomized sweep so CI can pin one leg
to a fixed schedule while letting exploratory runs roll new ones."""

import operator
import os

import pytest

from repro.core import (FaultPlan, FlintConfig, FlintContext, StageFailure)

CHAOS_SEED = int(os.environ.get("FLINT_CHAOS_SEED", "0"))

#: transient prefixes that must be empty once a job (even a failed one)
#: has shut down — _cache/ is excluded: registered caches outlive jobs
TRANSIENT_PREFIXES = ("_exchange/", "_spill/", "_payload/", "_result/",
                      "_broadcast/", "_stream/")

DATA = [(i % 7, i) for i in range(300)]
EXPECTED = {}
for _k, _v in DATA:
    EXPECTED[_k] = EXPECTED.get(_k, 0) + _v
EXPECTED = sorted(EXPECTED.items())

ADD = operator.add


def chaos_config(backend, pipelined, **kw):
    kw.setdefault("concurrency", 8)
    kw.setdefault("flush_records", 50)
    kw.setdefault("visibility_timeout_s", 0.5)
    kw.setdefault("drain_timeout_s", 1.5)
    kw.setdefault("retry_base_s", 0.001)
    kw.setdefault("retry_cap_s", 0.01)
    kw.setdefault("max_stage_retries", 5)
    return FlintConfig(shuffle_backend=backend, pipeline_stages=pipelined,
                       **kw)


def assert_no_leaks(ctx):
    leaked = [k for p in TRANSIENT_PREFIXES for k in ctx.store.list(p)]
    assert not leaked, f"leaked transient objects: {leaked[:5]}"
    sched = ctx.last_scheduler
    assert sched.sqs._queues == {}, "leaked queues"


def run_job(backend, pipelined, plan, **cfg_kw):
    ctx = FlintContext(config=chaos_config(backend, pipelined, **cfg_kw),
                       fault_plan=plan)
    result = (ctx.parallelize(DATA, 4)
              .reduceByKey(ADD, 3)
              .collect())
    return ctx, sorted(result)


# ------------------------------------------------- randomized fault sweep
# 13 seeds x 2 transports x 2 modes = 52 seeded schedules, every one of
# which must produce the exact fault-free answer and leak nothing.

SWEEP_SEEDS = [CHAOS_SEED * 1000 + i for i in range(13)]


@pytest.mark.parametrize("pipelined", [True, False],
                         ids=["pipelined", "barrier"])
@pytest.mark.parametrize("backend", ["sqs", "s3"])
@pytest.mark.parametrize("seed", SWEEP_SEEDS)
def test_chaos_schedule_is_invisible_in_results(seed, backend, pipelined):
    plan = FaultPlan(seed=seed,
                     s3_error_prob=0.03,
                     sqs_error_prob=0.03,
                     sqs_delay_prob=0.10, sqs_delay_s=0.02,
                     invoke_throttle_prob=0.02,
                     lose_object_prob=0.02)
    ctx, result = run_job(backend, pipelined, plan)
    assert result == EXPECTED
    assert_no_leaks(ctx)


# --------------------------------------------- targeted recovery scenarios


def test_lost_exchange_object_recovers_via_stage_resubmission():
    """An acknowledged exchange batch vanishes after write; the drain
    proves the producer quorum complete, raises LostShuffleInput, and the
    scheduler re-executes the producing stage from lineage — observable in
    recovery_stats, invisible in the result."""
    plan = FaultPlan(lose_keys=("_exchange/",))
    ctx, result = run_job("s3", True, plan)
    assert result == EXPECTED
    sched = ctx.last_scheduler
    assert sched.recovery_stats["lost_inputs"] >= 1
    assert sched.recovery_stats["stage_resubmits"] >= 1
    assert sched.recovery_stats["replayed_tasks"] >= 1
    assert sched.faults.stats["lost_objects"] == 1
    assert_no_leaks(ctx)


def test_lost_exchange_object_recovers_in_barrier_mode():
    plan = FaultPlan(lose_keys=("_exchange/",))
    ctx, result = run_job("s3", False, plan)
    assert result == EXPECTED
    assert ctx.last_scheduler.recovery_stats["stage_resubmits"] >= 1
    assert_no_leaks(ctx)


def test_lost_cache_batch_replans_and_rematerializes():
    """A materialized _cache/ batch is acknowledged then lost. The next
    action's manifest check raises LostCacheInput; the CONTEXT drops the
    damaged materialization and replans the cached lineage from source."""
    plan = FaultPlan(lose_keys=("_cache/",))
    ctx = FlintContext(config=chaos_config("sqs", True), fault_plan=plan)
    cached = ctx.parallelize(DATA, 4).map(lambda kv: kv).cache()
    first = sorted(cached.reduceByKey(ADD, 3).collect())
    assert first == EXPECTED  # materializing action: loss is silent
    assert ctx.last_scheduler.faults.stats["lost_objects"] == 1
    second = sorted(cached.reduceByKey(ADD, 3).collect())  # reads cache
    assert second == EXPECTED
    assert_no_leaks(ctx)


def test_account_concurrency_throttling_backs_off_and_completes():
    """Dispatch beyond the account cap draws 429s; the scheduler backs
    off (decorrelated jitter) and redrives. Barrier mode: under a tight
    cap, pipelined consumers would squat on concurrency slots while
    draining and starve the throttled producers (docs/fault_tolerance.md
    documents that trade-off)."""
    def slow_ident(kv):
        import time
        time.sleep(0.002)  # hold the container so dispatches overlap
        return kv

    plan = FaultPlan(account_concurrency=2)
    ctx = FlintContext(config=chaos_config("sqs", False, concurrency=6),
                       fault_plan=plan)
    result = sorted(ctx.parallelize(DATA, 6).map(slow_ident)
                    .reduceByKey(ADD, 3).collect())
    assert result == EXPECTED
    sched = ctx.last_scheduler
    assert sched.recovery_stats["throttled"] > 0
    assert sched.lam.throttles > 0
    # 429s never ran: counted on the ledger but billed no GB-seconds
    assert ctx.ledger.report()["lambda_throttles"] > 0
    assert_no_leaks(ctx)


def test_invocation_timeout_partial_flushes_absorbed_by_dedup():
    """The lease expires mid-task AFTER one full flush landed: the retry
    re-emits byte-identical batches and downstream (src, seq) dedup
    absorbs the overlap — no double counting."""
    for backend in ("sqs", "s3"):
        plan = FaultPlan(tasks={(0, 1): {"timeout_after_records": 60}})
        ctx, result = run_job(backend, True, plan)  # flush_records=50 < 60
        assert result == EXPECTED, backend
        assert ctx.last_scheduler.faults.stats["timeouts"] == 1
        assert_no_leaks(ctx)


def test_retried_calls_bill_honestly():
    """Failed 5xx attempts are never billed (AWS does not charge server
    errors) — each retry re-bills only the attempt that actually ran, so
    the successful-request bill matches fault-free exactly and total cost
    stays within the run_chaos_ab 2x gate."""
    quiet_ctx, quiet = run_job("s3", True, None)
    noisy_ctx, noisy = run_job("s3", True, FaultPlan(seed=5,
                                                     s3_error_prob=0.2))
    assert quiet == noisy == EXPECTED
    assert noisy_ctx.ledger.report()["service_faults"] > 0
    noisy_reqs = noisy_ctx.ledger.s3_gets + noisy_ctx.ledger.s3_puts
    quiet_reqs = quiet_ctx.ledger.s3_gets + quiet_ctx.ledger.s3_puts
    assert noisy_reqs == quiet_reqs  # failed attempts billed nothing
    assert (noisy_ctx.ledger.report()["total_usd"]
            <= 2 * quiet_ctx.ledger.report()["total_usd"])


# -------------------------------------------------- exhaustion (failure)
# Every bounded recovery layer must fail STRUCTURED and leak-free when its
# budget runs out — on both transports.


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_task_retry_exhaustion_is_structured_and_leak_free(backend):
    plan = FaultPlan(tasks={(0, 1): {"fail_attempts": 99}})
    ctx = FlintContext(config=chaos_config(backend, True,
                                           max_task_retries=1),
                       fault_plan=plan, elastic_retries=0)
    with pytest.raises(StageFailure) as exc:
        ctx.parallelize(DATA, 4).reduceByKey(ADD, 3).collect()
    e = exc.value
    assert e.error_type == "InjectedFailure"
    assert e.stage_id == 0 and e.task_index == 1
    assert e.attempts == 2 and e.retryable is False
    assert_no_leaks(ctx)  # the FAILURE path must gc too


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_stage_resubmission_exhaustion(backend):
    """A permanent black hole on first-sequence exchange batches: every
    resubmitted producer loses its rewrite again, so the stage-retry
    budget exhausts and the failure surfaces structured, without leaks.
    (On sqs the loss targets nothing — included to pin that a transport
    with no durable exchange objects simply never enters this path.)"""
    plan = FaultPlan(lose_keys_every=("-00000000-",))
    ctx = FlintContext(config=chaos_config(backend, True,
                                           max_stage_retries=1,
                                           drain_timeout_s=1.0),
                       fault_plan=plan, elastic_retries=0)
    if backend == "sqs":
        result = sorted(ctx.parallelize(DATA, 4)
                        .reduceByKey(ADD, 3).collect())
        assert result == EXPECTED
    else:
        with pytest.raises(StageFailure) as exc:
            ctx.parallelize(DATA, 4).reduceByKey(ADD, 3).collect()
        e = exc.value
        assert e.error_type in ("LostShuffleInput", "TimeoutError")
        assert "stage-resubmission budget exhausted" in str(e)
        assert e.retryable is False
        assert ctx.last_scheduler.recovery_stats["stage_resubmits"] >= 1
    assert_no_leaks(ctx)


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_retry_budget_exhaustion_mid_drain(backend):
    """A tiny job-wide retry budget under heavy transient errors: the
    budget dies mid-job and the failure is terminal (a job burning its
    whole budget is systemically unhealthy), structured, and leak-free."""
    plan = FaultPlan(seed=2, s3_error_prob=0.6, sqs_error_prob=0.6)
    ctx = FlintContext(config=chaos_config(backend, True, retry_budget=4,
                                           retry_max_attempts=10),
                       fault_plan=plan, elastic_retries=0)
    with pytest.raises(StageFailure) as exc:
        ctx.parallelize(DATA, 4).reduceByKey(ADD, 3).collect()
    assert exc.value.error_type == "RetryBudgetExhausted"
    assert exc.value.retryable is False
    assert_no_leaks(ctx)
