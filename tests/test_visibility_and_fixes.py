"""SQS visibility-timeout semantics (receive claims, ack-after-fold,
redelivery) and the satellite bugfix regressions: serde kwdefaults,
serde self-reference, oversized-record spill, barrier-mode teardown."""

import operator
import pickle
import time

import pytest

from repro.core import (FlintConfig, FlintContext, FlintScheduler,
                        StageFailure, build_plan)
from repro.core.costs import CostLedger
from repro.core.queues import (Message, ObjectStoreSim, QueueGone, SQSSim,
                               SpillPointer, pack_records, unpack_records)
from repro.core import serde

TEXT = "\n".join(["the quick brown fox", "jumps over the lazy dog",
                  "the dog barks"] * 100).encode()

EXPECTED = {"the": 300, "quick": 100, "brown": 100, "fox": 100,
            "jumps": 100, "over": 100, "lazy": 100, "dog": 200, "barks": 100}


def wordcount(ctx, nparts=4, red_parts=3):
    ctx.upload("text.txt", TEXT)
    return dict(ctx.textFile("text.txt", nparts)
                .flatMap(lambda line: line.split())
                .map(lambda w: (w, 1))
                .reduceByKey(operator.add, red_parts)
                .collect())


# ------------------------------------------------ visibility unit tests


def _sim(vis=0.2, **kw):
    sqs = SQSSim(CostLedger(), visibility_timeout=vis, **kw)
    sqs.create_queue("q")
    return sqs


def test_receive_claims_instead_of_popping():
    sqs = _sim()
    sqs.send_batch("q", [Message(b"a", 0, "s0t0")])
    got = sqs.receive_many("q")
    assert len(got) == 1 and got[0].receipt is not None
    # in flight: invisible to a second receive, absent from the backlog
    assert sqs.receive_many("q") == []
    assert sqs.approx_len("q") == 0
    assert sqs.inflight_len("q") == 1


def test_unacked_message_redelivers_after_timeout():
    sqs = _sim(vis=0.15)
    sqs.send_batch("q", [Message(b"a", 0, "s0t0")])
    first_receipt = sqs.receive_many("q")[0].receipt
    time.sleep(0.2)
    again = sqs.receive_many("q")  # lazy sweep returns it to visible
    assert len(again) == 1 and (again[0].src, again[0].seq) == ("s0t0", 0)
    assert again[0].receipt != first_receipt  # fresh handle, fresh receive
    assert sqs.redeliveries == 1


def test_ack_deletes_and_duplicate_acks_are_idempotent():
    sqs = _sim(vis=0.15)
    sqs.send_batch("q", [Message(b"a", 0, "s0t0")])
    m = sqs.receive_many("q")[0]
    sqs.delete_batch("q", [m.receipt])
    sqs.delete_batch("q", [m.receipt])  # double ack: no-op
    time.sleep(0.2)
    assert sqs.receive_many("q") == []  # acked for good, never redelivered
    assert sqs.inflight_len("q") == 0


def test_stale_receipt_after_redelivery_is_a_noop():
    """An expired claim's old receipt must not delete the message out from
    under whoever re-received it."""
    sqs = _sim(vis=0.15)
    sqs.send_batch("q", [Message(b"a", 0, "s0t0")])
    old = sqs.receive_many("q")[0].receipt
    time.sleep(0.2)
    again = sqs.receive_many("q")  # redelivered under a new receipt
    assert len(again) == 1
    sqs.delete_batch("q", [old])  # stale: no-op
    assert sqs.inflight_len("q") == 1
    sqs.delete_batch("q", [again[0].receipt])
    assert sqs.inflight_len("q") == 0


def test_change_visibility_extends_the_claim():
    sqs = _sim(vis=0.15)
    sqs.send_batch("q", [Message(b"a", 0, "s0t0")])
    m = sqs.receive_many("q")[0]
    sqs.change_visibility("q", [m.receipt], 1.0)
    time.sleep(0.3)  # past the original deadline, inside the extension
    assert sqs.receive_many("q") == []
    assert sqs.inflight_len("q") == 1


def test_receive_from_deleted_queue_raises_queue_gone():
    sqs = _sim()
    sqs.delete_queue("q")
    with pytest.raises(QueueGone):
        sqs.receive_many("q")


def test_receive_many_drains_requested_backlog():
    """Adaptive drain sizing: one scheduler step can take the whole
    visible backlog, not a fixed 100."""
    sqs = _sim(vis=5.0)
    for i in range(0, 300, 10):
        sqs.send_batch("q", [Message(b"x", i + j, "s0t0")
                             for j in range(10)])
    backlog = sqs.approx_len("q")
    assert backlog == 300
    got = sqs.receive_many("q", min(1000, max(10, backlog)))
    assert len(got) == 300
    assert sqs.approx_len("q") == 0


def test_visibility_must_undercut_drain_timeout():
    """A visibility timeout at or above the drain timeout means a retried
    consumer gives up before its predecessor's claims expire — rejected
    up front instead of failing later with 'queue incomplete'."""
    with pytest.raises(ValueError, match="visibility_timeout_s"):
        FlintScheduler(FlintConfig(shuffle_backend="sqs",
                                   visibility_timeout_s=30.0,
                                   drain_timeout_s=30.0))
    FlintScheduler(FlintConfig(shuffle_backend="s3", visibility_timeout_s=30.0,
                               drain_timeout_s=30.0)).shutdown()  # s3: moot


# ------------------------------------- consumer failure is recoverable


@pytest.mark.parametrize("pipelined", [True, False])
def test_consumer_failure_recovers_with_identical_results(pipelined):
    """The acceptance criterion: a ShuffleRead task dying mid-task
    (fail_after_records) completes via retry with results identical to
    the fault-free run, in both modes, under duplicate_prob > 0."""
    cfg = dict(concurrency=4, flush_records=20, duplicate_prob=0.2,
               visibility_timeout_s=0.5, drain_timeout_s=8.0,
               pipeline_stages=pipelined)
    clean = wordcount(FlintContext("flint", FlintConfig(**cfg)))
    faulty = FlintContext("flint", FlintConfig(**cfg),
                          fault_plan={(1, 1): {"fail_after_records": 2}},
                          elastic_retries=0)
    assert wordcount(faulty) == clean == EXPECTED


@pytest.mark.parametrize("pipelined", [True, False])
def test_consumer_speculation_no_longer_splits_queue(pipelined):
    """A straggling consumer gets a speculative duplicate; the two drains
    race on acks (instead of destructively splitting the queue) and the
    loser aborts on QueueGone when the winner's queue is released."""
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=8, pipeline_stages=pipelined,
                                   speculation_factor=2.0,
                                   speculation_min_done=2,
                                   visibility_timeout_s=0.5,
                                   # adaptive coalescing would fold these
                                   # deliberately tiny reduce partitions
                                   # into one task — this test needs the
                                   # full 6 to race a speculative twin
                                   coalesce_min_bytes=0),
                       fault_plan={(1, 0): {"straggle_s": 0.8}})
    assert wordcount(ctx, nparts=4, red_parts=6) == EXPECTED
    reduce_stats = ctx.last_scheduler.stage_stats[-1]
    assert reduce_stats["speculated"] >= 1


@pytest.mark.parametrize("pipelined", [True, False])
def test_mid_pipeline_consumer_writer_retry_is_deterministic(pipelined):
    """A shuffle-reading task that WRITES another shuffle re-emits
    byte-identical (src, seq) messages on retry (output is sorted before
    partitioning/packing), so downstream dedup never mixes two attempts'
    packings — even when the first attempt flushed partial output before
    dying."""
    def three_stage(ctx):
        ctx.upload("text.txt", TEXT)
        return sorted(ctx.textFile("text.txt", 4)
                      .flatMap(lambda line: line.split())
                      .map(lambda w: (w, 1))
                      .reduceByKey(operator.add, 2)   # stage 1: read+write
                      .map(lambda kv: (kv[1], 1))
                      .reduceByKey(operator.add, 2)   # stage 2: final
                      .collect())

    cfg = dict(concurrency=4, flush_records=1, duplicate_prob=0.2,
               visibility_timeout_s=0.5, drain_timeout_s=8.0,
               pipeline_stages=pipelined)
    clean = three_stage(FlintContext("flint", FlintConfig(**cfg)))
    faulty = FlintContext("flint", FlintConfig(**cfg),
                          fault_plan={(1, 0): {"fail_after_records": 1},
                                      (1, 1): {"fail_after_records": 1}},
                          elastic_retries=0)
    assert three_stage(faulty) == clean == [(100, 7), (200, 1), (300, 1)]


@pytest.mark.parametrize("pipelined", [True, False])
def test_mid_pipeline_groupby_retry_is_deterministic(pipelined):
    """Same, for group mode: value lists collect in arrival order, which
    differs across attempts — the drain sorts them before the task
    re-emits records that embed them."""
    cfg = dict(concurrency=4, flush_records=1, duplicate_prob=0.2,
               visibility_timeout_s=0.5, drain_timeout_s=8.0,
               pipeline_stages=pipelined)
    data = [(i % 4, i) for i in range(24)]

    def query(ctx):
        out = (ctx.parallelize(data, 3)
               .groupByKey(2)                         # stage 1: read+write
               .map(lambda kv: (len(kv[1]), sorted(kv[1])))
               .groupByKey(2)                         # stage 2: final
               .collect())
        # a group's value order carries no guarantee — compare multisets
        return sorted((k, sorted(v)) for k, v in out)

    clean = query(FlintContext("flint", FlintConfig(**cfg)))
    faulty = FlintContext("flint", FlintConfig(**cfg),
                          fault_plan={(1, 0): {"fail_after_records": 1},
                                      (1, 1): {"fail_after_records": 1}},
                          elastic_retries=0)
    assert query(faulty) == clean


@pytest.mark.parametrize("pipelined", [True, False])
def test_chained_producer_link_failure_resumes_from_cursor(pipelined):
    """A chained producer whose SECOND link dies retries from its last
    continuation cursor: the completed link's (src, seq) messages stay
    untouched and only the failed link replays — byte-identical, since
    in-link flush boundaries are record-count-based."""
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=4, pipeline_stages=pipelined,
                                   max_records_per_invoke=35,
                                   flush_records=10, duplicate_prob=0.2,
                                   visibility_timeout_s=0.5,
                                   drain_timeout_s=8.0),
                       fault_plan={(0, 1): {"fail_on_link": 2}},
                       elastic_retries=0)
    assert wordcount(ctx) == EXPECTED
    stats = ctx.last_scheduler.stage_stats[0]
    assert stats["chained"] > 0
    assert stats["attempts"] >= 5  # 4 tasks + the link-2 retry


def test_drain_stall_times_out_despite_own_redeliveries():
    """A batch made purely of the drain's own lapsed-claim redeliveries is
    not progress: with a stuck producer (no EOS ever), the inactivity
    timeout must still fire instead of being reset forever."""
    import threading
    from repro.core.executors import (FlintConfig as FC, LambdaSim,
                                      _drain_shuffle)
    from repro.core.dag import ShuffleRead

    cfg = FC(shuffle_backend="sqs", visibility_timeout_s=0.2,
             drain_timeout_s=1.0)
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    sqs = SQSSim(ledger, visibility_timeout=cfg.visibility_timeout_s)
    env = LambdaSim(cfg, ledger, store, sqs)
    from repro.core.shuffle import pack_batch, queue_name
    q8 = queue_name(8, 0)
    sqs.create_queue(q8)
    for body in pack_batch([(1, 1), (2, 2)]):
        sqs.send_batch(q8, [Message(body, 0, "s0t0")])
    # no EOS: the producer is permanently stuck

    err = []
    def drain():
        try:
            _drain_shuffle(ShuffleRead([(8, "group")], 0), env, {"8": 1})
        except Exception as e:  # noqa: BLE001
            err.append(e)
    t = threading.Thread(target=drain, daemon=True)
    t.start()
    t.join(8.0)
    assert not t.is_alive(), "drain hung: own redeliveries reset the deadline"
    assert err and isinstance(err[0], TimeoutError)
    sqs.close()


def test_consumer_retry_when_attempt_holds_messages_in_flight():
    """executor-level: a drain that received everything but died without
    acking leaves the queue refillable — a fresh drain completes after
    the visibility deadline lapses."""
    from repro.core.executors import (FlintConfig as FC, LambdaSim,
                                      _drain_shuffle)
    from repro.core.dag import ShuffleRead

    cfg = FC(shuffle_backend="sqs", visibility_timeout_s=0.3,
             drain_timeout_s=5.0)
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    sqs = SQSSim(ledger, visibility_timeout=cfg.visibility_timeout_s)
    env = LambdaSim(cfg, ledger, store, sqs)
    from repro.core.shuffle import pack_batch, queue_name
    q7 = queue_name(7, 0)
    sqs.create_queue(q7)
    for body in pack_batch([(i, i) for i in range(50)]):
        sqs.send_batch(q7, [Message(body, 0, "s0t0")])
    sqs.send_batch(q7, [Message(b"", 1, "s0t0", kind="eos")])

    read = ShuffleRead([(7, "group")], 0)
    out1, _, _ack1 = _drain_shuffle(read, env, {"7": 1})
    # first attempt "dies" here: _ack1 never called, messages in flight
    out2, _, ack2 = _drain_shuffle(read, env, {"7": 1})
    assert out1[(7, "group")] == out2[(7, "group")]
    ack2()
    assert sqs.inflight_len(q7) == 0


# --------------------------------------------------- serde regressions


def test_serde_preserves_kwdefaults():
    def f(x, *, k=3, label="v"):
        return (x + k, label)

    g = serde.loads_fn(serde.dumps_fn(f))
    assert g(1) == (4, "v")
    assert g(1, k=10, label="w") == (11, "w")


def test_serde_self_referential_function():
    def fact(n):
        return 1 if n <= 1 else n * fact(n - 1)

    g = serde.loads_fn(serde.dumps_fn(fact))
    assert g(6) == 720


def test_serde_mutually_recursive_functions():
    def is_even(n):
        return True if n == 0 else is_odd(n - 1)

    def is_odd(n):
        return False if n == 0 else is_even(n - 1)

    g = serde.loads_fn(serde.dumps_fn(is_even))
    assert g(10) is True and g(7) is False


def test_serde_self_referential_closure():
    def make():
        def rec(n):
            return 0 if n == 0 else rec(n - 1) + 1
        return rec

    g = serde.loads_fn(serde.dumps_fn(make()))
    assert g(5) == 5


def _module_weight(v):
    # module-level on purpose: the recursive reference is a GLOBAL, and it
    # appears only inside the generator expression's nested code object
    if isinstance(v, (list, tuple)):
        return sum(_module_weight(x) for x in v) + len(v)
    return v


def test_serde_captures_globals_referenced_inside_comprehensions():
    """A global called only from a comprehension/genexpr lives in the
    NESTED code object's co_names; packing must walk nested code or the
    shipped function dies with NameError."""
    g = serde.loads_fn(serde.dumps_fn(_module_weight))
    assert g([1, [2, 3]]) == 1 + (2 + 3 + 2) + 2


def test_serde_recursive_fn_runs_on_executor():
    def weight(n):
        return 1 if n <= 1 else weight(n - 1) + 1

    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    out = dict(ctx.parallelize([(i % 3, i) for i in range(12)], 2)
               .map(lambda kv: (kv[0], weight(kv[1] % 4)))
               .reduceByKey(operator.add, 2).collect())
    assert sum(out.values()) == sum(max(1, i % 4) for i in range(12))


# ------------------------------------------------ oversized-record spill


def test_pack_records_spills_oversized_record():
    store = ObjectStoreSim(CostLedger())

    def spill(blob):
        key = "_spill/test"
        store.put(key, blob)
        return key

    big = ("k", "x" * 400_000)  # single pickle far over 256 KiB
    bodies = pack_records([("a", 1), big, ("b", 2)], spill=spill)
    assert all(len(b) <= 256 * 1024 for b in bodies)
    out = [r for b in bodies for r in unpack_records(b, store)]
    assert out == [("a", 1), big, ("b", 2)]
    # without a store the pointer cannot resolve
    ptr_body = pack_records([big], spill=spill)[0]
    with pytest.raises(ValueError):
        unpack_records(ptr_body)
    assert isinstance(pickle.loads(ptr_body[4:]), SpillPointer)


def test_oversized_record_rides_shuffle_end_to_end():
    """A >256 KiB record used to make every send_batch retry raise
    ValueError — now it spills to the object store and the consumer
    resolves the pointer."""
    big = "x" * 400_000
    # the 256 KiB cap is a QUEUE property — the S3 exchange ships batches
    # this size whole, so pin the transport the spill path exists for
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="sqs"))
    out = dict(ctx.parallelize([("big", big), ("small", "y")] * 2, 2)
               .groupByKey(2).collect())
    assert out["big"] == [big, big]
    assert out["small"] == ["y", "y"]
    # spill actually happened — and the job-end GC reclaimed every key
    assert ctx.last_scheduler.gc_report.get("_spill/", 0) > 0
    assert not ctx.store.list("_spill/")


# ----------------------------------------------- barrier-mode teardown


def test_barrier_stage_failure_closes_sqs_sim():
    """Barrier mode now tears the transport down on StageFailure like the
    pipelined path, so blocked consumers are released immediately instead
    of lingering up to drain_timeout_s in the thread pool."""
    cfg = FlintConfig(concurrency=4, pipeline_stages=False,
                      max_task_retries=0)
    ctx = FlintContext("flint", cfg)
    ctx.upload("text.txt", TEXT)
    rdd = (ctx.textFile("text.txt", 2).flatMap(lambda line: line.split())
           .map(lambda w: (w, 1)).reduceByKey(operator.add, 2))
    plan = build_plan(rdd, "collect")
    sched = FlintScheduler(cfg, ctx.ledger, ctx.store,
                           fault_plan={(0, 0): {"fail_attempts": 99}})
    with pytest.raises(StageFailure):
        sched.run(plan)
    assert sched.sqs.closed
    sched.shutdown()
