"""Multi-tenant service suite (docs/multi_tenant.md): N concurrent
sessions over one FlintService must produce serial-single-tenant
answers on both transports, share producer stages and cache
materializations across tenants, enforce admission and quota limits,
keep the shared cache under its byte cap without evicting pinned
entries, stay correct under seeded account-wide chaos with isolated
per-tenant retry budgets, and leak nothing once every session closes."""

import threading
import time

import pytest

from repro.core import FlintConfig, FlintContext
from repro.core.costs import CostLedger
from repro.core.faults import FaultPlan
from repro.core.scheduler import StageFailure
from repro.svc import (AdmissionController, AdmissionRejected,
                       FairSharePool, FlintService, SharedCache)

BACKENDS = ["sqs", "s3"]

TAXI_ROWS = "\n".join(
    f"2013-01-01 {i % 24:02d}:{i % 60:02d}:00,"
    f"{'credit' if i % 3 else 'cash'},{i % 7},{(i * 7) % 100 / 10}"
    for i in range(600)).encode()


def _cfg(backend, **kw):
    kw = {"concurrency": 8, "visibility_timeout_s": 0.5,
          "drain_timeout_s": 2.0, **kw}
    return FlintConfig(shuffle_backend=backend, **kw)


# module-level row functions: cross-tenant CSE keys on the lineage
# fingerprint, which hashes the SERIALIZED function — sessions must
# submit literally the same derivation, as one client library would
def _split(line):
    return line.split(",")


def _by_hour(row):
    # integer tenths: keyed sums must not depend on float merge order
    return (row[0][11:13], int(float(row[3]) * 10 + 0.5))


def _by_payment(row):
    return (row[1], 1)


def _add(a, b):
    return a + b


def _q_tips_by_hour(sess, nparts=4):
    return sorted(sess.textFile("taxi.csv", nparts).map(_split)
                  .map(_by_hour).reduceByKey(_add, 3).collect())


def _q_count_by_payment(sess, nparts=4):
    return sorted(sess.textFile("taxi.csv", nparts).map(_split)
                  .map(_by_payment).reduceByKey(_add, 2).collect())


def _serial_expected(backend):
    ctx = FlintContext(config=_cfg(backend))
    ctx.upload("taxi.csv", TAXI_ROWS)
    return {"hour": _q_tips_by_hour(ctx), "pay": _q_count_by_payment(ctx)}


def _slow_split(line):
    time.sleep(0.05)
    return line.split(",")


def _q_slow(sess):
    """_q_tips_by_hour with a deliberately slow producer, so a second
    tenant reliably submits while the producer stage is still live."""
    return sorted(sess.textFile("taxi.csv", 4).map(_slow_split)
                  .map(_by_hour).reduceByKey(_add, 3).collect())


# --------------------------------------------------------- concurrency


@pytest.mark.parametrize("backend", BACKENDS)
def test_concurrent_tenants_match_serial(backend):
    """3 tenants x 2 mixed queries at once == serial single-tenant runs;
    afterwards the service closes with zero transient keys left."""
    expected = _serial_expected(backend)
    svc = FlintService(_cfg(backend), slot_capacity=12)
    for name, w in (("a", 2), ("b", 1), ("c", 1)):
        svc.register_tenant(name, weight=w)
    svc.upload("taxi.csv", TAXI_ROWS)

    results, errors = {}, []

    def run(name):
        try:
            with svc.session(name) as s:
                results[name] = {"hour": _q_tips_by_hour(s),
                                 "pay": _q_count_by_payment(s)}
        except Exception as e:  # surfaced after join
            errors.append((name, repr(e)))

    threads = [threading.Thread(target=run, args=(n,)) for n in "abc"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert not errors, errors
    for name in "abc":
        assert results[name] == expected, name
    rep = svc.report()
    # tenant compute is metered per child ledger and sums upward
    for field in ("lambda_requests", "sqs_requests"):
        assert (sum(r[field] for r in rep["tenants"].values())
                == rep["account"][field])
    assert rep["pool"]["peak_held"] <= 12
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values()), \
        svc.leak_report()


def test_cross_tenant_cse_shares_one_producer():
    """Two tenants submitting the same query while it runs: the second
    plans NO producer stage (strictly fewer lambda invocations) and both
    read the same answer; the shared stream is destroyed afterwards."""
    svc = FlintService(_cfg("s3"), slot_capacity=12)
    svc.register_tenant("a")
    svc.register_tenant("b")
    svc.upload("taxi.csv", TAXI_ROWS)
    expected, out = None, {}

    def run_a():
        with svc.session("a") as s:
            out["a"] = _q_slow(s)

    ta = threading.Thread(target=run_a)
    ta.start()
    # wait for tenant a's plan to publish its shuffle, then submit b's
    # identical query while a's slow producer stage is still running
    deadline = time.time() + 5.0
    while svc.share.stats["published"] == 0 and time.time() < deadline:
        time.sleep(0.005)
    assert svc.share.stats["published"] >= 1, "a never published"
    with svc.session("b") as s:
        out["b"] = _q_slow(s)
    ta.join()

    assert out["a"] == out["b"]
    assert svc.share.stats["hits"] >= 1
    assert svc.share.stats["joined_groups"] >= 1
    assert svc.share.stats["destroyed"] == svc.share.stats["published"]
    rep = svc.report()["tenants"]
    # b ran only the consumer stage — strictly fewer invocations than a
    assert rep["b"]["lambda_requests"] < rep["a"]["lambda_requests"]
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values())


def test_sqs_shuffles_never_shared_cross_job():
    """SQS queues are destroyed by consumption, so the registry must
    refuse to share them: two sequential identical SQS queries each run
    their own producer."""
    svc = FlintService(_cfg("sqs"), slot_capacity=8)
    svc.upload("taxi.csv", TAXI_ROWS)
    with svc.session("a") as s:
        r1 = _q_tips_by_hour(s)
    with svc.session("b") as s:
        r2 = _q_tips_by_hour(s)
    assert r1 == r2
    assert svc.share.stats["published"] == 0
    assert svc.share.stats["hits"] == 0
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values())


# ------------------------------------------------------------- caching


def _cached_hours(sess):
    cached = (sess.textFile("taxi.csv", 4).map(_split)
              .map(_by_hour).cache())
    return sorted(cached.reduceByKey(_add, 3).collect())


def test_shared_cache_hits_across_tenants():
    """Tenant a materializes a cache(); tenant b's identical derivation
    plans from the shared materialization — no source rescan."""
    svc = FlintService(_cfg("s3"), slot_capacity=8)
    svc.upload("taxi.csv", TAXI_ROWS)
    with svc.session("a") as s:
        ra = _cached_hours(s)
    assert len(svc.cache) == 1 and svc.cache.total_bytes() > 0
    with svc.session("b") as s:
        # the planner resolves b's identical derivation to a's
        # materialized partitions — no source scan, no map chain
        from repro.core.dag import CacheInput, build_plan
        node = (s.textFile("taxi.csv", 4).map(_split)
                .map(_by_hour).cache().reduceByKey(_add, 3))
        plan = build_plan(node, "collect", cache_index=svc.cache)
        inputs = [t.input for st in plan for t in st.tasks]
        assert any(isinstance(i, CacheInput) for i in inputs)
        rb = _cached_hours(s)
    assert ra == rb
    assert len(svc.cache) == 1  # still ONE shared materialization
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values())


def test_cache_eviction_under_byte_cap_spares_pinned():
    """Unit-level SharedCache contract: commits evict LRU ready entries
    over the cap, pinned entries survive both eviction and drop()."""
    ledger = CostLedger()
    from repro.core.queues import ObjectStoreSim
    store = ObjectStoreSim(ledger)
    cache = SharedCache(store, byte_cap=2500)

    def materialize(token, nbytes):
        cache[token] = {"nparts": 1, "ready": False}
        store.put(f"_cache/{token}/1/p0/b0", b"x" * nbytes)
        cache[token]["ready"] = True
        cache.committed(token)

    materialize("t1", 1000)
    cache.pin("t1")
    materialize("t2", 1000)
    materialize("t3", 1000)  # over cap: t2 (LRU, unpinned) evicted
    assert cache.stats["evictions"] == 1
    assert "t2" not in cache and not store.list("_cache/t2/")
    assert "t1" in cache and store.list("_cache/t1/")  # pinned survivor
    assert cache.total_bytes() <= 2500
    assert cache.drop("t1") == 0          # pinned: refused
    cache.unpin("t1")
    assert cache.drop("t1") > 0           # unpinned: deleted
    assert not store.list("_cache/t1/")
    assert cache.drop_all() > 0           # t3 goes too
    assert len(cache) == 0


def test_service_cache_eviction_end_to_end():
    """A byte cap smaller than two materializations: caching a second
    dataset evicts the first, and re-running the first query still
    answers correctly by re-materializing."""
    svc = FlintService(_cfg("s3"), slot_capacity=8, cache_bytes=1)
    svc.upload("taxi.csv", TAXI_ROWS)
    with svc.session("a") as s:
        r1 = _cached_hours(s)
        assert svc.cache.stats["evictions"] >= 1  # cap is tiny
        assert _cached_hours(s) == r1  # re-materializes, same answer
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values())


# ------------------------------------------------- admission and quotas


def test_admission_rejects_at_capacity():
    ac = AdmissionController(max_running=2, max_queued=1)
    ac.admit("t1")
    ac.admit("t2")
    queued_in = threading.Event()
    admitted = threading.Event()

    def queue_third():
        queued_in.set()
        ac.admit("t3")
        admitted.set()

    t = threading.Thread(target=queue_third)
    t.start()
    queued_in.wait(2.0)
    deadline = time.time() + 2.0
    while ac.queued == 0 and time.time() < deadline:
        time.sleep(0.002)
    with pytest.raises(AdmissionRejected) as ei:
        ac.admit("t4")  # 2 running + 1 queued: over both limits
    assert ei.value.reason == "capacity" and ei.value.tenant == "t4"
    ac.release()
    assert admitted.wait(2.0)
    t.join()
    assert ac.stats["rejected_capacity"] == 1
    assert ac.stats["peak_running"] == 2 and ac.stats["peak_queued"] == 1


def test_quota_rejection_and_mid_job_enforcement():
    """A tenant over its dollar budget is refused at the gate; a tenant
    that crosses the budget while running is stopped mid-job with a
    structured, non-retryable failure. Other tenants are unaffected."""
    svc = FlintService(_cfg("s3"), slot_capacity=8)
    svc.register_tenant("broke", max_usd=1e-9)
    svc.register_tenant("rich")
    svc.upload("taxi.csv", TAXI_ROWS)
    with svc.session("broke") as s:
        # budget > 0 spent of 1e-9: first admit passes, the mid-job
        # guard halts the run after the first billed launches
        with pytest.raises(StageFailure) as ei:
            _q_tips_by_hour(s)
        assert ei.value.error_type == "TenantQuotaExceeded"
        assert not ei.value.retryable
        with pytest.raises(AdmissionRejected) as ei:  # now gated
            _q_tips_by_hour(s)
        assert ei.value.reason == "quota"
    with svc.session("rich") as s:
        assert _q_tips_by_hour(s)  # unaffected by the neighbor's quota
    assert svc.report()["admission"]["rejected_quota"] == 1
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values())


def test_fair_share_respects_weights():
    """Deterministic max-min check: capacity 4 split between weight-3
    and weight-1 tenants lands on 3/1 no matter the acquisition order."""
    pool = FairSharePool(4)
    pool.set_weight("a", 3)
    pool.set_weight("b", 1)
    la, lb = pool.lease("a"), pool.lease("b")
    la.set_demand(4)
    lb.set_demand(4)
    for _ in range(8):  # greedy alternation, b first
        lb.try_acquire()
        la.try_acquire()
    assert pool.held("a") == 3 and pool.held("b") == 1
    assert pool.held() == 4
    # releases rebalance: a gives one back, b still can't exceed its
    # share while a has unmet demand
    la.release()
    assert lb.try_acquire() is False
    assert la.try_acquire() is True
    la.detach()
    lb.detach()
    assert pool.held() == 0


def test_fair_share_pool_stress():
    """Hammer one pool from many leases: capacity is never exceeded and
    every slot comes back after detach."""
    pool = FairSharePool(6)
    stop = threading.Event()

    def worker(tenant):
        ls = pool.lease(tenant)
        ls.set_demand(3)
        while not stop.is_set():
            if ls.try_acquire():
                time.sleep(0.0005)
                ls.release()
        ls.detach()

    threads = [threading.Thread(target=worker, args=(f"t{i % 3}",))
               for i in range(9)]
    for t in threads:
        t.start()
    time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join()
    assert pool.peak_held <= 6
    assert pool.held() == 0
    assert pool.grants > 0


# ----------------------------------------------------------------- chaos


def test_service_chaos_serial_equal_and_zero_leaks():
    """Seeded account-wide chaos (shared store 5xx + lost objects, per-
    scheduler SQS/Lambda faults, shared account concurrency cap): two
    concurrent tenants still produce fault-free answers, the shared
    gauge sees the real account-wide peak, and nothing leaks."""
    expected = _serial_expected("s3")
    plan = FaultPlan(seed=int(__import__("os").environ.get(
        "FLINT_CHAOS_SEED", "20260808")),
        s3_error_prob=0.02, sqs_error_prob=0.02,
        invoke_throttle_prob=0.02, lose_object_prob=0.01,
        account_concurrency=6)
    svc = FlintService(_cfg("s3", max_stage_retries=5, retry_base_s=0.001,
                            retry_cap_s=0.01),
                       fault_plan=plan, slot_capacity=10)
    svc.register_tenant("a", retry_budget=400)
    svc.register_tenant("b", retry_budget=400)
    svc.upload("taxi.csv", TAXI_ROWS)
    results, errors = {}, []

    def run(name):
        try:
            with svc.session(name) as s:
                results[name] = {"hour": _q_tips_by_hour(s),
                                 "pay": _q_count_by_payment(s)}
        except Exception as e:
            errors.append((name, repr(e)))

    threads = [threading.Thread(target=run, args=(n,)) for n in "ab"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert results["a"] == expected and results["b"] == expected
    assert svc.gauge.peak <= 10  # slots bound the account in-flight peak
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values()), \
        svc.leak_report()


def test_retry_budgets_are_isolated_per_tenant():
    """Chaos retries spend only the retrying tenant's budget: after a
    runs under heavy store faults, b's untouched budget is still full."""
    plan = FaultPlan(seed=7, s3_error_prob=0.15)
    svc = FlintService(_cfg("s3", retry_base_s=0.001, retry_cap_s=0.01),
                       fault_plan=plan, slot_capacity=8)
    svc.register_tenant("a", retry_budget=500)
    svc.register_tenant("b", retry_budget=500)
    svc.upload("taxi.csv", TAXI_ROWS)
    with svc.session("a") as s:
        _q_tips_by_hour(s)
    ta = svc._tenants["a"].retry_budget
    tb = svc._tenants["b"].retry_budget
    assert ta.spent > 0      # the chaos made a retry at least once
    assert tb.spent == 0     # none of it billed to the idle tenant
    svc.close()


# -------------------------------------------- shared-state thread safety


def test_cost_ledger_children_sum_to_parent_under_contention():
    root = CostLedger()
    kids = [root.child() for _ in range(4)]

    def bill(ledger):
        for _ in range(300):
            ledger.add_lambda(0.05, 1024)
            ledger.add_s3(100)
            ledger.add_s3_put(50)
            ledger.add_sqs(64)

    threads = [threading.Thread(target=bill, args=(k,)) for k in kids]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for field in ("lambda_requests", "s3_gets", "s3_puts", "sqs_requests",
                  "bytes_to_s3", "bytes_from_s3"):
        assert getattr(root, field) == sum(getattr(k, field) for k in kids)
    assert root.report()["lambda_requests"] == 1200


def test_shared_cache_concurrent_mutation_stays_consistent():
    """Concurrent register/commit/read/drop/pin from many threads: no
    exceptions, byte accounting never goes negative, and a full drain
    leaves the cache and the store empty."""
    from repro.core.queues import ObjectStoreSim
    store = ObjectStoreSim(CostLedger())
    cache = SharedCache(store, byte_cap=5000)
    errors = []

    def churn(i):
        try:
            for j in range(40):
                token = f"t{i}-{j % 5}"
                cache[token] = {"nparts": 1, "ready": False}
                store.put(f"_cache/{token}/1/p0/b0", b"y" * 100)
                cache[token]["ready"] = True
                cache.committed(token)
                cache.pin(token)
                _ = cache.total_bytes()
                _ = list(cache.items())
                cache.unpin(token)
                cache.drop(token)
        except Exception as e:
            errors.append(repr(e))

    threads = [threading.Thread(target=churn, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    cache.drop_all()
    assert len(cache) == 0 and cache.total_bytes() == 0
    assert not store.list("_cache/")
