"""Unit coverage for the chaos subsystem's building blocks: the retry
layer's taxonomy/backoff/budget (core.retry), the FaultPlan schema and the
seeded FaultInjector's reproducibility (core.faults), and the resilience-
knob validation at FlintConfig/scheduler construction."""

import pytest

from repro.core import FaultInjector, FaultPlan, FlintConfig, FlintScheduler
from repro.core.costs import CostLedger
from repro.core.queues import ObjectStoreSim
from repro.core.retry import (RetryBudget, RetryBudgetExhausted,
                              RetryExhausted, RetryPolicy, RetryingStore,
                              ThrottledError, TransientServiceError,
                              is_retryable)


# ------------------------------------------------------------ retry layer


def fast_policy(**kw):
    kw.setdefault("max_attempts", 4)
    kw.setdefault("base_s", 0.0001)
    kw.setdefault("cap_s", 0.001)
    return RetryPolicy(**kw)


def test_taxonomy_retryable_vs_fatal():
    assert is_retryable(TransientServiceError("x"))
    assert is_retryable(ThrottledError("x"))
    assert not is_retryable(KeyError("missing"))  # missing != flaky
    assert not is_retryable(RetryExhausted("x"))
    assert not is_retryable(RetryBudgetExhausted("x"))


def test_backoff_sleep_stays_within_bounds():
    pol = RetryPolicy(max_attempts=3, base_s=0.01, cap_s=0.05)
    prev = pol.base_s
    for _ in range(200):
        prev = pol.next_sleep(prev)
        assert 0.01 <= prev <= 0.05


def test_call_retries_transient_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientServiceError("503")
        return "ok"

    assert fast_policy().call(flaky) == "ok"
    assert calls["n"] == 3


def test_call_raises_retry_exhausted_with_cause():
    def always():
        raise TransientServiceError("503 forever")

    with pytest.raises(RetryExhausted) as exc:
        fast_policy(max_attempts=3).call(always)
    assert isinstance(exc.value.cause, TransientServiceError)


def test_call_passes_fatal_errors_through_untouched():
    def missing():
        raise KeyError("nope")

    calls = {"n": 0}

    def count_then_missing():
        calls["n"] += 1
        raise KeyError("nope")

    with pytest.raises(KeyError):
        fast_policy().call(missing)
    with pytest.raises(KeyError):
        fast_policy().call(count_then_missing)
    assert calls["n"] == 1  # no retry burned on a fatal error


def test_budget_is_shared_and_exhausts():
    budget = RetryBudget(3)
    pol_a = fast_policy(max_attempts=10, budget=budget)
    pol_b = fast_policy(max_attempts=10, budget=budget)

    def always():
        raise TransientServiceError("503")

    # first policy burns 2 retries, second's first retry spends the last
    with pytest.raises(RetryBudgetExhausted):
        pol_a.call(always)
    assert budget.remaining == 0
    with pytest.raises(RetryBudgetExhausted):
        pol_b.call(always)


def test_budget_validation():
    with pytest.raises(ValueError):
        RetryBudget(0)


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=0.1, cap_s=0.05)


def test_retrying_store_roundtrip_through_transients():
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    plan = FaultPlan(seed=11, s3_error_prob=0.4)
    store.faults = FaultInjector(plan, ledger)
    rstore = RetryingStore(store, fast_policy(max_attempts=50))
    for i in range(30):
        rstore.put(f"k/{i}", b"v%d" % i)
    for i in range(30):
        assert rstore.get(f"k/{i}") == b"v%d" % i
    assert len(rstore.list("k/")) == 30
    assert ledger.service_faults > 0  # some 503s actually fired


# ------------------------------------------------------- FaultPlan schema


def test_fault_plan_validates_probabilities():
    with pytest.raises(ValueError):
        FaultPlan(s3_error_prob=1.5)
    with pytest.raises(ValueError):
        FaultPlan(lose_object_prob=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(account_concurrency=-1)
    with pytest.raises(ValueError):
        FaultPlan(sqs_delay_s=-0.5)


def test_fault_plan_validates_task_faults():
    with pytest.raises(ValueError):
        FaultPlan(tasks={"0-0": {"fail_attempts": 1}})  # not a tuple key
    with pytest.raises(ValueError):
        FaultPlan(tasks={(0, 0): {"explode": True}})  # unknown fault key
    FaultPlan(tasks={(0, 0): {"fail_attempts": 2, "straggle_s": 0.1}})


def test_fault_plan_coerce_legacy_dict_and_none():
    legacy = {(0, 1): {"fail_attempts": 3}}
    plan = FaultPlan.coerce(legacy)
    assert plan.tasks == legacy and not plan.has_service_faults
    assert FaultPlan.coerce(None).empty
    existing = FaultPlan(seed=9)
    assert FaultPlan.coerce(existing) is existing
    with pytest.raises(TypeError):
        FaultPlan.coerce("chaos")


def test_fault_plan_service_fault_detection():
    assert not FaultPlan(tasks={(0, 0): {"fail_attempts": 1}}
                         ).has_service_faults
    assert FaultPlan(sqs_error_prob=0.1).has_service_faults
    assert FaultPlan(account_concurrency=4).has_service_faults
    assert FaultPlan(lose_keys=("_exchange/",)).has_service_faults
    assert FaultPlan().empty


# --------------------------------------------------- injector determinism


def _schedule(seed, calls=100):
    inj = FaultInjector(FaultPlan(seed=seed, s3_error_prob=0.3))
    out = []
    for i in range(calls):
        try:
            inj.s3_call("put", f"key/{i % 7}")
            out.append(False)
        except TransientServiceError:
            out.append(True)
    return out


def test_injector_same_seed_same_schedule():
    assert _schedule(42) == _schedule(42)
    sched = _schedule(42)
    assert any(sched) and not all(sched)  # an actual mix at p=0.3


def test_injector_decisions_keyed_per_signature_not_global_order():
    """Interleaving calls to other signatures must not shift a given
    signature's decision sequence — that is what makes fixed-seed chaos
    schedules reproducible under thread racing."""
    plan = FaultPlan(seed=7, s3_error_prob=0.5)
    a = FaultInjector(plan)
    b = FaultInjector(plan)

    def probe(inj, key):
        try:
            inj.s3_call("get", key)
            return False
        except TransientServiceError:
            return True

    seq_a = [probe(a, "target") for _ in range(20)]
    seq_b = []
    for _ in range(20):
        probe(b, "noise-1")
        seq_b.append(probe(b, "target"))
        probe(b, "noise-2")
    assert seq_a == seq_b


def test_lose_keys_fires_once_lose_keys_every_always():
    inj = FaultInjector(FaultPlan(lose_keys=("once/",),
                                  lose_keys_every=("forever/",)))
    assert inj.object_written("once/a") is True
    assert inj.object_written("once/b") is False  # one-shot
    assert inj.object_written("forever/a") is True
    assert inj.object_written("forever/b") is True
    assert inj.stats["lost_objects"] == 3


def test_lost_objects_respect_prefixes_and_spare_tombstones():
    inj = FaultInjector(FaultPlan(seed=1, lose_object_prob=1.0))
    assert inj.object_written("_exchange/0/p0/s0t0-00000000-ab") is True
    assert inj.object_written("_cache/tok/2/p0/000000-cd") is True
    assert inj.object_written("_result/123") is False  # not a lose prefix
    # release tombstones are markers, not data — never lost
    assert inj.object_written("_exchange/0/p0/.released-g0") is False


def test_concurrency_cap_throttles_deterministically():
    inj = FaultInjector(FaultPlan(account_concurrency=2))
    assert inj.invoke_fault(0, 0, 0, inflight=2) is None
    assert inj.invoke_fault(0, 1, 0, inflight=3) == "throttle"
    assert inj.stats["throttles"] == 1


def test_timeout_after_targets_first_attempt_only():
    inj = FaultInjector(FaultPlan(
        tasks={(1, 2): {"timeout_after_records": 55}}))
    assert inj.timeout_after(1, 2, 0) == 55
    assert inj.timeout_after(1, 2, 1) is None  # the retry must finish
    assert inj.timeout_after(0, 0, 0) is None
    probabilistic = FaultInjector(FaultPlan(seed=3, invoke_timeout_prob=1.0))
    t = probabilistic.timeout_after(0, 0, 0)
    assert t is not None and t >= 20
    assert t == FaultInjector(FaultPlan(seed=3, invoke_timeout_prob=1.0)
                              ).timeout_after(0, 0, 0)  # seeded


def test_injector_counts_service_faults_in_ledger():
    ledger = CostLedger()
    inj = FaultInjector(FaultPlan(seed=0, sqs_error_prob=1.0), ledger)
    with pytest.raises(TransientServiceError):
        inj.sqs_call("send", "q")
    rep = ledger.report()
    assert rep["service_faults"] == 1
    assert "lambda_throttles" in rep


# --------------------------------------- resilience-knob validation (cfg)


@pytest.mark.parametrize("bad", [
    {"retry_budget": 0},
    {"retry_max_attempts": 0},
    {"retry_base_s": 0.0},
    {"retry_base_s": 0.2, "retry_cap_s": 0.1},
    {"dispatch_backoff_base_s": 0.0},
    {"dispatch_backoff_base_s": 2.0, "dispatch_backoff_cap_s": 1.0},
    {"max_stage_retries": -1},
    # drain deadline must fire before the invocation lease does
    {"drain_timeout_s": 400.0, "time_limit_s": 300.0},
])
def test_config_validate_rejects_incoherent_knobs(bad):
    cfg = FlintConfig(**bad)
    with pytest.raises(ValueError):
        cfg.validate()
    # the scheduler constructor enforces the same gate
    with pytest.raises(ValueError):
        FlintScheduler(cfg)


def test_config_validate_accepts_defaults():
    FlintConfig().validate()


def test_scheduler_rejects_unknown_fault_plan_type():
    with pytest.raises(TypeError):
        FlintScheduler(FlintConfig(), fault_plan="chaos")
