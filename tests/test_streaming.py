"""Streaming engine suite (docs/streaming.md): windowed streaming
queries over replayed event streams must produce finalized results
IDENTICAL to the equivalent batch query — including after a mid-stream
driver kill/resume from checkpoint and under seeded chaos — on both
shuffle transports, with zero leaked queues or objects. Plus unit
coverage for the window/watermark state machine, the source contract,
late-data accounting, the per-window transport cost model, and the
service integration (long-running admission + between-batch quota)."""

import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FaultPlan, FlintConfig, FlintContext
from repro.core.scheduler import StageFailure
from repro.sql.dataframe import DataFrame
from repro.sql.expr import (Schema, avg_, col, collect_list, count_, lit,
                            sum_)
from repro.sql.plan import Window
from repro.streaming import (PANE_COL, EventGenerator, S3PrefixTailer,
                             WindowSpec, WindowState, read_stream)
from repro.svc import FlintService

CHAOS_SEED = int(os.environ.get("FLINT_CHAOS_SEED", "0"))

#: every transient prefix that must be empty after queries clean up —
#: streaming checkpoints included once the query's cleanup() ran
TRANSIENT_PREFIXES = ("_exchange/", "_spill/", "_payload/", "_result/",
                      "_broadcast/", "_stream/")

BACKENDS = ["sqs", "s3"]


def _cfg(backend="sqs", **kw):
    kw.setdefault("concurrency", 4)
    kw.setdefault("visibility_timeout_s", 0.5)
    kw.setdefault("drain_timeout_s", 1.5)
    return FlintConfig(shuffle_backend=backend, **kw)


def assert_no_leaks(ctx):
    leaked = [k for p in TRANSIENT_PREFIXES for k in ctx.store.list(p)]
    assert not leaked, f"leaked transient objects: {leaked[:5]}"
    sched = ctx.last_scheduler
    if sched is not None:
        assert sched.sqs._queues == {}, "leaked queues"


def py_reference(events, size, slide=None, pred=None):
    """Driver-independent reference: sum/count per (window, key),
    computed row-at-a-time in plain Python."""
    slide = size if slide is None else slide
    out = {}
    for ts, key, val in events:
        if pred is not None and not pred(ts, key, val):
            continue
        pane = ts - ts % slide
        for ws in range(pane - size + slide, pane + 1, slide):
            cur = out.setdefault((ws, key), [0, 0])
            cur[0] += val
            cur[1] += 1
    return sorted((ws, ws + size, k, t, n)
                  for (ws, k), (t, n) in out.items())


def _sum_count_stream(ctx, src, size, slide=None, **start_kw):
    start_kw.setdefault("allowed_lateness", src.max_delay)
    return (read_stream(ctx, src)
            .window("ts", size, slide)
            .groupBy("key")
            .agg(sum_(col("val")).alias("total"), count_().alias("n"))
            .start(start_kw.pop("name", "q"), **start_kw))


# ------------------------------------------------ window state machine


def test_window_spec_validation():
    with pytest.raises(ValueError):
        WindowSpec("ts", 0)
    with pytest.raises(ValueError):
        WindowSpec("ts", 10, -2)
    with pytest.raises(ValueError):
        WindowSpec("ts", 10, 3)  # size not a multiple of slide
    assert list(WindowSpec("ts", 30, 10).windows_of(60)) == [40, 50, 60]
    assert list(WindowSpec("ts", 10).windows_of(20)) == [20]


def _tumbling_state(size=10, lateness=0):
    import operator
    return WindowState(WindowSpec("ts", size), [operator.add],
                       lambda slots: [slots[0]], lateness)


def test_window_state_watermark_closes_left_to_right():
    st_ = _tumbling_state()
    st_.merge(0, ("a",), [5], 1)
    st_.merge(10, ("a",), [7], 2)
    st_.merge(10, ("b",), [1], 1)
    assert st_.advance(9.0) == []           # window [0,10) not yet past
    assert st_.advance(10.0) == [(0, 10, "a", 5)]
    assert st_.frontier == 10
    # later watermarks close later windows, keys in sorted order
    assert st_.advance(25.0) == [(10, 20, "a", 7), (10, 20, "b", 1)]
    # watermarks never regress
    st_.advance(3.0)
    assert st_.watermark == 25.0


def test_window_state_sliding_recombines_panes():
    import operator
    st_ = WindowState(WindowSpec("ts", 20, 10), [operator.add],
                      lambda s: [s[0]])
    st_.merge(0, ("k",), [1], 1)
    st_.merge(10, ("k",), [2], 1)
    st_.merge(20, ("k",), [4], 1)
    out = st_.advance(float("inf"))
    # windows [-10,10) [0,20) [10,30) [20,40): pane sums recombine
    assert out == [(-10, 10, "k", 1), (0, 20, "k", 3),
                   (10, 30, "k", 6), (20, 40, "k", 4)]


def test_window_state_allowed_lateness_updates_then_drops():
    st_ = _tumbling_state(lateness=5)
    st_.merge(0, ("a",), [1], 1)
    assert st_.advance(12.0) == []          # held open for late updates
    assert st_.merge(0, ("a",), [9], 1)     # late UPDATE lands
    assert st_.advance(15.0) == [(0, 10, "a", 10)]
    assert not st_.merge(0, ("a",), [3], 2)  # after close: drop + count
    assert st_.late_dropped == 2


def test_window_state_snapshot_restore_roundtrip():
    st_ = _tumbling_state(lateness=2)
    st_.merge(0, ("a",), [1], 1)
    st_.merge(10, ("b",), [2], 1)
    st_.advance(13.0)
    snap = st_.snapshot()
    st2 = _tumbling_state(lateness=2)
    st2.restore(snap)
    assert st2.advance(None) == st_.advance(None)
    assert st2.advance(float("inf")) == st_.advance(float("inf"))


@settings(max_examples=25, deadline=None)
@given(size_panes=st.integers(1, 3), seed=st.integers(0, 10 ** 6),
       lateness=st.sampled_from([0, 5, 100]))
def test_window_state_property_vs_bruteforce(size_panes, seed, lateness):
    """Any in-order watermark schedule with lateness covering the
    disorder emits exactly the brute-force window sums."""
    import operator
    import random
    rng = random.Random(seed)
    slide = 10
    size = slide * size_panes
    events = [(rng.randrange(60), rng.choice("ab"), rng.randrange(100))
              for _ in range(rng.randrange(1, 60))]
    st_ = WindowState(WindowSpec("ts", size, slide), [operator.add],
                      lambda s: [s[0]], lateness)
    out = []
    for i in range(0, len(events), 7):
        chunk = events[i:i + 7]
        for ts, k, v in chunk:
            pane = ts - ts % slide
            st_.merge(pane, (k,), [v], 1)
        out.extend(st_.advance(max(ts for ts, _, _ in chunk)))
    out.extend(st_.advance(float("inf")))
    if lateness >= 60:  # nothing can drop: exact equality
        assert sorted(out) == [(ws, we, k, t) for ws, we, k, t, _n in
                               py_reference(events, size, slide)]
        assert st_.late_dropped == 0
    # whatever closed is final: no window may appear twice
    assert len({(r[0], r[2]) for r in out}) == len(out)


# ------------------------------------------------ Window plan node


def test_window_plan_node_in_batch_dataframe():
    ctx = FlintContext("flint", _cfg())
    rows = [(3, "a", 1), (17, "b", 2), (25, "a", 3)]
    df = (DataFrame.from_rdd(ctx.parallelize(rows, 2),
                             EventGenerator.schema)
          .withWindow("ts", 10))
    assert df.schema.names[-1] == "window_start"
    got = sorted(df.collect())
    assert got == [(3, "a", 1, 0), (17, "b", 2, 10), (25, "a", 3, 20)]
    assert "Window[" in df.explain()
    # the optimizer pushes filters BELOW the pane projection but must
    # keep the Window node itself intact (explain still shows it)
    assert "Window[" in df.where(col("key") == lit("a")).explain()
    with pytest.raises(ValueError):
        df.withWindow("ts", 10, 3)  # size % slide != 0
    with pytest.raises(TypeError):
        DataFrame.from_rdd(ctx.parallelize(rows, 2),
                           Schema([("ts", "str"), ("key", "str"),
                                   ("val", "int")])).withWindow("ts", 10)
    assert_no_leaks(ctx)


def test_window_node_survives_optimizer():
    from repro.sql.optimizer import optimize
    ctx = FlintContext("flint", _cfg())
    rows = [(i, "k", i) for i in range(20)]
    df = (DataFrame.from_rdd(ctx.parallelize(rows, 2),
                             EventGenerator.schema)
          .withWindow("ts", 10)
          .where(col("val") >= lit(5)))
    plan = optimize(df.plan, ctx)

    def find_window(node):
        if isinstance(node, Window):
            return node
        for c in node.children():
            w = find_window(c)
            if w is not None:
                return w
        return None
    assert find_window(plan) is not None
    assert sorted(df.collect()) == [(i, "k", i, i - i % 10)
                                    for i in range(5, 20)]


# ------------------------------------------------ stream == batch


@pytest.mark.parametrize("backend", BACKENDS)
def test_tumbling_stream_matches_batch(backend):
    ctx = FlintContext("flint", _cfg(backend))
    src = EventGenerator(seed=11, total=400, rate=10, late_prob=0.3,
                         max_delay=4)
    q = _sum_count_stream(ctx, src, size=10, transport=backend,
                          batch_size=130)
    got = q.run()
    assert got == py_reference(src.read(0, 400), 10)
    assert q.late_dropped == 0
    assert q.stats()["transports"] == [backend] * 4
    q.cleanup()
    assert_no_leaks(ctx)


def test_sliding_stream_matches_batch():
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=5, total=300, rate=10, max_delay=3)
    q = _sum_count_stream(ctx, src, size=30, slide=10, batch_size=90,
                          name="slide")
    assert q.run() == py_reference(src.read(0, 300), 30, 10)
    q.cleanup()
    assert_no_leaks(ctx)


def test_transform_ops_and_avg_match_batch():
    """where/withColumn/select compose ahead of the window; avg
    decomposes into sum+count slots that merge across batches."""
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=2, total=300, rate=10, max_delay=2)
    q = (read_stream(ctx, src)
         .where(col("val") >= lit(100))
         .withColumn("v2", col("val") * lit(2))
         .select("ts", "key", col("v2").alias("val"))
         .window("ts", 20)
         .groupBy("key")
         .agg(sum_(col("val")).alias("t"), count_().alias("n"),
              avg_(col("val")).alias("m"))
         .start("ops", allowed_lateness=2, batch_size=100))
    got = q.run()
    ref = py_reference(src.read(0, 300), 20,
                       pred=lambda ts, k, v: v >= 100)
    assert got == [(ws, we, k, 2 * t, n, 2 * t / n)
                   for ws, we, k, t, n in ref]
    q.cleanup()
    assert_no_leaks(ctx)


def test_stream_static_join():
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=9, total=200, rate=10, n_keys=3,
                         max_delay=2)
    dims = DataFrame.from_rdd(
        ctx.parallelize([("k0", 10), ("k1", 100), ("k2", 1000)], 2),
        Schema([("key", "str"), ("mult", "int")]))
    q = (read_stream(ctx, src)
         .join(dims, on="key")
         .withColumn("val", col("val") * col("mult"))
         .window("ts", 20)
         .groupBy("key")
         .agg(sum_(col("val")).alias("t"), count_().alias("n"))
         .start("join", allowed_lateness=2, batch_size=80))
    mult = {"k0": 10, "k1": 100, "k2": 1000}
    ref = py_reference([(ts, k, v * mult[k])
                        for ts, k, v in src.read(0, 200)], 20)
    assert q.run() == ref
    q.cleanup()
    assert_no_leaks(ctx)


def test_stream_static_join_rejects_static_preserving_shapes():
    ctx = FlintContext("flint", _cfg())
    src = EventGenerator(seed=1, total=10)
    dims = DataFrame.from_rdd(ctx.parallelize([("k0", 1)], 1),
                              Schema([("key", "str"), ("mult", "int")]))
    for how in ("right", "outer"):
        with pytest.raises(ValueError, match="stream-static"):
            read_stream(ctx, src).join(dims, on="key", how=how)


def test_late_data_dropped_and_counted():
    """With zero allowed lateness a bursty out-of-order stream drops
    SOME contributions (counted), and every emitted window is final."""
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=4, total=300, rate=10, late_prob=0.6,
                         max_delay=8)
    q = _sum_count_stream(ctx, src, size=10, allowed_lateness=0,
                          batch_size=60, name="late")
    got = q.run()
    assert q.late_dropped > 0
    assert len({(r[0], r[2]) for r in got}) == len(got)  # finalized once
    # drops only ever SHRINK a window's sum/count vs the reference
    ref = {(r[0], r[2]): r[3:] for r in py_reference(src.read(0, 300), 10)}
    for ws, _we, k, t, n in got:
        rt, rn = ref[(ws, k)]
        assert t <= rt and n <= rn
    q.cleanup()
    assert_no_leaks(ctx)


def test_collect_list_and_misuse_rejected():
    ctx = FlintContext("flint", _cfg())
    src = EventGenerator(seed=0, total=10)
    ws = read_stream(ctx, src).window("ts", 10).groupBy("key")
    with pytest.raises(ValueError, match="collect_list"):
        ws.agg(collect_list(col("val")).alias("vs"))
    with pytest.raises(ValueError, match="at least one aggregate"):
        ws.agg()
    with pytest.raises(TypeError):
        ws.agg(col("val"))
    with pytest.raises(ValueError, match="reserved"):
        (read_stream(ctx, src).withColumn(PANE_COL, lit(1))
         .window("ts", 10))
    with pytest.raises(ValueError, match="batch_size"):
        _sum_count_stream(ctx, src, size=10, batch_size=0)
    with pytest.raises(ValueError, match="for_each_batch"):
        read_stream(ctx, src).for_each_batch(lambda b, r: None)


# ------------------------------------------------ exactly-once recovery


def _resumable(ctx, name, **kw):
    src = EventGenerator(seed=3, total=400, rate=10, late_prob=0.2,
                         max_delay=3)
    return _sum_count_stream(ctx, src, size=10, batch_size=120,
                             name=name, **kw)


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_resume_is_exactly_once(backend):
    ctx = FlintContext("flint", _cfg(backend))
    expected = _resumable(ctx, "ref").run()
    q1 = _resumable(ctx, "crash")
    q1.step()
    q1.step()
    # driver dies here; a fresh driver under the same name resumes from
    # the checkpoint: already-consumed offsets are NOT re-read, emitted
    # windows are NOT re-finalized
    q2 = _resumable(ctx, "crash")
    assert q2.batch == 2 and q2.offset == 240
    assert q2.run() == expected
    q1.cleanup()
    q2.cleanup()
    _resumable(ctx, "ref").cleanup()
    assert_no_leaks(ctx)


def test_lost_latest_checkpoint_falls_back_to_previous():
    """An acknowledged-but-lost checkpoint write must not lose data: the
    resumed driver falls back to the prior checkpoint and the replayable
    source re-reads the lost batch."""
    ctx = FlintContext("flint", _cfg("sqs"))
    expected = _resumable(ctx, "ref2").run()
    q1 = _resumable(ctx, "lost")
    for _ in range(3):
        q1.step()
    assert ctx.store.list("_stream/lost/ckpt/") == [
        "_stream/lost/ckpt/00000002", "_stream/lost/ckpt/00000003"]
    ctx.store.delete("_stream/lost/ckpt/00000003")
    q2 = _resumable(ctx, "lost")
    assert q2.batch == 2  # fell back one batch
    assert q2.run() == expected
    q1.cleanup()
    q2.cleanup()
    _resumable(ctx, "ref2").cleanup()
    assert_no_leaks(ctx)


def test_checkpoint_retention_and_cleanup():
    ctx = FlintContext("flint", _cfg("sqs"))
    q = _resumable(ctx, "ret")
    q.run()
    ckpts = ctx.store.list("_stream/ret/ckpt/")
    assert len(ckpts) == 2  # last two retained, older ones deleted
    assert q.cleanup() == 2
    assert ctx.store.list("_stream/") == []
    with pytest.raises(RuntimeError, match="stopped"):
        q.step()
    assert_no_leaks(ctx)


def test_checkpointing_disabled_runs_fresh():
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=6, total=100, rate=10, max_delay=2)
    q = _sum_count_stream(ctx, src, size=10, batch_size=50,
                          checkpoint=False, name="nock")
    assert q.run() == py_reference(src.read(0, 100), 10)
    assert ctx.store.list("_stream/") == []
    q.cleanup()
    assert_no_leaks(ctx)


def test_sink_prefix_is_idempotent_across_resume():
    ctx = FlintContext("flint", _cfg("sqs"))
    q1 = _resumable(ctx, "sink", sink_prefix="out/sink")
    q1.step()
    q1.step()
    q2 = _resumable(ctx, "sink", sink_prefix="out/sink")
    expected = q2.run()
    per_window = {}
    for key in ctx.store.list("out/sink/"):
        for row in ctx.store.get_obj(key):
            per_window.setdefault(key, []).append(row)
    flat = sorted(r for rows in per_window.values() for r in rows)
    assert flat == sorted(expected)  # replay overwrote, never duplicated
    q1.cleanup()
    q2.cleanup()
    ctx.store.delete_prefix("out/")
    assert_no_leaks(ctx)


def test_for_each_batch_sees_every_finalized_row():
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=8, total=200, rate=10, max_delay=2)
    seen = []
    q = _sum_count_stream(ctx, src, size=10, batch_size=60, name="feb",
                          for_each_batch=lambda b, rows:
                          seen.append((b, rows)))
    got = q.run()
    assert [r for _, rows in seen for r in rows] == got
    assert [b for b, _ in seen] == sorted({b for b, _ in seen})
    q.cleanup()
    assert_no_leaks(ctx)


# ------------------------------------------------ transport cost model


def test_transport_choice_follows_observed_volume():
    """Quiet windows ride SQS; a multi-MB burst flips the EWMA to S3 and
    sustained quiet flips it back — per-batch, from the one cost model
    (core.costs.pick_shuffle_transport)."""
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=0, total=100)
    q = _sum_count_stream(ctx, src, size=10, name="vol")
    assert q._choose_transport(100) == "sqs"
    assert q._choose_transport(2_000_000) == "s3"
    while q._choose_transport(100) == "s3":
        pass  # EWMA decays back
    assert q.transports[0] == "sqs" and "s3" in q.transports \
        and q.transports[-1] == "sqs"
    q.stop()
    ctx.store.delete_prefix("_stream/")


def test_pinned_transport_never_consults_cost_model():
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=0, total=100)
    q = _sum_count_stream(ctx, src, size=10, transport="s3", name="pin")
    assert q._choose_transport(1) == "s3"
    assert q._volume is None
    q.stop()
    ctx.store.delete_prefix("_stream/")


# ------------------------------------------------ S3 prefix tailer


TAIL_SCHEMA = Schema([("ts", "int"), ("key", "str"), ("val", "int")])


def _csv(rows):
    return "\n".join(f"{t},{k},{v}" for t, k, v in rows).encode()


def test_s3_prefix_tailer_stream_matches_batch():
    ctx = FlintContext("flint", _cfg("sqs"))
    chunks = [[(i, f"k{i % 3}", i * 7) for i in range(c * 20, c * 20 + 20)]
              for c in range(4)]
    for c, rows in enumerate(chunks[:2]):
        ctx.store.put(f"events/{c:04d}.csv", _csv(rows))
    src = S3PrefixTailer(ctx.store, "events/", TAIL_SCHEMA)
    q = (read_stream(ctx, src)
         .window("ts", 10)
         .groupBy("key")
         .agg(sum_(col("val")).alias("t"), count_().alias("n"))
         .start("tail", batch_size=1))  # one object per batch
    q.step()
    # objects arriving AFTER the stream started join later batches
    for c, rows in enumerate(chunks[2:], start=2):
        ctx.store.put(f"events/{c:04d}.csv", _csv(rows))
    src.seal()
    got = q.run()
    assert got == py_reference([r for c in chunks for r in c], 10)
    assert q.batch >= 4  # at most one object consumed per batch
    q.cleanup()
    ctx.store.delete_prefix("events/")
    assert_no_leaks(ctx)


def test_tailer_offsets_replay_and_diverge():
    ctx = FlintContext("flint", _cfg())
    ctx.store.put("tl/a", _csv([(1, "k", 2)]))
    ctx.store.put("tl/b", _csv([(3, "k", 4)]))
    src = S3PrefixTailer(ctx.store, "tl/", TAIL_SCHEMA)
    assert src.initial() == ()
    o1 = src.next_offset((), 1)
    o2 = src.next_offset(o1, 5)
    assert o1 == ("tl/a",) and o2 == ("tl/a", "tl/b")
    assert src.read(o1, o2) == src.read(o1, o2) == [(3, "k", 4)]
    with pytest.raises(ValueError, match="diverged"):
        src.read(("tl/b",), o2)
    assert not src.exhausted(o2)
    src.seal()
    assert src.exhausted(o2) and not src.exhausted(o1)


# ------------------------------------------------ property: replayed
# stream == batch reference, any batch size, any window shape


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       batch_size=st.sampled_from([37, 90, 250]),
       panes=st.sampled_from([1, 2]))
def test_property_stream_equals_batch_reference(seed, batch_size, panes):
    ctx = FlintContext("flint", _cfg("sqs"))
    src = EventGenerator(seed=seed, total=220, rate=10, late_prob=0.25,
                         max_delay=4)
    q = _sum_count_stream(ctx, src, size=10 * panes, slide=10,
                          batch_size=batch_size, name=f"prop{seed}")
    got = q.run()
    assert got == py_reference(src.read(0, 220), 10 * panes, 10)
    q.cleanup()
    assert_no_leaks(ctx)


# ------------------------------------------------ service integration


def test_service_streaming_admission_and_quota():
    """A streaming query admits ONCE as a long-running job (batches do
    not re-queue at the gate), a second stream on the same session is
    refused, and a tenant crossing its budget is stopped BETWEEN batches
    with the structured quota failure. Neighbors are unaffected."""
    svc = FlintService(_cfg("sqs"), slot_capacity=4)
    svc.register_tenant("a")
    svc.register_tenant("broke", max_usd=1e-9)
    svc.register_tenant("rich")
    with svc.session("a") as s:
        src = EventGenerator(seed=1, total=200, rate=10, max_delay=2)
        q = (s.read_stream(src)
             .window("ts", 10)
             .groupBy("key")
             .agg(sum_(col("val")).alias("t"), count_().alias("n"))
             .start("svc-q", allowed_lateness=2, batch_size=60))
        with pytest.raises(RuntimeError, match="already runs"):
            s.read_stream(EventGenerator(seed=2, total=10)) \
                .window("ts", 10).groupBy("key") \
                .agg(count_().alias("n")).start("svc-q2")
        assert q.run() == py_reference(src.read(0, 200), 10)
        q.cleanup()
    with svc.session("broke") as s:
        src = EventGenerator(seed=1, total=200, rate=10, max_delay=2)
        q = (s.read_stream(src).window("ts", 10).groupBy("key")
             .agg(count_().alias("n"))
             .start("broke-q", batch_size=60))
        with pytest.raises(StageFailure) as ei:
            q.run()
        assert ei.value.error_type == "TenantQuotaExceeded"
        q.cleanup()
    with svc.session("rich") as s:  # neighbor unaffected, slot released
        src = EventGenerator(seed=1, total=60, rate=10, max_delay=2)
        q = (s.read_stream(src).window("ts", 10).groupBy("key")
             .agg(count_().alias("n")).start("rich-q", batch_size=60))
        assert q.run()
        q.cleanup()
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values()), \
        svc.leak_report()


@pytest.mark.parametrize("backend", BACKENDS)
def test_service_streaming_under_chaos_with_lost_checkpoint(backend):
    """Seeded transient faults on every service call PLUS one eaten
    ``_stream/`` checkpoint write, with a driver kill/resume in the
    middle: the finalized windows still exactly match the fault-free
    batch reference, and nothing leaks."""
    plan = FaultPlan(seed=CHAOS_SEED * 31 + 7,
                     s3_error_prob=0.05, sqs_error_prob=0.05,
                     lose_keys=("chaos-q/ckpt/",))  # first ckpt write lost
    svc = FlintService(_cfg(backend, max_stage_retries=5,
                            retry_base_s=0.001, retry_cap_s=0.01),
                       fault_plan=plan, slot_capacity=4)
    svc.register_tenant("t")
    src = EventGenerator(seed=13, total=300, rate=10, late_prob=0.3,
                         max_delay=3)
    expected = py_reference(src.read(0, 300), 10)
    with svc.session("t") as s:

        def make_q():
            return (s.read_stream(EventGenerator(
                        seed=13, total=300, rate=10, late_prob=0.3,
                        max_delay=3))
                    .window("ts", 10)
                    .groupBy("key")
                    .agg(sum_(col("val")).alias("t"),
                         count_().alias("n"))
                    .start("chaos-q", allowed_lateness=3, batch_size=90))
        q1 = make_q()
        q1.step()
        q1.step()
        q1.stop()  # driver killed mid-stream (slot released)
        q2 = make_q()
        assert q2.run() == expected
        assert q2.cleanup() >= 1
        stray = [k for k in s.ctx.store.list("_collections/")]
        assert stray == [], f"staged batch data leaked: {stray[:5]}"
    svc.close()
    assert all(v == 0 for v in svc.leak_report().values()), \
        svc.leak_report()
